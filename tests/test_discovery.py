"""End-to-end FREYJA behaviour: predictor accuracy, ranking, generalization
across lakes (the paper's central claims at test scale)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DiscoveryIndex, GBDTConfig, LakeSpec, generate_lake,
                        profile_lake, rank, select_queries,
                        train_quality_model)
from repro.core.gbdt import fit_gbdt, predict_np
from repro.core.predictor import (exact_jk, gbdt_predict_ref,
                                  pairwise_distances, predict_scores_ref)
from repro.kernels import ops


@pytest.fixture(scope="module")
def trained(small_lake_module):
    lake, prof = small_lake_module
    model = train_quality_model([lake], GBDTConfig(n_trees=30, depth=4),
                                n_query=64)
    return lake, prof, model


@pytest.fixture(scope="module")
def small_lake_module():
    from repro.core import LakeSpec, generate_lake, profile_lake
    lake = generate_lake(LakeSpec(n_domains=10, n_tables=24, row_budget=2048,
                                  rows_log_mean=6.8, coverage_range=(0.5, 1.0),
                                  gran_ratio=(4, 8), seed=7))
    return lake, profile_lake(lake.batch)


def test_gbdt_fit_quality(trained):
    _, _, model = trained
    assert model.train_r2 > 0.5


def test_gbdt_kernel_matches_numpy(trained):
    lake, prof, model = trained
    qids = np.arange(8)
    d = np.asarray(pairwise_distances(prof, qids)).reshape(-1, 23)[:500]
    a = predict_np(model.gbdt, d)
    b = np.asarray(ops.gbdt_infer(d, model.gbdt))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_ranking_precision(trained):
    lake, prof, model = trained
    idx = DiscoveryIndex(profiles=prof, model=model, table_ids=lake.table)
    qids = select_queries(lake, 12, min_semantic=3)
    scores, ids = rank(idx, qids, k=3)
    valid = np.isfinite(scores)
    sem = lake.is_semantic(np.repeat(qids, 3), ids.reshape(-1)).reshape(-1)
    p_at_3 = (sem & valid.reshape(-1)).sum() / max(valid.sum(), 1)
    assert p_at_3 > 0.6, p_at_3


def test_generalizes_to_unseen_lake(trained):
    """The paper's claim: one model, no per-lake fine-tuning."""
    _, _, model = trained
    lake2 = generate_lake(LakeSpec(n_domains=8, n_tables=20, row_budget=512,
                                   rows_log_mean=5.2, seed=123,
                                   zipf_range=(0.2, 1.2)))
    prof2 = profile_lake(lake2.batch)
    idx = DiscoveryIndex(profiles=prof2, model=model, table_ids=lake2.table)
    qids = select_queries(lake2, 10, min_semantic=3)
    scores, ids = rank(idx, qids, k=5)
    valid = np.isfinite(scores)
    sem = lake2.is_semantic(np.repeat(qids, 5), ids.reshape(-1)).reshape(-1)
    p_at_5 = (sem & valid.reshape(-1)).sum() / max(valid.sum(), 1)
    assert p_at_5 > 0.55, p_at_5


def test_prediction_correlates_with_exact(trained):
    lake, prof, model = trained
    qids = np.arange(0, lake.n_columns, 7)[:16]
    j, k = exact_jk(lake, qids)
    from repro.core import quality
    y = np.asarray(quality.continuous_quality(jnp.asarray(j), jnp.asarray(k),
                                              model.strictness))
    pred = predict_scores_ref(model, prof, qids)
    # correlation over pairs with any signal
    mask = (y > 0.01) | (pred > 0.01)
    if mask.sum() > 10:
        r = np.corrcoef(y[mask], pred[mask])[0, 1]
        assert r > 0.6, r


def test_rank_exclude_same_table_masking(trained):
    """With exclusion on, no result shares the query's table; with it off,
    same-table columns (near-duplicates) dominate the top ranks."""
    lake, prof, model = trained
    idx = DiscoveryIndex(profiles=prof, model=model, table_ids=lake.table)
    qids = select_queries(lake, 8, min_semantic=3)
    scores_ex, ids_ex = rank(idx, qids, k=5, exclude_same_table=True)
    for qi, q in enumerate(qids):
        valid = np.isfinite(scores_ex[qi])
        assert (lake.table[ids_ex[qi][valid]] != lake.table[q]).all()
    # and the self column never appears either way
    _, ids_in = rank(idx, qids, k=5, exclude_same_table=False)
    for qi, q in enumerate(qids):
        assert q not in ids_in[qi]


def test_rank_k_exceeds_lake_size(trained):
    lake, prof, model = trained
    idx = DiscoveryIndex(profiles=prof, model=model, table_ids=lake.table)
    n = idx.n_columns
    k = n + 7
    qids = np.asarray([0, 1], np.int32)
    scores, ids = rank(idx, qids, k=k, exclude_same_table=False)
    assert scores.shape == (2, k) and ids.shape == (2, k)
    assert not np.isfinite(scores[:, n:]).any()
    assert (ids[:, n:] == -1).all()
    valid = np.isfinite(scores[0])
    assert np.unique(ids[0][valid]).size == valid.sum()  # no duplicate columns


def test_rank_matches_sharded_on_local_mesh(trained):
    """rank and rank_sharded agree on whatever host mesh exists (run the
    suite with XLA_FLAGS=--xla_force_host_platform_device_count=8 to make
    this a genuine multi-device check; test_distributed.py always does)."""
    import jax
    from repro.core.discovery import rank_sharded as _rs
    lake, prof, model = trained
    idx = DiscoveryIndex(profiles=prof, model=model, table_ids=lake.table)
    qids = select_queries(lake, 6, min_semantic=3)
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    s1, i1 = rank(idx, qids, k=5, exclude_same_table=False)
    s2, i2 = _rs(idx, qids, mesh, k=5, shard_axes=("data",))
    np.testing.assert_allclose(np.sort(s1, 1), np.sort(s2, 1),
                               rtol=1e-4, atol=1e-5)
    overlap = np.mean([len(set(a) & set(b)) / 5.0 for a, b in zip(i1, i2)])
    assert overlap > 0.9, overlap


def test_rank_sharded_k_exceeds_shard_size(trained):
    """k larger than the per-shard column count must not crash the local
    top-k (regression for the kl clamp)."""
    import jax
    lake, prof, model = trained
    # tiny sub-index: fewer columns than k after sharding
    import dataclasses as dc
    sub = np.arange(6)
    prof_small = dc.replace(prof, numeric=prof.numeric[sub],
                            words=prof.words[sub], n_rows=prof.n_rows[sub])
    idx = DiscoveryIndex(profiles=prof_small, model=model,
                         table_ids=lake.table[sub])
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    from repro.core.discovery import rank_sharded as _rs
    scores, ids = _rs(idx, np.asarray([0, 1]), mesh, k=10)
    assert scores.shape == (2, 10)
    s_ref, _ = rank(idx, np.asarray([0, 1]), k=10, exclude_same_table=False)
    # same-table exclusion differs; compare only the score multiset of the
    # shared convention (sharded path never excludes same-table)
    np.testing.assert_allclose(np.sort(scores, 1), np.sort(s_ref, 1),
                               rtol=1e-4, atol=1e-5)


def test_fused_kernel_path_matches_ref(trained):
    lake, prof, model = trained
    qids = np.arange(6)
    z = prof.zscored.astype(np.float32)
    w = prof.words
    s_ref = predict_scores_ref(model, prof, qids)
    s_k = np.asarray(ops.fused_score(z[qids], w[qids], z, w, model.gbdt))
    np.testing.assert_allclose(s_k, s_ref, rtol=1e-4, atol=1e-5)
