"""Exact multiset/set intersections: hypothesis vs brute force; JAX batch
path vs numpy path."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.ingest import sketch_from_hashes
from repro.core.sketches import (batch_exact_metrics, intersections_np,
                                 pack_sketches, pair_metrics_np)


def _brute(a, b):
    from collections import Counter
    ca, cb = Counter(a), Counter(b)
    multi = sum(min(ca[v], cb[v]) for v in ca)
    inter = len(set(a) & set(b))
    return multi, inter


@given(st.lists(st.integers(0, 20), min_size=1, max_size=60),
       st.lists(st.integers(0, 20), min_size=1, max_size=60))
@settings(max_examples=100, deadline=None)
def test_intersections_vs_brute(a, b):
    sa = sketch_from_hashes(np.asarray(a, np.uint64))
    sb = sketch_from_hashes(np.asarray(b, np.uint64))
    assert intersections_np(sa, sb) == _brute(a, b)


@given(st.lists(st.lists(st.integers(0, 15), min_size=1, max_size=40),
                min_size=2, max_size=6))
@settings(max_examples=30, deadline=None)
def test_batch_metrics_match_numpy(cols):
    sketches = [sketch_from_hashes(np.asarray(c, np.uint64)) for c in cols]
    packed = pack_sketches(sketches)
    qv = jnp.asarray(packed.values)
    qc = jnp.asarray(packed.counts)
    qcard = jnp.asarray(packed.card)
    qrows = jnp.asarray(packed.n_rows)
    m = batch_exact_metrics(qv, qc, qcard, qrows, qv, qc, qcard, qrows)
    for i, si in enumerate(sketches):
        for j, sj in enumerate(sketches):
            ref = pair_metrics_np(si, sj)
            assert np.isclose(float(m["j_multi"][i, j]), ref["j_multi"], atol=1e-5)
            assert np.isclose(float(m["k"][i, j]), ref["k"], atol=1e-5)
            assert np.isclose(float(m["jaccard"][i, j]), ref["jaccard"], atol=1e-5)
            assert np.isclose(float(m["containment"][i, j]), ref["containment"], atol=1e-5)


def test_self_join_is_maximal():
    s = sketch_from_hashes(np.arange(100, dtype=np.uint64))
    m = pair_metrics_np(s, s)
    assert m["j_multi"] == 0.5 and m["k"] == 1.0 and m["jaccard"] == 1.0
