"""Exact multiset/set intersections: hypothesis vs brute force; JAX batch
path vs numpy path."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.ingest import sketch_from_hashes
from repro.core.sketches import (batch_exact_metrics, intersections_np,
                                 pack_sketches, pair_metrics_np)


def _brute(a, b):
    from collections import Counter
    ca, cb = Counter(a), Counter(b)
    multi = sum(min(ca[v], cb[v]) for v in ca)
    inter = len(set(a) & set(b))
    return multi, inter


@given(st.lists(st.integers(0, 20), min_size=1, max_size=60),
       st.lists(st.integers(0, 20), min_size=1, max_size=60))
@settings(max_examples=100, deadline=None)
def test_intersections_vs_brute(a, b):
    sa = sketch_from_hashes(np.asarray(a, np.uint64))
    sb = sketch_from_hashes(np.asarray(b, np.uint64))
    assert intersections_np(sa, sb) == _brute(a, b)


@given(st.lists(st.lists(st.integers(0, 15), min_size=1, max_size=40),
                min_size=2, max_size=6))
@settings(max_examples=30, deadline=None)
def test_batch_metrics_match_numpy(cols):
    sketches = [sketch_from_hashes(np.asarray(c, np.uint64)) for c in cols]
    packed = pack_sketches(sketches)
    qv = jnp.asarray(packed.values)
    qc = jnp.asarray(packed.counts)
    qcard = jnp.asarray(packed.card)
    qrows = jnp.asarray(packed.n_rows)
    m = batch_exact_metrics(qv, qc, qcard, qrows, qv, qc, qcard, qrows)
    for i, si in enumerate(sketches):
        for j, sj in enumerate(sketches):
            ref = pair_metrics_np(si, sj)
            assert np.isclose(float(m["j_multi"][i, j]), ref["j_multi"], atol=1e-5)
            assert np.isclose(float(m["k"][i, j]), ref["k"], atol=1e-5)
            assert np.isclose(float(m["jaccard"][i, j]), ref["jaccard"], atol=1e-5)
            assert np.isclose(float(m["containment"][i, j]), ref["containment"], atol=1e-5)


def test_self_join_is_maximal():
    s = sketch_from_hashes(np.arange(100, dtype=np.uint64))
    m = pair_metrics_np(s, s)
    assert m["j_multi"] == 0.5 and m["k"] == 1.0 and m["jaccard"] == 1.0


def _metrics_self(packed):
    args = [jnp.asarray(a) for a in (packed.values, packed.counts,
                                     packed.card, packed.n_rows)]
    return batch_exact_metrics(*args, *args)


def test_pack_sketches_empty_list():
    p = pack_sketches([])
    assert p.values.shape == (0, 1) and p.card.shape == (0,)
    m = _metrics_self(p)
    assert all(v.shape == (0, 0) for v in m.values())


def test_pack_sketches_all_empty_sketches():
    """Sketches with zero distinct values must not produce zero-width packing
    (regression: k collapsed to 0 and the searchsorted probe crashed)."""
    empty = sketch_from_hashes(np.zeros((0,), np.uint64))
    p = pack_sketches([empty, empty])
    assert p.values.shape[1] >= 1
    assert (p.card == 0).all()
    m = _metrics_self(p)
    assert float(m["j_multi"][0, 1]) == 0.0
    assert float(m["jaccard"][0, 1]) == 0.0


def test_pack_sketches_k_max_zero():
    """k_max=0 used to be silently replaced by the cardinality cap."""
    s = sketch_from_hashes(np.arange(5, dtype=np.uint64))
    p = pack_sketches([s], k_max=0)
    assert p.values.shape == (1, 1)
    assert p.card[0] == 1          # truncated to the packing width
    m = _metrics_self(p)
    assert np.isfinite(np.asarray(m["j_multi"])).all()


def test_pack_sketches_k_max_truncates():
    s = sketch_from_hashes(np.arange(10, dtype=np.uint64))
    p = pack_sketches([s], k_max=4)
    assert p.values.shape == (1, 4) and p.card[0] == 4
