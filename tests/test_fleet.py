"""Engine-replica fleet: lifecycle, load-aware routing, fault injection.

Two layers of hardening for the concurrent subsystem:

* **fault injection** — :class:`FaultInjector` kills or hangs replica
  workers at named points (mid-batch, mid-warmup, mid-drain); the
  invariants under every fault are that each accepted future resolves
  (a re-dispatched result or a clean ``SchedulerOverloadError``), no
  batch is silently dropped, and an evicted replica's pinned snapshots
  are released (refcounts return to zero, executor closed);
* **routing invariants** — hypothesis property tests over arbitrary
  synthetic replica states: the router never places on a non-SERVING
  replica, placement is deterministic, and queue-depth spread stays
  bounded (no ready replica starves).
"""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.service import (ColumnCatalog, DiscoveryEngine, DiscoveryRequest,
                           EngineConfig, EngineFleet, EventBus, FaultInjector,
                           FleetConfig, FleetRouter, ReplicaSnapshot,
                           RequestScheduler, SchedulerConfig,
                           SchedulerOverloadError)
from repro.service.fleet import DRAINING, EVICTED, SERVING, WARMING, _FleetBatch
from repro.service.scheduler import _Item
from concurrent.futures import Future


def _tiny_model():
    from repro.core.gbdt import GBDTParams
    from repro.core.predictor import JoinQualityModel
    p = GBDTParams(feats=np.zeros((1, 1), np.int32),
                   thrs=np.zeros((1, 1), np.float32),
                   leaves=np.zeros((1, 2), np.float32), base=0.0)
    return JoinQualityModel(gbdt=p)


@pytest.fixture(scope="module")
def snapshot(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("fleet_catalog"))
    cat = ColumnCatalog(root, n_perm=64)
    for t in range(4):
        cat.add_table(f"t{t}",
                      [(f"c{t}a", [f"v{t}_{i}" for i in range(60)]),
                       (f"c{t}b", [f"w{i % 11}" for i in range(40)])])
    return cat.snapshot()


MODEL = _tiny_model()


def _make_fleet(snapshot, n=2, injector=None, bus=None, **cfg):
    engines = [DiscoveryEngine(snapshot, MODEL,
                               EngineConfig(k=3, mode="full",
                                            cache_entries=0), events=bus)
               for _ in range(n)]
    cfg.setdefault("health_interval_s", 0.05)
    return EngineFleet(engines, FleetConfig(**cfg), events=bus,
                       injector=injector)


def _reqs(prefix, n):
    return [DiscoveryRequest(name=f"{prefix}{i}", column_id=i % 8)
            for i in range(n)]


def _wait_until(pred, timeout=20.0):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout:
            raise AssertionError("condition not reached in time")
        time.sleep(0.01)


def _assert_pins_released(replica):
    """The eviction contract: engine closed, head refcount zero, its
    executor closed, and no other live version remains."""
    eng = replica.engine
    assert eng.closed
    _wait_until(lambda: eng._head.refs == 0)
    assert eng._head.executor.closed
    assert not eng._live


class _Gate:
    """Stall one replica engine's batch path under test control."""

    def __init__(self, engine):
        self.release = threading.Event()
        self.entered = threading.Event()
        real = engine.query_batch

        def wrapped(reqs, **kw):
            self.entered.set()
            assert self.release.wait(30)
            return real(reqs, **kw)

        engine.query_batch = wrapped


# ---------------------------------------------------------------------------
# lifecycle + serving
# ---------------------------------------------------------------------------

def test_fleet_serves_through_scheduler_with_parity(snapshot):
    direct = DiscoveryEngine(snapshot, MODEL,
                             EngineConfig(k=3, mode="full", cache_entries=0))
    baseline = {r.name: r for r in direct.query_batch(_reqs("q", 12))}
    fleet = _make_fleet(snapshot, n=2)
    try:
        with RequestScheduler(fleet, SchedulerConfig(max_wait_ms=1.0)) as sch:
            futs = [sch.submit(r) for r in _reqs("q", 12)]
            outs = [f.result(timeout=60) for f in futs]
        for r in outs:
            want = baseline[r.name]
            assert [m.column_id for m in r.matches] == \
                [m.column_id for m in want.matches]
            assert r.queue_ms >= 0.0 and r.compute_ms > 0.0
            assert r.latency_ms == pytest.approx(r.queue_ms + r.compute_ms)
        st_ = fleet.stats()
        assert st_["completed"] == 12
        assert st_["scheduler"]["completed"] == 12
        assert st_["scheduler"]["failed"] == 0
        assert all(v["state"] == SERVING for v in st_["replicas"].values())
    finally:
        fleet.close()
    # close() retires every replica and releases every pinned snapshot
    for r in fleet.replicas:
        assert r.state == EVICTED
        _assert_pins_released(r)


def test_fleet_query_batch_direct_no_scheduler(snapshot):
    fleet = _make_fleet(snapshot, n=2)
    try:
        outs = fleet.query_batch(_reqs("d", 5), timeout=60)
        assert [r.name for r in outs] == [f"d{i}" for i in range(5)]
    finally:
        fleet.close()


def test_replica_state_events_on_shared_bus(snapshot):
    bus = EventBus(capacity=512)
    cur = bus.subscribe("test")
    fleet = _make_fleet(snapshot, n=2, bus=bus)
    try:
        _wait_until(lambda: all(r.state == SERVING for r in fleet.replicas))
        fleet.query_batch(_reqs("e", 3), timeout=60)
    finally:
        fleet.close()
    evs = cur.poll()
    flips = [e.payload for e in evs if e.type == "replica_state"]
    assert sum(1 for p in flips if p["state"] == SERVING) == 2
    assert sum(1 for p in flips if p["state"] == EVICTED) == 2
    routed = [e for e in evs if e.type == "batch_routed"]
    assert routed and all("replica" in e.payload for e in routed)


def test_drain_lifecycle_releases_engine_and_traffic_moves(snapshot):
    fleet = _make_fleet(snapshot, n=2)
    try:
        fleet.query_batch(_reqs("w", 2), timeout=60)
        fleet.drain_replica(0)
        _wait_until(lambda: fleet.replicas[0].state == EVICTED)
        _assert_pins_released(fleet.replicas[0])
        served_before = fleet.replicas[1].batches_served
        outs = fleet.query_batch(_reqs("x", 3), timeout=60)
        assert len(outs) == 3
        assert fleet.replicas[1].batches_served == served_before + 1
        assert fleet.replicas[0].batches_served <= 1  # nothing post-drain
    finally:
        fleet.close()


def test_install_buckets_propagates_to_every_replica(snapshot):
    fleet = _make_fleet(snapshot, n=3)
    try:
        with RequestScheduler(fleet,
                              SchedulerConfig(max_wait_ms=0.0,
                                              batch_buckets=(4, 8))):
            for r in fleet.replicas:
                assert r.engine.config.batch_buckets == (4, 8)
                assert r.engine.planner.config.batch_buckets == (4, 8)
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

def test_kill_mid_batch_redispatches_everything(snapshot):
    inj = FaultInjector()
    inj.arm("mid_batch", mode="kill")
    fleet = _make_fleet(snapshot, n=2, injector=inj)
    try:
        with RequestScheduler(fleet, SchedulerConfig(max_wait_ms=1.0)) as sch:
            futs = [sch.submit(r) for r in _reqs("k", 10)]
            outs = [f.result(timeout=60) for f in futs]   # ALL resolve
        assert [r.name for r in outs] == [f"k{i}" for i in range(10)]
        st_ = fleet.stats()
        assert st_["evictions"] == 1 and st_["redispatches"] >= 1
        # no batch silently dropped: every submission is accounted for
        assert st_["scheduler"]["completed"] == 10
        assert st_["scheduler"]["failed"] == 0
        killed = [r for r in fleet.replicas if r.state == EVICTED]
        assert len(killed) == 1
        _assert_pins_released(killed[0])
        assert inj.fired and inj.fired[0][2] == "kill"
    finally:
        fleet.close()


def test_kill_mid_warmup_survivor_serves(snapshot):
    inj = FaultInjector()
    inj.arm("mid_warmup", replica=0, mode="kill")
    fleet = _make_fleet(snapshot, n=2, injector=inj)
    try:
        _wait_until(lambda: fleet.replicas[0].state == EVICTED)
        _assert_pins_released(fleet.replicas[0])
        outs = fleet.query_batch(_reqs("s", 4), timeout=60)
        assert len(outs) == 4
        assert fleet.replicas[1].state == SERVING
        assert fleet.warm_event.is_set()
    finally:
        fleet.close()


def test_hang_mid_batch_health_evicts_and_redispatches(snapshot):
    inj = FaultInjector()
    inj.arm("mid_batch", mode="hang")
    fleet = _make_fleet(snapshot, n=2, injector=inj,
                        health_interval_s=0.05, hang_timeout_s=0.25)
    try:
        with RequestScheduler(fleet, SchedulerConfig(max_wait_ms=1.0)) as sch:
            futs = [sch.submit(r) for r in _reqs("h", 8)]
            outs = [f.result(timeout=60) for f in futs]   # ALL resolve
        assert len(outs) == 8
        st_ = fleet.stats()
        assert st_["evictions"] == 1 and st_["redispatches"] >= 1
        hung = [r for r in fleet.replicas if r.state == EVICTED][0]
        assert hung.engine.closed
    finally:
        inj.release_hangs()               # let the hung worker exit
        fleet.close()
    # the un-hung worker finds its engine closed and exits without
    # corrupting anything; its pin count still returns to zero
    _assert_pins_released([r for r in fleet.replicas
                           if r.batches_served == 0][0])


def test_hang_mid_warmup_evicted_by_health_check(snapshot):
    inj = FaultInjector()
    inj.arm("mid_warmup", replica=0, mode="hang")
    fleet = _make_fleet(snapshot, n=2, injector=inj,
                        health_interval_s=0.05, hang_timeout_s=10.0,
                        warmup_timeout_s=0.25)
    try:
        _wait_until(lambda: fleet.replicas[0].state == EVICTED)
        outs = fleet.query_batch(_reqs("wh", 3), timeout=60)
        assert len(outs) == 3
    finally:
        inj.release_hangs()
        fleet.close()


def test_kill_mid_drain_redispatches_queued_batch(snapshot):
    inj = FaultInjector()
    inj.arm("mid_drain", replica=0, mode="kill")
    fleet = _make_fleet(snapshot, n=2, injector=inj)
    try:
        _wait_until(lambda: all(r.state == SERVING for r in fleet.replicas))
        gate = _Gate(fleet.replicas[0].engine)

        def item(name):
            return _Item(request=DiscoveryRequest(name=name, column_id=0),
                         future=Future(), t_submit=time.perf_counter(),
                         deadline=None, trace_id=name)

        # stage directly on replica 0 (bypassing the router) so a batch
        # is QUEUED behind the gated in-flight one when the drain begins
        b1, b2 = _FleetBatch([item("b1")]), _FleetBatch([item("b2")])
        assert fleet.replicas[0].enqueue(b1)
        assert gate.entered.wait(30)
        assert fleet.replicas[0].enqueue(b2)
        fleet.drain_replica(0)
        gate.release.set()
        # b1 finishes on replica 0; b2 hits mid_drain -> kill -> the
        # fleet re-dispatches it to the surviving replica
        assert b1.items[0].future.result(timeout=60).name == "b1"
        assert b2.items[0].future.result(timeout=60).name == "b2"
        _wait_until(lambda: fleet.replicas[0].state == EVICTED)
        _assert_pins_released(fleet.replicas[0])
        assert fleet.stats()["redispatches"] == 1
        assert fleet.replicas[1].requests_served >= 1
    finally:
        fleet.close()


def test_every_replica_killed_fails_futures_cleanly(snapshot):
    """With every replica repeatedly killed, accepted futures must still
    ALL resolve — with a clean SchedulerOverloadError, never a hang."""
    inj = FaultInjector()
    inj.arm("mid_batch", mode="kill", times=99)
    fleet = _make_fleet(snapshot, n=2, injector=inj, max_redispatch=2)
    try:
        with RequestScheduler(fleet, SchedulerConfig(max_wait_ms=1.0)) as sch:
            futs = [sch.submit(r) for r in _reqs("x", 6)]
            outcomes = []
            for f in futs:
                try:
                    outcomes.append(("ok", f.result(timeout=60)))
                except SchedulerOverloadError:
                    outcomes.append(("overload", None))
        assert len(outcomes) == 6                      # nothing hung
        assert all(kind == "overload" for kind, _ in outcomes)
        st_ = fleet.stats()
        assert st_["evictions"] == 2
        assert st_["scheduler"]["failed"] == 6         # nothing dropped
        for r in fleet.replicas:
            _assert_pins_released(r)
        # late submissions fail fast instead of queueing forever
        with pytest.raises((SchedulerOverloadError, RuntimeError)):
            fleet.query_batch(_reqs("late", 1), timeout=10)
    finally:
        fleet.close()


def test_fault_injector_validates_points_and_modes():
    inj = FaultInjector()
    with pytest.raises(ValueError, match="point"):
        inj.arm("mid_nothing")
    with pytest.raises(ValueError, match="mode"):
        inj.arm("mid_batch", mode="explode")
    inj.arm("mid_batch", replica=3, times=2)
    inj.check("mid_batch", 1)             # wrong replica: no fire
    assert not inj.fired


# ---------------------------------------------------------------------------
# routing invariants (property tests)
# ---------------------------------------------------------------------------

def _random_snapshots(rnd, n_replicas):
    states = (WARMING, SERVING, DRAINING, EVICTED)
    return [ReplicaSnapshot(replica_id=i,
                            state=rnd.choice(states),
                            queue_depth=rnd.randrange(0, 500),
                            cost_per_item=rnd.uniform(1e-4, 10.0))
            for i in range(n_replicas)]


@settings(max_examples=60)
@given(st.randoms(), st.integers(1, 8), st.integers(0, 64))
def test_router_never_places_on_non_serving(rnd, n_replicas, spread):
    snaps = _random_snapshots(rnd, n_replicas)
    rid = FleetRouter(max_depth_spread=spread).choose(
        snaps, n_items=rnd.randrange(1, 65))
    if rid is None:
        assert all(s.state != SERVING for s in snaps)
    else:
        assert snaps[rid].state == SERVING


@settings(max_examples=60)
@given(st.randoms(), st.integers(1, 8), st.integers(0, 64))
def test_router_is_deterministic(rnd, n_replicas, spread):
    snaps = _random_snapshots(rnd, n_replicas)
    n = rnd.randrange(1, 65)
    router = FleetRouter(max_depth_spread=spread)
    first = router.choose(snaps, n_items=n)
    assert all(router.choose(list(snaps), n_items=n) == first
               for _ in range(5))


@settings(max_examples=40)
@given(st.randoms(), st.integers(2, 6), st.integers(0, 32))
def test_router_bounds_queue_depth_spread(rnd, n_replicas, spread):
    """Over any placement sequence (no consumption — worst case), the
    depth gap between the most- and least-loaded SERVING replicas never
    exceeds ``max_depth_spread + n_max`` — the no-starvation bound."""
    router = FleetRouter(max_depth_spread=spread)
    costs = [rnd.uniform(1e-3, 5.0) for _ in range(n_replicas)]
    depths = [0] * n_replicas
    n_max = 0
    for _ in range(100):
        n = rnd.randrange(1, 9)
        n_max = max(n_max, n)
        snaps = [ReplicaSnapshot(i, SERVING, depths[i], costs[i])
                 for i in range(n_replicas)]
        rid = router.choose(snaps, n_items=n)
        assert rid is not None
        # eligibility bound at choose time
        assert depths[rid] <= min(depths) + spread
        depths[rid] += n
        assert max(depths) - min(depths) <= spread + n_max


@settings(max_examples=40)
@given(st.randoms(), st.integers(2, 8))
def test_router_equal_cost_is_least_loaded_round_robin(rnd, n_replicas):
    """Equal costs + equal batch sizes: each of the first ``n_replicas``
    placements lands on a distinct replica (nobody starves while an
    idle peer exists)."""
    router = FleetRouter(max_depth_spread=64)
    cost = rnd.uniform(1e-3, 5.0)
    depths = [0] * n_replicas
    hit = []
    for _ in range(n_replicas):
        snaps = [ReplicaSnapshot(i, SERVING, depths[i], cost)
                 for i in range(n_replicas)]
        rid = router.choose(snaps, n_items=4)
        hit.append(rid)
        depths[rid] += 4
    assert sorted(hit) == list(range(n_replicas))


def test_router_empty_and_all_evicted():
    r = FleetRouter()
    assert r.choose([], n_items=1) is None
    assert r.choose([ReplicaSnapshot(0, EVICTED, 0, 1.0),
                     ReplicaSnapshot(1, DRAINING, 0, 1.0)]) is None


def test_router_prefers_cheap_replica_under_load():
    """A 2x-faster replica absorbs more work until depths rebalance."""
    r = FleetRouter(max_depth_spread=64)
    snaps = [ReplicaSnapshot(0, SERVING, 10, 1.0),
             ReplicaSnapshot(1, SERVING, 10, 0.25)]
    assert r.choose(snaps, n_items=8) == 1
    # but the spread cap still overrides raw cost
    snaps = [ReplicaSnapshot(0, SERVING, 0, 1.0),
             ReplicaSnapshot(1, SERVING, 100, 0.001)]
    assert r.choose(snaps, n_items=8) == 0
