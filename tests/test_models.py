"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.trainer import build_train_step

ARCHS = registry.list_archs()


def _batch_for(cfg, b=2, s=64, key=0):
    r = np.random.default_rng(key)
    batch = {"tokens": jnp.asarray(r.integers(1, cfg.vocab, (b, s)), jnp.int32),
             "labels": jnp.asarray(r.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["img"] = jnp.asarray(
            r.normal(size=(b, cfg.n_patches, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            r.normal(size=(b, s, cfg.d_model)) * 0.02, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = registry.reduced_config(registry.get_config(arch))
    params, specs = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits = registry.forward(params, cfg, batch)
    assert logits.shape == (2, 64, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # sharding spec tree must cover every param leaf
    n_p = len(jax.tree.leaves(params))
    n_s = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, tuple)))
    assert n_p == n_s


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = registry.reduced_config(registry.get_config(arch))
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3)))
    params2, opt2, metrics = step(params, opt, _batch_for(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32) -
                                                b.astype(jnp.float32)).sum()),
                     params, params2))
    assert delta > 0


@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x22b", "zamba2-2.7b",
                                  "rwkv6-3b"])
def test_decode_consistency(arch):
    """Token-by-token decode equals teacher-forced forward."""
    cfg = registry.reduced_config(registry.get_config(arch))
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(1))
    b, n = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, n), 0, cfg.vocab)
    s_pad = max(cfg.attn_chunk, n)
    full = np.asarray(registry.forward(
        params, cfg, {"tokens": jnp.pad(toks, ((0, 0), (0, s_pad - n)))}))[:, :n]
    caches = registry.init_caches(cfg, b, 128)
    outs = []
    for i in range(n):
        lg, caches = registry.decode_step(params, cfg,
                                          {"tokens": toks[:, i:i + 1]}, caches)
        outs.append(np.asarray(lg)[:, 0])
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, full, rtol=2e-2, atol=2e-4)


def test_vlm_prefill_decode_consistency():
    """VLM: prefill-with-caches must carry the image prefix into decode."""
    from repro.models.transformer import forward_with_caches
    cfg = registry.reduced_config(registry.get_config("phi-3-vision-4.2b"))
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 64
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(1, cfg.vocab, (b, s)), jnp.int32)
    img = jnp.asarray(r.normal(size=(b, cfg.n_patches, cfg.d_model)) * 0.02,
                      jnp.float32)
    full = np.asarray(registry.forward(params, cfg,
                                       {"tokens": toks, "img": img}))
    _, caches = forward_with_caches(params, cfg, toks[:, :s // 2], 128, img=img)
    outs = []
    for i in range(s // 2, s):
        lg, caches = registry.decode_step(params, cfg,
                                          {"tokens": toks[:, i:i + 1]}, caches)
        outs.append(np.asarray(lg)[:, 0])
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, full[:, s // 2:], rtol=2e-2, atol=2e-4)


def test_sliding_window_ring_cache():
    """SWA prefill->decode stays consistent with full forward beyond W.

    capacity_factor is raised so no MoE tokens drop: the prefill and the
    full forward see different token counts, so capacity-dropping (a real
    effect, not a bug) would otherwise make outputs incomparable.
    """
    from repro.models.transformer import forward_with_caches
    cfg = registry.reduced_config(registry.get_config("mixtral-8x22b"))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    assert cfg.sliding_window == 64
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 1, 128                        # prompt 2× the window
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s + 8), 0, cfg.vocab)
    full = np.asarray(registry.forward(
        params, cfg, {"tokens": jnp.pad(toks, ((0, 0), (0, 192 - s - 8)))}))
    _, caches = forward_with_caches(params, cfg, toks[:, :s], 128)
    outs = []
    for i in range(s, s + 8):
        lg, caches = registry.decode_step(params, cfg,
                                          {"tokens": toks[:, i:i + 1]}, caches)
        outs.append(np.asarray(lg)[:, 0])
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, full[:, s:s + 8], rtol=2e-2, atol=2e-4)


def test_long_500k_skip_rules():
    expected_runs = {"mixtral-8x22b", "zamba2-2.7b", "rwkv6-3b"}
    runs = {a for a in ARCHS
            if registry.cell_supported(registry.get_config(a), "long_500k")[0]}
    assert runs == expected_runs
