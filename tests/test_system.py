"""End-to-end behaviour tests for the paper's system: ingest -> profile ->
train quality model -> discover joins, on the paper's own Fig. 1 toy data
plus a synthetic lake."""
import numpy as np

from repro.core import (DiscoveryIndex, GBDTConfig, LakeSpec,
                        ingest_string_columns, generate_lake, profile_lake,
                        select_queries, train_quality_model)
from repro.core.discovery import rank


def test_fig1_toy_end_to_end(small_lake):
    d1 = {"D1.Country": ["Mexico", "Spain", "U.S.", "France"],
          "D1.Happiness": ["6.595", "6.354", "6.892", "6.592"],
          "D1.Schengen": ["N", "Y", "N", "Y"]}
    d2 = {"D2.Country": ["Spain", "Spain", "Germany", "Italy"],
          "D2.Code": ["ESP", "ESP", "GER", "ITA"],
          "D2.Location": ["Barcelona", "Madrid", "Munich", "Rome"],
          "D2.Discount": ["Y", "N", "N", "Y"],
          "D2.Satis": ["7.7", "8.5", "8", "7.7"]}
    d3 = {"D3.X": ["Spain", "U.S.", "Mexico", "Germany"],
          "D3.Y": ["47M", "330M", "123M", "83M"],
          "D3.Z": ["2020", "2020", "2020", "2020"]}
    cols, tids = [], []
    for tid, table in enumerate((d1, d2, d3)):
        for name, values in table.items():
            cols.append((name, values))
            tids.append(tid)
    batch, _ = ingest_string_columns(cols, table_ids=tids)
    profiles = profile_lake(batch)
    model = train_quality_model([small_lake], GBDTConfig(n_trees=30, depth=4),
                                n_query=48)
    index = DiscoveryIndex(profiles=profiles, model=model, names=batch.names,
                           table_ids=np.asarray(tids))
    q = batch.names.index("D1.Country")
    scores, ids = rank(index, np.asarray([q]), k=4)
    top = [batch.names[i] for i, s in zip(ids[0], scores[0]) if np.isfinite(s)]
    # the two country columns must rank in the top 3 (paper Example 1)
    assert "D3.X" in top[:3] and "D2.Country" in top[:3], top


def test_full_pipeline_on_synthetic_lake(small_lake, small_profiles):
    model = train_quality_model([small_lake], GBDTConfig(n_trees=30, depth=4),
                                n_query=64)
    assert model.train_r2 > 0.5
    idx = DiscoveryIndex(profiles=small_profiles, model=model,
                         table_ids=small_lake.table)
    qids = select_queries(small_lake, 10, min_semantic=3)
    scores, ids = rank(idx, qids, k=3)
    valid = np.isfinite(scores)
    sem = small_lake.is_semantic(np.repeat(qids, 3),
                                 ids.reshape(-1)).reshape(len(qids), 3)
    assert (sem & valid).sum() / valid.sum() > 0.55
