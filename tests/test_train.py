"""Training substrate: optimizer math, loss descent, checkpoint/restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.models import registry
from repro.train.loop import StragglerMonitor, train_loop
from repro.train.optimizer import (AdamWConfig, adamw_update, global_norm,
                                   init_opt_state, lr_schedule)
from repro.train.trainer import build_train_step, cross_entropy


def test_adamw_matches_reference():
    """One AdamW step on a scalar matches the closed-form update."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.asarray(2.0)}
    g = {"w": jnp.asarray(0.5)}
    st = init_opt_state(p)
    p2, st2, _ = adamw_update(cfg, p, g, st)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mh, vh = m / 0.1, v / 0.01
    want = 2.0 - cfg.lr * mh / (np.sqrt(vh) + cfg.eps)
    assert np.isclose(float(p2["w"]), want, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_grad_clip_and_schedule():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=10, total_steps=100)
    # linear warmup starts at lr/warmup (not 0 — step 0 must make progress)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == pytest.approx(0.1)
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(cfg.min_lr_frac, rel=1e-2)
    g = {"a": jnp.full((10,), 10.0)}
    assert float(global_norm(g)) == pytest.approx(np.sqrt(1000), rel=1e-5)


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.asarray([[1, 2, -1, -1]])
    loss = cross_entropy(logits, labels)
    assert float(loss) == pytest.approx(np.log(8), rel=1e-5)


def test_loss_decreases_and_resumes(tmp_path):
    cfg = registry.reduced_config(registry.get_config("smollm-360m"))
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=2,
                                                     total_steps=40)))
    pipe = TokenPipeline(vocab=cfg.vocab, seq=64, global_batch=4)
    ckpt = str(tmp_path / "ck")
    logs = []
    params1, opt1, hist = train_loop(step, params, opt, pipe, steps=30,
                                     ckpt_dir=ckpt, ckpt_every=10,
                                     log_every=1, log=logs.append)
    losses = [h[1] for h in hist]
    assert losses[-1] < losses[0] - 0.2, losses
    # restart resumes from the latest checkpoint and continues
    params2, opt2, hist2 = train_loop(step, params, opt, pipe, steps=32,
                                      ckpt_dir=ckpt, ckpt_every=10,
                                      log_every=1, log=logs.append)
    assert any("[resume]" in l for l in logs)
    assert int(opt2["step"]) == 32


def test_grad_accumulation_equivalence():
    """accum=2 must equal a single big-batch step (same mean gradient)."""
    cfg = registry.reduced_config(registry.get_config("smollm-360m"))
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab=cfg.vocab, seq=32, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    s1 = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3), accum=1))
    s2 = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3), accum=2))
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p2, _, m2 = s2(params, init_opt_state(params), batch)
    assert np.isclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_straggler_monitor():
    m = StragglerMonitor(alpha=0.5, threshold=2.0)
    assert not m.observe(1.0)
    assert not m.observe(1.1)
    assert m.observe(5.0)
    assert m.flagged == 1


def test_checkpoint_atomic_keepn(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(5), "b": {"c": np.ones((2, 2))}}
    for s in (1, 2, 3):
        mgr.save(s, tree)
    assert mgr.latest_step() == 3
    files = [f for f in os.listdir(tmp_path) if f.startswith("ckpt_")]
    assert len(files) == 2                    # keep-N gc
    restored, step = mgr.restore(3, tree)
    assert step == 3
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_structure_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": np.arange(3)})
    with pytest.raises(AssertionError):
        mgr.restore(1, {"zzz": np.arange(3)})
