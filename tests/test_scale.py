"""Large-lake scale path: tiered LSH candidate generation, quantized
profile matrices (with the exact fp32 re-rank), lazy memory-mapped
snapshots, bulk single-segment ingest, and the scaled lake generator
with planted joinability tiers."""
import warnings

import numpy as np
import pytest

from repro.core import (GBDTConfig, ScaledLakeSpec, generate_scaled_lake,
                        select_scaled_queries, train_quality_model)
from repro.exec.plan import Planner, PlannerConfig
from repro.service import (CatalogReader, ColumnCatalog, DiscoveryEngine,
                           DiscoveryRequest, EngineConfig, LSHConfig,
                           band_keys, coarse_band_keys, measure_recall)
from repro.service import lsh as lsh_mod
from repro.service.scheduler import (DeadlineExpired, RequestScheduler,
                                     SchedulerConfig)

N_SCALED = 4096


@pytest.fixture(scope="module")
def scaled_lake():
    return generate_scaled_lake(ScaledLakeSpec(n_columns=N_SCALED, seed=5))


@pytest.fixture(scope="module")
def model(small_lake):
    return train_quality_model([small_lake], GBDTConfig(n_trees=30, depth=4),
                               n_query=64)


@pytest.fixture(scope="module")
def scaled_root(scaled_lake, tmp_path_factory):
    root = str(tmp_path_factory.mktemp("scaled_catalog"))
    cat = ColumnCatalog(root, n_perm=128)
    n_tables = int(scaled_lake.table.max()) + 1
    cat.add_batch(scaled_lake.batch, [f"t{i}" for i in range(n_tables)])
    return root


@pytest.fixture(scope="module")
def scaled_snapshot(scaled_root):
    return CatalogReader(scaled_root).snapshot(lazy=True)


# ---------------------------------------------------------------------------
# band keys: remainder fold + coarse digest
# ---------------------------------------------------------------------------

def test_band_keys_remainder_folds_and_warns_once(rng):
    sigs = rng.integers(0, 2**32, (6, 100), dtype=np.uint32)
    lsh_mod._REMAINDER_WARNED.discard((100, 16))
    with pytest.warns(RuntimeWarning, match="folding the 4 trailing"):
        keys = band_keys(sigs, 16)          # r = 6, 96 rows used, 4 trail
    assert keys.shape == (6, 16)
    # the warning is once per geometry
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        band_keys(sigs, 16)
    assert not [x for x in w if issubclass(x.category, RuntimeWarning)]
    # trailing rows are folded into the LAST band, not dropped: perturbing
    # a trailing permutation changes only that band's key
    sigs2 = sigs.copy()
    sigs2[:, 98] ^= np.uint32(0x5A5A5A5A)
    keys2 = band_keys(sigs2, 16)
    np.testing.assert_array_equal(keys[:, :-1], keys2[:, :-1])
    assert (keys[:, -1] != keys2[:, -1]).all()


def test_band_keys_exact_division_unchanged(rng):
    sigs = rng.integers(0, 2**32, (4, 128), dtype=np.uint32)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        keys = band_keys(sigs, 64)
    assert not w
    assert keys.shape == (4, 64)


def test_coarse_band_keys_digest(rng):
    sigs = rng.integers(0, 2**32, (8, 128), dtype=np.uint32)
    ck = coarse_band_keys(sigs, 16)
    assert ck.shape == (8, 16) and ck.dtype == np.uint32
    # identical signatures -> identical digests; a changed sampled row
    # flips exactly one digest lane
    np.testing.assert_array_equal(coarse_band_keys(sigs, 16), ck)
    sigs2 = sigs.copy()
    sigs2[:, 0] ^= np.uint32(1)            # row 0 is the first sampled row
    ck2 = coarse_band_keys(sigs2, 16)
    assert (ck2[:, 0] != ck[:, 0]).all()
    np.testing.assert_array_equal(ck2[:, 1:], ck[:, 1:])
    with pytest.raises(ValueError):
        coarse_band_keys(sigs, 200)


# ---------------------------------------------------------------------------
# scaled lake generator
# ---------------------------------------------------------------------------

def test_scaled_lake_planted_jaccard(scaled_lake):
    lake, spec = scaled_lake, scaled_lake.spec
    assert lake.batch.values32.shape == (N_SCALED, spec.row_budget)
    qids = select_scaled_queries(lake, 9, seed=1)
    for q in qids:
        q = int(q)
        partners = lake.partners(q)
        assert partners.size == spec.group_size - 1
        # partners are strided into distinct tables (join partners in the
        # same table would be excluded by the engine's table mask)
        assert lake.table[q] not in lake.table[partners]
        # realized pairwise Jaccard tracks the planted tier
        want = spec.jaccard_tiers[lake.tier[q]]
        a = set(np.unique(lake.batch.values32[q]).tolist())
        b = set(np.unique(lake.batch.values32[int(partners[0])]).tolist())
        j = len(a & b) / len(a | b)
        assert abs(j - want) < 0.25 * want + 0.05


def test_scaled_lake_noise_disjoint(scaled_lake):
    lake = scaled_lake
    noise = np.flatnonzero(lake.group < 0)[:4]
    planted = np.flatnonzero(lake.group >= 0)[:4]
    for n in noise:
        vn = set(np.unique(lake.batch.values32[n]).tolist())
        for p in planted:
            vp = set(np.unique(lake.batch.values32[p]).tolist())
            assert not (vn & vp)


def test_select_scaled_queries_tier_balanced(scaled_lake):
    qids = select_scaled_queries(scaled_lake, 12, seed=3)
    assert len(set(qids.tolist())) == 12
    tiers = scaled_lake.tier[qids]
    counts = np.bincount(tiers, minlength=3)
    assert (counts >= 3).all()             # 12 queries over 3 tiers


# ---------------------------------------------------------------------------
# bulk ingest + lazy snapshots
# ---------------------------------------------------------------------------

def test_add_batch_single_segment(scaled_lake, scaled_root):
    cat = ColumnCatalog(scaled_root)
    assert len(cat.manifest["segments"]) == 1
    snap = cat.snapshot()
    assert snap.n_columns == N_SCALED
    assert len(cat.tables()) == int(scaled_lake.table.max()) + 1


def test_lazy_snapshot_matches_eager(scaled_root):
    reader = CatalogReader(scaled_root)
    lazy = reader.snapshot(lazy=True)
    eager = reader.snapshot(lazy=False)
    assert lazy.lazy and not eager.lazy
    np.testing.assert_array_equal(np.asarray(lazy.signatures),
                                  eager.signatures)
    np.testing.assert_array_equal(np.asarray(lazy.profiles.numeric),
                                  eager.profiles.numeric)
    # lazy stats come from the segment's float64 moments, eager from a
    # float32 pass over the matrix — close, not bit-equal
    np.testing.assert_allclose(lazy.profiles.mean, eager.profiles.mean,
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(lazy.profiles.std, eager.profiles.std,
                               rtol=1e-3, atol=1e-5)
    assert lazy.table_ids.shape == eager.table_ids.shape


def test_lazy_falls_back_on_multi_segment(tmp_path):
    cat = ColumnCatalog(str(tmp_path), n_perm=64)
    cat.add_table("a", [("x", [f"v{i}" for i in range(40)])])
    cat.add_table("b", [("y", [f"w{i}" for i in range(40)])])
    snap = cat.snapshot(lazy=True)         # two segments -> eager load
    assert not snap.lazy
    assert snap.n_columns == 2


def test_lazy_snapshot_survives_concurrent_compaction(tmp_path):
    root = str(tmp_path)
    cat = ColumnCatalog(root, n_perm=64)
    cat.add_table("a", [("x", [f"v{i}" for i in range(64)]),
                        ("y", [f"w{i % 9}" for i in range(64)])])
    cat.add_table("b", [("z", [f"v{i}" for i in range(32)])])
    cat.compact()                          # single segment -> lazy-eligible
    reader = CatalogReader(root, lazy=True)
    pinned = reader.snapshot()
    assert pinned.lazy
    # writer keeps going: drop + compact retires and DELETES the segment
    # files the pinned snapshot memmaps
    cat.drop_table("b")
    cat.compact()
    # POSIX unlink keeps the open mappings valid: every array is still
    # fully readable through the pinned snapshot
    sigs = np.asarray(pinned.signatures)
    nums = np.asarray(pinned.profiles.numeric)
    assert sigs.shape[0] == 3 and np.isfinite(nums).all()
    assert int(sigs.sum()) != 0
    # a fresh snapshot reflects the compacted state
    fresh = reader.snapshot()
    assert fresh.n_columns == 2


# ---------------------------------------------------------------------------
# quantized profile matrices
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_bounds(rng):
    from repro.kernels.profile_distance import (PROFILE_DTYPES, dequantize,
                                                quantize_profiles)
    z = rng.normal(0, 2.0, (257, 21)).astype(np.float32)
    assert set(PROFILE_DTYPES) >= {"fp32", "int8", "fp16"}
    q32, s32 = quantize_profiles(z, "fp32")
    np.testing.assert_array_equal(np.asarray(dequantize(q32, s32)), z)
    q8, s8 = quantize_profiles(z, "int8")
    assert q8.dtype == np.int8
    step = np.abs(z).max(axis=0) / 127.0
    err8 = np.abs(np.asarray(dequantize(q8, s8)) - z).max(axis=0)
    assert (err8 <= step * 0.5 + 1e-6).all()
    q16, s16 = quantize_profiles(z, "fp16")
    assert q16.dtype == np.float16
    err16 = np.abs(np.asarray(dequantize(q16, s16)) - z)
    assert (err16 <= np.abs(z) * 2e-3 + 1e-6).all()
    with pytest.raises(ValueError):
        quantize_profiles(z, "int4")


def test_quantized_topk_parity(small_lake, model, tmp_path):
    """int8/fp16 resident matrices + exact fp32 re-rank reproduce the
    fp32 engine's top-k (the ISSUE parity gate: overlap >= 0.99)."""
    from repro.core import select_queries
    from repro.service import add_lake
    cat = ColumnCatalog(str(tmp_path), n_perm=128)
    add_lake(cat, small_lake)
    snap = cat.snapshot()
    qids = select_queries(small_lake, 16)
    reqs = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q))
            for q in qids]
    tops = {}
    for dt in ("fp32", "int8", "fp16"):
        eng = DiscoveryEngine(snap, model,
                              EngineConfig(k=10, mode="full",
                                           profile_dtype=dt,
                                           cache_entries=0))
        tops[dt] = [[m.column_id for m in r.matches]
                    for r in eng.query_batch(reqs)]
    for dt in ("int8", "fp16"):
        overlap = np.mean([len(set(a) & set(b)) / max(len(a), 1)
                           for a, b in zip(tops["fp32"], tops[dt])])
        assert overlap >= 0.99, f"{dt} top-k overlap {overlap} vs fp32"


# ---------------------------------------------------------------------------
# tiered candidate generation
# ---------------------------------------------------------------------------

def test_planner_tiered_geometry():
    p = Planner(PlannerConfig())
    # fraction-of-lake sizing with floor / cap / block rounding
    assert p.survivor_budget(1_000_000, 4096) == 2048     # cap
    assert p.survivor_budget(2_000, 400) == 512           # floor
    sb = p.survivor_budget(30_000, 4096)
    assert sb % 32 == 0 and 512 <= sb <= 2048
    assert p.survivor_budget(300, 50) <= 300              # never past lake
    plan = p.plan(n_columns=100_000, n_queries=8, mode="tiered")
    assert plan.candidates == "tiered" and not plan.sharded
    assert plan.survivor_budget == 2048
    # the fine tier never scores wider than the coarse pass gathered
    assert plan.budget <= plan.survivor_budget


def test_tiered_recall_and_events(scaled_snapshot, scaled_lake, model):
    qids = select_scaled_queries(scaled_lake, 12, seed=2)
    engine = DiscoveryEngine(
        scaled_snapshot, model,
        EngineConfig(k=10, mode="tiered", metrics=True,
                     lsh=LSHConfig(n_bands=64, n_coarse_bands=16),
                     candidate_frac=0.2, cache_entries=0))
    rec = measure_recall(engine, qids, k=10)
    assert rec["recall"] >= 0.9
    assert rec["scored_fraction"] < 0.5    # sublinear candidate stage
    assert "tiered" in engine.stats()["last_plan"]["kind"]
    # coarse_pass / fine_probe events folded into the service metrics
    m = engine.metrics.collect()
    assert m["coarse_passes_total"]["values"][""] >= 1
    assert m["fine_probes_total"]["values"][""] >= 1
    hist = m["coarse_survivor_fraction"]["values"]
    assert hist["count"] >= 1
    assert hist["sum"] / hist["count"] <= 0.5


def test_tiered_quantized_matches_tiered_fp32(scaled_snapshot, scaled_lake,
                                              model):
    qids = select_scaled_queries(scaled_lake, 8, seed=4)
    reqs = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q))
            for q in qids]
    tops = {}
    for dt in ("fp32", "int8"):
        eng = DiscoveryEngine(
            scaled_snapshot, model,
            EngineConfig(k=10, mode="tiered", profile_dtype=dt,
                         lsh=LSHConfig(n_bands=64, n_coarse_bands=16),
                         candidate_frac=0.2, cache_entries=0))
        tops[dt] = [[m.column_id for m in r.matches]
                    for r in eng.query_batch(reqs)]
    overlap = np.mean([len(set(a) & set(b)) / max(len(a), 1)
                       for a, b in zip(tops["fp32"], tops["int8"])])
    assert overlap >= 0.9


# ---------------------------------------------------------------------------
# deadline-aware batch shrink
# ---------------------------------------------------------------------------

def test_scheduler_shrinks_window_to_deadline(scaled_snapshot, model):
    engine = DiscoveryEngine(scaled_snapshot, model,
                             EngineConfig(k=5, cache_entries=0))
    sched = RequestScheduler(engine,
                             SchedulerConfig(max_wait_ms=5_000.0,
                                             max_batch=8))
    try:
        fut = sched.submit(DiscoveryRequest(name="hurry", column_id=0),
                           deadline_ms=80.0)
        # the 5 s coalescing window must be cut to the ~80 ms deadline:
        # the future resolves (either served in time or expired) long
        # before the full window elapses
        try:
            fut.result(timeout=3.0)
        except DeadlineExpired:
            pass
        stats = sched.stats()
        assert stats["window_shrunk"] >= 1
        assert stats["batches"] + stats["expired"] >= 1
    finally:
        sched.close()
