import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))   # _fixtures imports

try:  # the container has no hypothesis; fall back to the deterministic shim
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_shims"))

import numpy as np
import pytest

from _fixtures import FakeClock, fake_clock, seeded_rng  # noqa: F401
from repro.core import LakeSpec, generate_lake, profile_lake


@pytest.fixture(scope="session")
def small_lake():
    # row budget large enough that observed cardinalities track vocabulary
    # sizes (K needs discriminative cardinalities — see DESIGN.md §5.4)
    return generate_lake(LakeSpec(n_domains=10, n_tables=24, row_budget=2048,
                                  rows_log_mean=6.8, coverage_range=(0.5, 1.0),
                                  gran_ratio=(4, 8), seed=7))


@pytest.fixture(scope="session")
def small_profiles(small_lake):
    return profile_lake(small_lake.batch)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
