"""Distributed paths on a small host-device mesh (subprocess: jax device
count must be set before first init)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_rank_sharded_matches_local():
    out = _run("""
        import numpy as np, jax
        from repro.core import (DiscoveryIndex, GBDTConfig, LakeSpec,
                                generate_lake, profile_lake, rank,
                                rank_sharded, train_quality_model,
                                select_queries)
        lake = generate_lake(LakeSpec(n_domains=8, n_tables=16,
                                      row_budget=256, rows_log_mean=5.0,
                                      seed=11))
        prof = profile_lake(lake.batch)
        model = train_quality_model([lake], GBDTConfig(n_trees=10, depth=3),
                                    n_query=32)
        idx = DiscoveryIndex(profiles=prof, model=model, table_ids=lake.table)
        qids = select_queries(lake, 6)
        s1, i1 = rank(idx, qids, k=5, exclude_same_table=False)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        s2, i2 = rank_sharded(idx, qids, mesh, k=5, shard_axes=("data",))
        # same top-k scores (ids can permute on ties)
        np.testing.assert_allclose(np.sort(s1, 1), np.sort(s2, 1),
                                   rtol=1e-4, atol=1e-5)
        overlap = np.mean([len(set(a) & set(b)) / 5.0
                           for a, b in zip(i1, i2)])
        assert overlap > 0.9, (overlap, i1[:2], i2[:2])
        print("OK rank_sharded")
    """)
    assert "OK rank_sharded" in out


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import registry
        from repro.dist import sharding as shd
        from repro.train.optimizer import AdamWConfig, init_opt_state
        from repro.train.trainer import build_train_step
        from repro.data.pipeline import TokenPipeline

        cfg = registry.reduced_config(registry.get_config("smollm-360m"))
        params, specs = registry.init_params(cfg, jax.random.PRNGKey(0))
        pipe = TokenPipeline(vocab=cfg.vocab, seq=64, global_batch=8)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}

        s0 = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3)))
        p0, _, m0 = s0(params, init_opt_state(params), batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pshard = shd.param_shardings(specs, mesh)
        pp = jax.tree.map(jax.device_put, params, pshard)
        msn = shd.zero1_shardings(specs, params, mesh)
        mspecs = jax.tree.map(lambda ns: ns.spec, msn)
        bshard = NamedSharding(mesh, P(("data",)))
        bb = {k: jax.device_put(v, bshard) for k, v in batch.items()}
        s1 = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3), mesh=mesh,
                                      moment_specs=mspecs))
        p1, _, m1 = s1(pp, init_opt_state(pp), bb)
        assert np.isclose(float(m0["loss"]), float(m1["loss"]), rtol=1e-3)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p0, p1)
        assert max(jax.tree.leaves(d)) < 5e-3, max(jax.tree.leaves(d))
        print("OK sharded train")
    """)
    assert "OK sharded train" in out


def test_moe_shard_map_matches_local():
    out = _run("""
        import dataclasses
        import numpy as np, jax
        import jax.numpy as jnp
        from repro.models import registry
        cfg = registry.reduced_config(registry.get_config("phi3.5-moe-42b-a6.6b"))
        # generous capacity so no tokens drop (drops differ between the
        # local and EP dispatch granularities and are not comparable)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        assert cfg.moe_sharding == "ep"
        params, specs = registry.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
        lg0 = registry.forward(params, cfg, {"tokens": toks})
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.dist import sharding as shd
        pshard = shd.param_shardings(specs, mesh)
        pp = jax.tree.map(jax.device_put, params, pshard)
        lg1 = registry.forward(pp, cfg, {"tokens": toks}, mesh=mesh)
        err = float(jnp.max(jnp.abs(lg0 - lg1)))
        scale = float(jnp.max(jnp.abs(lg0)))
        assert err / scale < 2e-2, (err, scale)
        print("OK moe ep")
    """)
    assert "OK moe ep" in out


def test_dryrun_cell_small():
    """The real dry-run driver (512 placeholder devices) on a fast cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-base",
         "--shape", "decode_32k", "--mesh", "single", "--tag", "_test",
         "--out-dir", "/tmp/repro_dryrun_test"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    assert " ok " in r.stdout, r.stdout
