"""Shared deterministic test fixtures: fake clock + seeded randomness.

The flakiest tests in this suite were the ones that raced wall time —
``time.sleep(0.05)`` hoping a 5 ms deadline lapsed, negative lease TTLs
standing in for expiry.  Both runtimes take injectable clocks
(``SchedulerConfig.clock``, ``WriterLease(clock=)``,
``FleetConfig.clock``), so tests advance a :class:`FakeClock` instead of
sleeping: deterministic on any host, zero wall-clock wait.

Imported by ``conftest.py`` so ``fake_clock`` / ``seeded_rng`` are plain
fixture arguments everywhere; ``FakeClock`` itself is importable for
tests that need several independently-ticking clocks.
"""
import threading
import zlib

import numpy as np
import pytest


class FakeClock:
    """A callable, manually-advanced clock.

    Drop-in for ``time.perf_counter`` / ``time.time`` style sources:
    calling it returns the current fake seconds; :meth:`advance` moves
    it forward (thread-safe — worker threads read while the test
    advances).  It never moves on its own, so pair it with components
    configured not to *wait on it* (``max_wait_ms=0`` for the
    scheduler's coalescing window, which derives timeouts from the
    injected clock).
    """

    def __init__(self, start: float = 1_000.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            return self._t

    def advance(self, seconds: float) -> float:
        """Move time forward; returns the new reading."""
        if seconds < 0:
            raise ValueError("a monotonic clock cannot go backwards")
        with self._lock:
            self._t += float(seconds)
            return self._t


@pytest.fixture()
def fake_clock() -> FakeClock:
    return FakeClock()


@pytest.fixture()
def seeded_rng(request) -> np.random.Generator:
    """Per-test deterministic generator: seeded from the test's nodeid,
    so every test gets a distinct but reproducible stream (no cross-test
    coupling through a shared session rng)."""
    seed = zlib.crc32(request.node.nodeid.encode()) & 0xFFFFFFFF
    return np.random.default_rng(seed)
