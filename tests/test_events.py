"""Observability layer: the bounded multi-consumer event bus, the
Prometheus-style metrics registry + exposition endpoint, and the
per-request phase traces threaded scheduler -> engine -> executor."""
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.service import (ColumnCatalog, DiscoveryEngine, DiscoveryRequest,
                           EngineConfig, EventBus, MetricsServer,
                           RequestScheduler, SchedulerConfig, ServiceMetrics,
                           mint_trace_id, parse_exposition)
from repro.service import events as EV
from repro.service.metrics import (BATCH_SIZE_BUCKETS, Histogram,
                                   MetricsRegistry)


def _tiny_model():
    from repro.core.gbdt import GBDTParams
    from repro.core.predictor import JoinQualityModel
    p = GBDTParams(feats=np.zeros((1, 1), np.int32),
                   thrs=np.zeros((1, 1), np.float32),
                   leaves=np.zeros((1, 2), np.float32), base=0.0)
    return JoinQualityModel(gbdt=p)


@pytest.fixture(scope="module")
def snapshot(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("events_catalog"))
    cat = ColumnCatalog(root, n_perm=64)
    for t in range(4):
        cat.add_table(f"t{t}",
                      [(f"c{t}a", [f"v{t}_{i}" for i in range(60)]),
                       (f"c{t}b", [f"w{i % 11}" for i in range(40)])])
    return cat.snapshot()


def _engine(snapshot, **kw):
    kw.setdefault("metrics", True)
    return DiscoveryEngine(snapshot, _tiny_model(),
                           EngineConfig(k=3, mode="full", cache_entries=0,
                                        **kw))


# -- event bus ---------------------------------------------------------------

class TestEventBus:
    def test_cursors_advance_independently(self):
        bus = EventBus(capacity=64)
        a, b = bus.subscribe("a"), bus.subscribe("b")
        for i in range(5):
            bus.publish("x", i=i)
        got_a = a.poll()
        assert [e.payload["i"] for e in got_a] == [0, 1, 2, 3, 4]
        for i in range(5, 8):
            bus.publish("x", i=i)
        # b sees the whole stream even though a already consumed a prefix
        assert [e.payload["i"] for e in b.poll()] == list(range(8))
        assert [e.payload["i"] for e in a.poll()] == [5, 6, 7]
        assert a.dropped == b.dropped == 0
        # seqs are dense and shared across consumers
        assert [e.seq for e in got_a] == [0, 1, 2, 3, 4]

    def test_subscribe_positions_at_tail(self):
        bus = EventBus(capacity=8)
        bus.publish("early")
        cur = bus.subscribe("late")
        assert cur.poll() == []
        bus.publish("after")
        assert [e.type for e in cur.poll()] == ["after"]

    def test_overflow_drop_accounting_slow_consumer(self):
        bus = EventBus(capacity=8)
        slow = bus.subscribe("slow")
        for i in range(20):
            bus.publish("x", i=i)
        got = slow.poll()
        # the ring holds the newest 8; the 12 overwritten are counted
        assert [e.payload["i"] for e in got] == list(range(12, 20))
        assert slow.dropped == 12
        assert slow.delivered == 8
        st = bus.stats()
        assert st["published"] == 20
        assert st["consumers"]["slow"] == {"delivered": 8, "dropped": 12,
                                           "lag": 0}

    def test_publish_nonblocking_without_consumers(self):
        # 10k publishes with no consumer must complete quickly (drop-oldest,
        # never wait); generous wall bound so CI noise can't flake it
        bus = EventBus(capacity=16)
        err = []

        def worker():
            try:
                for i in range(10_000):
                    bus.publish("spin", i=i)
            except BaseException as e:      # pragma: no cover
                err.append(e)

        th = threading.Thread(target=worker)
        t0 = time.perf_counter()
        th.start()
        th.join(timeout=10)
        assert not th.is_alive() and not err
        assert time.perf_counter() - t0 < 10
        assert bus.stats()["published"] == 10_000

    def test_max_events_poll_chunking(self):
        bus = EventBus(capacity=64)
        cur = bus.subscribe()
        for i in range(10):
            bus.publish("x", i=i)
        assert len(cur.poll(max_events=4)) == 4
        assert len(cur.poll(max_events=4)) == 4
        assert len(cur.poll()) == 2

    def test_mint_trace_id_unique(self):
        ids = {mint_trace_id() for _ in range(1000)}
        assert len(ids) == 1000


# -- metrics registry --------------------------------------------------------

class TestMetrics:
    def test_histogram_bucket_boundaries(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat_ms", buckets=(1.0, 10.0, 100.0))
        for v in (0.5, 1.0, 1.5, 10.0, 99.9, 100.0, 1e6):
            h.observe(v)
        got = h._collect()["buckets"]
        # le is INCLUSIVE (Prometheus contract): 1.0 lands in le="1"
        assert got == {"1": 2, "10": 4, "100": 6, "+Inf": 7}
        assert h._collect()["count"] == 7

    def test_exposition_golden(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs_total", "requests")
        g = reg.gauge("depth")
        h = reg.histogram("ms", buckets=(1.0, 5.0))
        c.inc(3)
        c.inc(2, consumer="metrics")
        g.set(7)
        h.observe(0.5)
        h.observe(4.0)
        h.observe(9.0)
        assert reg.render() == (
            "# TYPE depth gauge\n"
            "depth 7\n"
            "# TYPE ms histogram\n"
            'ms_bucket{le="1"} 1\n'
            'ms_bucket{le="5"} 2\n'
            'ms_bucket{le="+Inf"} 3\n'
            "ms_sum 13.5\n"
            "ms_count 3\n"
            "# HELP reqs_total requests\n"
            "# TYPE reqs_total counter\n"
            "reqs_total 3\n"
            'reqs_total{consumer="metrics"} 2\n')

    def test_parse_exposition_roundtrip(self):
        reg = MetricsRegistry()
        reg.counter("a_total").inc(5)
        reg.gauge("b").set(2.5, shard="x")
        reg.histogram("h_ms", buckets=(10.0,)).observe(3)
        parsed = parse_exposition(reg.render())
        assert parsed["a_total"][""] == 5
        assert parsed["b"]['{shard="x"}'] == 2.5
        assert parsed["h_ms_bucket"]['{le="10"}'] == 1
        assert parsed["h_ms_bucket"]['{le="+Inf"}'] == 1
        assert parsed["h_ms_count"][""] == 1

    def test_registration_idempotent_and_type_checked(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")

    def test_service_metrics_event_folding(self):
        bus = EventBus(capacity=256)
        m = ServiceMetrics(bus)
        bus.publish(EV.REQUEST_ADMITTED, trace_id="t1")
        bus.publish(EV.REQUEST_SHED, name="q")
        bus.publish(EV.BATCH_FORMED, n=4, trace_ids=list("abcd"))
        bus.publish(EV.CACHE_HIT, n=3)
        bus.publish(EV.CACHE_MISS, n=1)
        bus.publish(EV.COMPILE_END, ms=12.5)
        bus.publish(EV.MANIFEST_ADVANCED, version=9)
        assert m.drain() == 7
        assert m.requests_admitted.value() == 1
        assert m.requests_shed.value() == 1
        assert m.batches_formed.value() == 1
        assert m.cache_hits.value() == 3
        assert m.cache_misses.value() == 1
        assert m.compiles.value() == 1
        assert m.manifest_version.value() == 9
        # batch_size histogram saw n=4 (bucket le=4)
        assert m.batch_size._collect()["buckets"][
            str(BATCH_SIZE_BUCKETS[2])] == 1

    def test_http_endpoint(self):
        reg = MetricsRegistry()
        reg.counter("up_total").inc()
        with MetricsServer(reg) as srv:
            assert srv.port > 0
            body = urllib.request.urlopen(srv.url, timeout=10).read()
            assert parse_exposition(body.decode())["up_total"][""] == 1
            # non-metrics paths 404 instead of leaking anything
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/other", timeout=10)


# -- end-to-end tracing ------------------------------------------------------

class TestTracing:
    def test_direct_query_trace_spans_sum_to_compute(self, snapshot):
        eng = _engine(snapshot, metrics=False)   # traces need no bus
        r = eng.query(DiscoveryRequest(name="q", column_id=0))
        assert r.trace_id is not None
        phases = [s["phase"] for s in r.trace]
        assert phases == ["pin", "resolve", "plan", "candidates",
                          "execute", "finalize"]
        assert abs(sum(s["ms"] for s in r.trace)
                   - r.latency_ms) < 1e-6
        assert r.latency_ms == r.compute_ms      # no scheduler: queue 0

    def test_caller_seeded_trace_id(self, snapshot):
        eng = _engine(snapshot, metrics=False)
        r = eng.query(DiscoveryRequest(name="q", column_id=0,
                                       trace_id="mine-001"))
        assert r.trace_id == "mine-001"

    def test_scheduler_roundtrip_admitted_to_batch_chain(self, snapshot):
        eng = _engine(snapshot)
        tail = eng.events.subscribe("test-tail")
        with RequestScheduler(eng, SchedulerConfig(max_wait_ms=1.0)) as s:
            futs = [s.submit(DiscoveryRequest(name=f"q{i}",
                                              column_id=i % 8))
                    for i in range(6)]
            rs = [f.result(timeout=60) for f in futs]
        evs = tail.poll()
        admitted = [e for e in evs if e.type == EV.REQUEST_ADMITTED]
        formed = [e for e in evs if e.type == EV.BATCH_FORMED]
        assert len(admitted) == 6
        # every admitted trace id appears in exactly one formed batch
        batched = [tid for e in formed for tid in e.payload["trace_ids"]]
        assert sorted(batched) == sorted(e.payload["trace_id"]
                                         for e in admitted)
        assert len(batched) == len(set(batched)) == 6
        # ... and on exactly one response, whose spans partition latency
        assert sorted(r.trace_id for r in rs) == sorted(batched)
        for r in rs:
            assert [s_["phase"] for s_ in r.trace[:2]] == ["profile",
                                                           "queue"]
            assert abs(sum(s_["ms"] for s_ in r.trace)
                       - r.latency_ms) < 1e-6
            assert r.trace[1]["ms"] >= 0      # queue = queue_ms - profile

    def test_scheduler_feeds_metrics_registry(self, snapshot):
        eng = _engine(snapshot)
        with RequestScheduler(eng, SchedulerConfig(max_wait_ms=0.5)) as s:
            futs = [s.submit(DiscoveryRequest(name=f"q{i}", column_id=i))
                    for i in range(4)]
            [f.result(timeout=60) for f in futs]
            text = eng.metrics.render()
        parsed = parse_exposition(text)
        assert parsed["requests_admitted_total"][""] == 4
        assert parsed["requests_completed_total"][""] == 4
        assert parsed["request_latency_ms_count"][""] == 4
        assert parsed["batches_formed_total"][""] >= 1
        # the dedicated metrics consumer kept up: zero drops
        assert all(v == 0 for v in
                   parsed["event_bus_dropped_total"].values())

    def test_compile_events_first_contact_only(self, snapshot):
        eng = _engine(snapshot)
        tail = eng.events.subscribe("compiles")
        reqs = [DiscoveryRequest(name="a", column_id=0)]
        r0 = eng.query_batch(reqs)[0]
        first = [e.type for e in tail.poll()]
        assert first.count(EV.COMPILE_BEGIN) == 1
        assert first.count(EV.COMPILE_END) == 1
        # first contact annotates the execute span with the compile wall
        ex = [s for s in r0.trace if s["phase"] == "execute"]
        assert ex and ex[0]["compile_ms"] > 0
        eng.query_batch(reqs)                    # same shape: silent
        again = [e.type for e in tail.poll()]
        assert EV.COMPILE_BEGIN not in again
        assert EV.COMPILE_END not in again
        # the first response's execute span carried the compile wall
        r = eng.query_batch(reqs)[0]
        assert all("compile_ms" not in s for s in r.trace)

    def test_snapshot_lifecycle_events(self, snapshot):
        eng = _engine(snapshot)
        tail = eng.events.subscribe("mvcc")
        eng.query(DiscoveryRequest(name="q", column_id=0))
        types = [e.type for e in tail.poll()]
        assert EV.SNAPSHOT_PINNED in types
        eng.refresh(snapshot)                    # retires the old version
        types = [e.type for e in tail.poll()]
        assert EV.SNAPSHOT_RETIRED in types


# -- catalog / compactor events ---------------------------------------------

class TestCatalogEvents:
    def test_store_publish_and_follower_poll_events(self, tmp_path):
        from repro.service import CatalogReader, CatalogStore
        bus = EventBus(capacity=256)
        store = CatalogStore(str(tmp_path), n_perm=32, events=bus)
        cur = bus.subscribe("chain")
        store.add_table("t0", [("c", [f"v{i}" for i in range(40)])])
        advanced = [e for e in cur.poll()
                    if e.type == EV.MANIFEST_ADVANCED]
        assert advanced and not advanced[-1].payload["follower"]
        assert advanced[-1].payload["version"] == store.version

        rbus = EventBus(capacity=64)
        reader = CatalogReader(str(tmp_path), events=rbus)
        rcur = rbus.subscribe("follower")
        store.add_table("t1", [("d", [f"w{i}" for i in range(40)])])
        assert reader.poll() == [store.version]
        seen = [e for e in rcur.poll() if e.type == EV.MANIFEST_ADVANCED]
        assert [e.payload["version"] for e in seen] == [store.version]
        assert all(e.payload["follower"] for e in seen)

    def test_compactor_lifecycle_events(self, tmp_path):
        from repro.service import BackgroundCompactor, CatalogStore
        bus = EventBus(capacity=256)
        store = CatalogStore(str(tmp_path), n_perm=32, events=bus)
        for t in range(3):
            store.add_table(f"t{t}", [("c", [f"v{t}_{i}"
                                             for i in range(30)])])
        cur = bus.subscribe("compaction")
        with BackgroundCompactor(store) as comp:  # inherits store.events
            comp.submit().result(timeout=60)
        types = [e.type for e in cur.poll()]
        assert types.index(EV.COMPACTION_STARTED) < \
            types.index(EV.COMPACTION_PUBLISHED)


# -- loadgen / stats consistency --------------------------------------------

class TestLoadgenAndStats:
    def test_open_loop_retains_completions(self, snapshot):
        from repro.service.loadgen import run_open_loop
        eng = _engine(snapshot)
        pool = [DiscoveryRequest(name=f"p{i}", column_id=i % 8)
                for i in range(8)]
        r = run_open_loop(eng, pool, offered_qps=200.0, duration_s=0.1,
                          deadline_ms=10_000.0, max_arrivals=24)
        assert len(r["completions"]) == r["n_offered"] - r["expired"]
        done_ts = [c["t_done_s"] for c in r["completions"]]
        assert done_ts == sorted(done_ts)        # drained in finish order
        assert r["latency_hist"]["+Inf"] == len(r["completions"])
        assert r["max_trace_sum_err_ms"] is not None
        assert r["max_trace_sum_err_ms"] <= 1.0
        assert {"profile", "queue", "execute"} <= set(r["trace_phases"])

    def test_stats_snapshot_consistent_under_load(self, snapshot):
        # hits+misses must always equal queries — the torn-snapshot bug
        # stats() had before it took the counter locks
        eng = _engine(snapshot, metrics=False)
        stop = threading.Event()
        errs = []

        def serve():
            i = 0
            while not stop.is_set():
                eng.query(DiscoveryRequest(name=f"s{i}", column_id=i % 8))
                i += 1

        def watch():
            while not stop.is_set():
                s = eng.stats()
                if s["cache"]["hits"] + s["cache"]["misses"] \
                        != s["queries"]:
                    errs.append(s)
                    return

        ths = [threading.Thread(target=serve) for _ in range(2)] + \
              [threading.Thread(target=watch)]
        for t in ths:
            t.start()
        time.sleep(0.8)
        stop.set()
        for t in ths:
            t.join(timeout=30)
        assert not errs, f"torn stats snapshot: {errs[0]}"
