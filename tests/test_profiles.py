"""Profile computation: oracles + invariances (Table II features)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import features as FT
from repro.core.ingest import pack_columns
from repro.core.profiles import compute_profiles_batch, profile_lake


def _profile_of(values, char_len=None, word_cnt=None):
    h64 = np.asarray(values, np.uint64)
    cl = np.asarray(char_len if char_len is not None else np.ones_like(h64), np.float32)
    wc = np.asarray(word_cnt if word_cnt is not None else np.ones_like(h64), np.float32)
    batch, _ = pack_columns(["c"], [h64], [cl], [wc], row_budget=max(len(h64), 4))
    num, words = compute_profiles_batch(
        jnp.asarray(batch.values32), jnp.asarray(batch.char_len),
        jnp.asarray(batch.word_cnt), jnp.asarray(batch.n_rows))
    return np.asarray(num)[0], np.asarray(words)[0]


@given(st.lists(st.integers(1, 50), min_size=2, max_size=200))
@settings(max_examples=60, deadline=None)
def test_cardinality_uniqueness_entropy(vals):
    num, _ = _profile_of(vals)
    uniq, counts = np.unique(vals, return_counts=True)
    # count features are stored log1p-transformed (DESIGN.md §5.7)
    assert np.isclose(num[FT.CARDINALITY], np.log1p(len(uniq)), atol=1e-5)
    assert np.isclose(num[FT.UNIQUENESS], len(uniq) / len(vals), atol=1e-5)
    p = counts / counts.sum()
    assert np.isclose(num[FT.ENTROPY], -(p * np.log(p)).sum(), atol=1e-4)
    assert np.isclose(num[FT.MIN_FREQ], np.log1p(counts.min()), atol=1e-5)
    assert np.isclose(num[FT.MAX_FREQ], np.log1p(counts.max()), atol=1e-5)
    assert np.isclose(num[FT.MAX_PERC_FREQ], counts.max() / len(vals), atol=1e-5)


@given(st.lists(st.integers(1, 30), min_size=2, max_size=100), st.randoms())
@settings(max_examples=40, deadline=None)
def test_row_permutation_invariance(vals, rnd):
    p1, w1 = _profile_of(vals)
    shuffled = list(vals)
    rnd.shuffle(shuffled)
    p2, w2 = _profile_of(shuffled)
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-5)
    assert set(w1.tolist()) == set(w2.tolist())


def test_string_stats():
    vals = [1, 2, 3, 4]
    cl = [3, 5, 7, 9]
    wc = [1, 2, 2, 3]
    num, _ = _profile_of(vals, cl, wc)
    assert num[FT.LONGEST_STR] == 9 and num[FT.SHORTEST_STR] == 3
    assert np.isclose(num[FT.AVG_STR], 6.0)
    assert num[FT.MIN_WORDS] == 1 and num[FT.MAX_WORDS] == 3
    assert np.isclose(num[FT.AVG_WORDS], 2.0)


def test_frequent_words_top10():
    # value 7 appears 5x, 9 appears 3x -> both must be among top-10 hashes
    vals = [7] * 5 + [9] * 3 + list(range(100, 108))
    _, words = _profile_of(vals)
    from repro.core.ingest import fold32
    h7 = fold32(np.asarray([7], np.uint64))[0]
    h9 = fold32(np.asarray([9], np.uint64))[0]
    top = set(words[:FT.N_FREQ_WORDS].tolist())
    assert int(h7) in top and int(h9) in top


def test_empty_and_padded_columns():
    batch, _ = pack_columns(["a", "b"],
                            [np.asarray([1, 2, 3], np.uint64),
                             np.asarray([], np.uint64)],
                            [np.asarray([1, 1, 1], np.float32), np.zeros(0, np.float32)],
                            [np.asarray([1, 1, 1], np.float32), np.zeros(0, np.float32)],
                            row_budget=8)
    num, words = compute_profiles_batch(
        jnp.asarray(batch.values32), jnp.asarray(batch.char_len),
        jnp.asarray(batch.word_cnt), jnp.asarray(batch.n_rows))
    num = np.asarray(num)
    assert np.isfinite(num).all()
    assert num[1].sum() == 0.0                      # empty column -> zeros
    assert np.isclose(num[0][FT.CARDINALITY], np.log1p(3), atol=1e-5)


def test_lake_profiles_zscore(small_lake, small_profiles):
    z = small_profiles.zscored
    assert np.isfinite(z).all()
    assert np.abs(z.mean(axis=0)).max() < 1e-3
    sd = z.std(axis=0)
    assert ((np.abs(sd - 1) < 1e-2) | (sd < 1e-6)).all()
