"""Live ingest & delta-proportional incremental refresh.

Covers the four load-bearing contracts of the incremental path:

* **index deltas** — ``LSHIndex.extend`` / ``retract`` are byte-identical
  to a fresh build over the same rows (including the ``n_perm % n_bands``
  remainder fold and drop-then-extend sequences);
* **placement reuse** — repeated incremental refreshes never leak device
  placements; retired shards are freed when their refcount hits zero;
* **coalesced follower refresh** — a burst of manifest advances folds
  into one refresh (counted in ``refreshes_coalesced``), the delta path
  recompiles nothing, and recall survives the frozen-stats z-scoring;
* **rolling fleet refresh** — replicas advance one at a time while the
  fleet keeps serving; zero dropped or failed queries during the roll.

Score parity with a full rebuild is intentionally NOT asserted: a
rebuild recomputes normalization stats while the delta path freezes the
predecessor's, so scores shift even though the ranked neighborhoods
agree.  The contracts are top-k ID overlap and ``measure_recall``.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (GBDTConfig, LakeSpec, generate_lake, select_queries,
                        train_quality_model)
from repro.exec.executor import live_placement_bundles
from repro.service import (ColumnCatalog, DiscoveryEngine, DiscoveryRequest,
                           EngineConfig, EngineFleet, EventBus, FleetConfig,
                           LSHConfig, LSHIndex, add_lake, measure_recall)
from repro.service.catalog import CatalogReader, manifest_delta
from repro.service.metrics import ServiceMetrics


@pytest.fixture(scope="module")
def lake_and_model():
    lake = generate_lake(LakeSpec(n_domains=10, n_tables=24, row_budget=2048,
                                  rows_log_mean=6.8, coverage_range=(0.5, 1.0),
                                  gran_ratio=(4, 8), seed=7))
    model = train_quality_model([lake], GBDTConfig(n_trees=30, depth=4),
                                n_query=64)
    return lake, model


def _new_catalog(tmp_path, lake, n_perm=128):
    root = str(tmp_path)
    cat = ColumnCatalog(root, n_perm=n_perm)
    add_lake(cat, lake)
    return root, cat


def _follower(root, model, **cfg_kw):
    reader = CatalogReader(root)
    cfg_kw.setdefault("column_buckets", (128, 256, 512, 1024))
    # disable the background next-bucket prewarm: its off-thread
    # placement would race the bundle-count assertions below
    cfg_kw.setdefault("prewarm_fraction", 2.0)
    eng = DiscoveryEngine(reader.snapshot(), model,
                          EngineConfig(k=10, mode="lsh",
                                       lsh=LSHConfig(n_bands=64),
                                       cache_entries=0, incremental=True,
                                       **cfg_kw),
                          events=cfg_kw.get("events"))
    eng.follow(reader, auto=False)
    return eng, reader


def _str_table(cat, name, seed, n_cols=3, n_rows=240):
    rng = np.random.default_rng(seed)
    cols = [(f"{name}_c{j}",
             [f"tok{rng.integers(0, 70)}" for _ in range(n_rows)])
            for j in range(n_cols)]
    cat.add_table(name, cols)


# ---------------------------------------------------------------------------
# satellite 1: index-delta byte parity
# ---------------------------------------------------------------------------

def _rand_sigs(n_cols, n_perm, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2 ** 32, size=(n_cols, n_perm),
                        dtype=np.uint64).astype(np.uint32)


@pytest.mark.parametrize("n_perm,n_bands", [(128, 64), (96, 7)])
def test_lsh_extend_matches_fresh_build(n_perm, n_bands):
    """extend() is byte-identical to a fresh build — including when
    ``n_perm % n_bands != 0`` exercises the remainder fold."""
    cfg = LSHConfig(n_bands=n_bands, n_coarse_bands=4)
    a = _rand_sigs(37, n_perm, seed=1)
    b = _rand_sigs(11, n_perm, seed=2)
    fresh = LSHIndex.build(np.concatenate([a, b]), cfg)
    delta = LSHIndex.build(a, cfg).extend(b)
    np.testing.assert_array_equal(delta.keys, fresh.keys)
    np.testing.assert_array_equal(delta.coarse, fresh.coarse)
    # zero-row extend is the identity
    assert LSHIndex.build(a, cfg).extend(b[:0]).keys.shape == (37, n_bands)


def test_lsh_retract_then_extend_matches_fresh_build():
    cfg = LSHConfig(n_bands=16, n_coarse_bands=2)
    a = _rand_sigs(29, 64, seed=3)
    c = _rand_sigs(9, 64, seed=4)
    keep = np.ones(29, bool)
    keep[[2, 7, 21]] = False
    fresh = LSHIndex.build(np.concatenate([a[keep], c]), cfg)
    delta = LSHIndex.build(a, cfg).retract(keep).extend(c)
    np.testing.assert_array_equal(delta.keys, fresh.keys)
    np.testing.assert_array_equal(delta.coarse, fresh.coarse)
    with pytest.raises(ValueError):
        LSHIndex.build(a, cfg).retract(keep[:5])


def test_manifest_delta_prefix_rule():
    old = {"n_perm": 64, "minhash_seed": 1, "dropped_ids": [],
           "segments": ["s0", "s1"]}
    new = {"n_perm": 64, "minhash_seed": 1, "dropped_ids": [],
           "segments": ["s0", "s1", "s2"]}
    assert manifest_delta(old, new) == ["s2"]
    assert manifest_delta(old, old) == []
    # a drop rewrites history: no delta
    dropped = dict(new, dropped_ids=[3])
    assert manifest_delta(old, dropped) is None
    # segment rewrite (compaction) breaks the prefix: no delta
    assert manifest_delta(old, dict(new, segments=["sX", "s1", "s2"])) is None
    assert manifest_delta(None, new) is None


# ---------------------------------------------------------------------------
# tentpole + satellites 3/4: coalesced incremental refresh on a follower
# ---------------------------------------------------------------------------

def test_incremental_refresh_coalesces_and_preserves_recall(
        lake_and_model, tmp_path):
    lake, model = lake_and_model
    root, cat = _new_catalog(tmp_path, lake)

    bus = EventBus()
    metrics = ServiceMetrics(bus)
    reader = CatalogReader(root)
    eng = DiscoveryEngine(reader.snapshot(), model,
                          EngineConfig(k=10, mode="lsh",
                                       lsh=LSHConfig(n_bands=64),
                                       cache_entries=0, incremental=True,
                                       column_buckets=(128, 256, 512, 1024),
                                       prewarm_fraction=2.0),
                          events=bus)
    eng.follow(reader, auto=False)
    c0 = eng.snapshot.n_columns

    # a burst of three manifest advances must fold into ONE refresh
    for i in range(3):
        _str_table(cat, f"burst{i}", seed=50 + i)
    eng._maybe_follow(force=True)

    rs = eng.stats()["refresh"]
    assert rs["incremental"] == 1 and rs["full"] == 1   # 1 = initial build
    assert rs["coalesced"] == 2
    assert rs["recompiles_total"] == 0
    assert rs["last_delta_columns"] == eng.snapshot.n_columns - c0
    assert rs["bytes_uploaded_total"] > 0
    assert rs["column_bucket"] in (128, 256, 512, 1024)
    assert 0.0 <= rs["stats_drift"] < 10.0

    # refresh events fold into the metrics registry (satellite 4)
    metrics.drain()
    assert metrics.refreshes_incremental.value() == 1
    assert metrics.refreshes_coalesced.value() == 2
    assert metrics.refresh_recompiles.value() == 0
    assert metrics.placement_bytes_uploaded.value() > 0
    text = metrics.render()
    assert "refresh_ms" in text
    assert "placement_bytes_uploaded_total" in text
    assert "refreshes_coalesced_total" in text

    # ranked-neighborhood quality vs a full rebuild: ID overlap, not scores
    rebuild = DiscoveryEngine(cat.snapshot(), model,
                              EngineConfig(k=10, mode="lsh",
                                           lsh=LSHConfig(n_bands=64),
                                           cache_entries=0,
                                           column_buckets=(128, 256, 512,
                                                           1024),
                                           prewarm_fraction=2.0))
    qids = select_queries(lake, 12)
    overlap = []
    for cid in qids:
        a = {m.column_id
             for m in eng.query(DiscoveryRequest(column_id=int(cid))).matches}
        b = {m.column_id for m in
             rebuild.query(DiscoveryRequest(column_id=int(cid))).matches}
        overlap.append(len(a & b) / max(len(b), 1))
    assert np.mean(overlap) >= 0.7, overlap
    assert measure_recall(eng, qids, k=10)["recall"] >= 0.9

    # external uploads z-score against the frozen stats — same head
    r = eng.query(DiscoveryRequest(
        name="up", values=[f"tok{i % 70}" for i in range(200)]))
    assert r.matches

    # a drop rewrites manifest history -> delta inadmissible -> full rebuild
    cat.drop_table("burst0")
    eng._maybe_follow(force=True)
    assert eng.stats()["refresh"]["full"] == 2

    eng.close()
    rebuild.close()


# ---------------------------------------------------------------------------
# satellite 2: placement-leak regression across refresh cycles
# ---------------------------------------------------------------------------

def _rss_mb() -> float:
    with open("/proc/self/statm") as fh:
        return int(fh.read().split()[1]) * 4096 / 2 ** 20


def test_refresh_cycles_do_not_leak_placements(lake_and_model, tmp_path):
    lake, model = lake_and_model
    root, cat = _new_catalog(tmp_path, lake)
    base = live_placement_bundles()    # foreign bundles, e.g. fixtures
    eng, reader = _follower(root, model)
    rss0 = _rss_mb()

    # count relative to a baseline: the bundle counter is process-global
    # and other modules' fixtures may legitimately hold placements
    high_water = live_placement_bundles()
    for i in range(6):
        _str_table(cat, f"cycle{i}", seed=90 + i, n_cols=2, n_rows=120)
        eng._maybe_follow(force=True)
        eng.query(DiscoveryRequest(column_id=1))
        high_water = max(high_water, live_placement_bundles())

    assert eng.stats()["refresh"]["incremental"] == 6
    # one live head; predecessors must have been released as refs hit 0.
    # allow 2: the head's bundle plus at most one mid-swap survivor.
    assert high_water - base <= 2, (high_water, base)
    # a placement or snapshot leak would accrete one retained corpus per
    # cycle; a generous bound still catches O(lake)-per-refresh retention
    assert _rss_mb() - rss0 < 256.0, (_rss_mb(), rss0)
    eng.close()
    assert live_placement_bundles() == base


# ---------------------------------------------------------------------------
# tentpole part 3: rolling fleet refresh under live queries
# ---------------------------------------------------------------------------

def test_rolling_fleet_refresh_drops_nothing(lake_and_model, tmp_path):
    lake, model = lake_and_model
    root, cat = _new_catalog(tmp_path, lake)
    base = live_placement_bundles()    # global counter; see leak test

    fleet = EngineFleet.from_catalog(
        root, model,
        EngineConfig(k=5, mode="lsh", lsh=LSHConfig(n_bands=64),
                     cache_entries=0, incremental=True, warmup=False,
                     column_buckets=(128, 256, 512, 1024),
                     prewarm_fraction=2.0),
        n_replicas=2, config=FleetConfig(health_interval_s=0.05))
    try:
        deadline = time.monotonic() + 30.0
        while not fleet.warm_event.is_set():
            assert time.monotonic() < deadline, "fleet never warmed"
            time.sleep(0.02)

        qids = [int(q) for q in select_queries(lake, 8)]
        stop = threading.Event()
        errors: list = []
        served = [0]

        def load():
            i = 0
            while not stop.is_set():
                reqs = [DiscoveryRequest(name=f"r{i}_{j}",
                                         column_id=qids[(i + j) % len(qids)])
                        for j in range(4)]
                try:
                    out = fleet.query_batch(reqs, timeout=60.0)
                    assert len(out) == len(reqs)
                    served[0] += len(out)
                except Exception as exc:           # pragma: no cover
                    errors.append(repr(exc))
                    return
                i += 1

        t = threading.Thread(target=load)
        t.start()
        try:
            for i in range(2):
                _str_table(cat, f"roll{i}", seed=130 + i)
                assert fleet.roll_refresh() == 2   # both replicas advanced
        finally:
            stop.set()
            t.join(timeout=60.0)

        assert not errors, errors
        assert served[0] > 0
        stats = fleet.stats()
        assert stats["rolling_refreshes"] == 4     # 2 rolls x 2 replicas
        versions = {r["engine_version"] for r in stats["replicas"].values()}
        assert len(versions) == 1                  # converged on one head
        for r in fleet.replicas:
            assert r.engine.stats()["refresh"]["incremental"] >= 1
            assert r.engine.stats()["refresh"]["recompiles_total"] == 0
    finally:
        fleet.close()
    assert live_placement_bundles() == base
