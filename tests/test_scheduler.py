"""Continuous-batching request runtime: future-based submission, priority
batch formation, bucket snapping, deadline expiry, overload shedding, the
queue/compute latency split, and the ``serve_discovery`` compat adapter's
request-order parity with the PR-4 synchronous chunking."""
import threading
import time

import numpy as np
import pytest

from repro.exec import DEFAULT_BATCH_BUCKETS, Planner, PlannerConfig
from repro.service import (ColumnCatalog, DeadlineExpired, DiscoveryEngine,
                           DiscoveryRequest, EngineConfig, RequestScheduler,
                           SchedulerConfig, SchedulerOverloadError,
                           serve_discovery)


def _tiny_model():
    from repro.core.gbdt import GBDTParams
    from repro.core.predictor import JoinQualityModel
    p = GBDTParams(feats=np.zeros((1, 1), np.int32),
                   thrs=np.zeros((1, 1), np.float32),
                   leaves=np.zeros((1, 2), np.float32), base=0.0)
    return JoinQualityModel(gbdt=p)


@pytest.fixture(scope="module")
def snapshot(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("sched_catalog"))
    cat = ColumnCatalog(root, n_perm=64)
    for t in range(4):
        cat.add_table(f"t{t}",
                      [(f"c{t}a", [f"v{t}_{i}" for i in range(60)]),
                       (f"c{t}b", [f"w{i % 11}" for i in range(40)])])
    return cat.snapshot()


@pytest.fixture()
def engine(snapshot):
    return DiscoveryEngine(snapshot, _tiny_model(),
                           EngineConfig(k=3, mode="full", cache_entries=0))


class _Gate:
    """Stall the engine's batch path so tests control batch formation."""

    def __init__(self, engine):
        self.release = threading.Event()
        self.entered = threading.Event()
        self.calls: list[list[str]] = []
        real = engine.query_batch

        def wrapped(reqs, **kw):
            self.calls.append([r.name for r in reqs])
            self.entered.set()
            assert self.release.wait(30)
            return real(reqs, **kw)

        engine.query_batch = wrapped


# ---------------------------------------------------------------------------
# submission / completion basics
# ---------------------------------------------------------------------------

def test_submit_completes_with_latency_split(engine):
    reqs = [DiscoveryRequest(name=f"q{i}", column_id=i % engine.n_columns)
            for i in range(6)]
    with RequestScheduler(engine, SchedulerConfig(max_wait_ms=1.0)) as sch:
        futs = [sch.submit(r) for r in reqs]
        outs = [f.result(timeout=30) for f in futs]
    assert [r.name for r in outs] == [r.name for r in reqs]
    for r in outs:
        assert r.queue_ms >= 0.0 and r.compute_ms > 0.0
        assert r.latency_ms == pytest.approx(r.queue_ms + r.compute_ms)
    s = engine.stats()["scheduler"]
    assert s["submitted"] == 6 and s["completed"] == 6
    assert s["batches"] >= 1 and sum(s["batch_size_hist"].values()) == \
        s["batches"]
    # direct engine calls report pure compute (no queue component)
    direct = engine.query(reqs[0])
    assert direct.queue_ms == 0.0
    assert direct.latency_ms == pytest.approx(direct.compute_ms)


def test_priority_orders_batches_out_of_order(engine):
    """Higher-priority submissions overtake earlier low-priority ones, and
    every future still resolves to its own request's response."""
    gate = _Gate(engine)
    with RequestScheduler(engine,
                          SchedulerConfig(max_wait_ms=0.0,
                                          max_batch=1)) as sch:
        f_decoy = sch.submit(DiscoveryRequest(name="decoy", column_id=0))
        assert gate.entered.wait(30)       # worker busy with the decoy
        f_low = sch.submit(DiscoveryRequest(name="low", column_id=1),
                           priority=0)
        f_high = sch.submit(DiscoveryRequest(name="high", column_id=2),
                            priority=5)
        gate.release.set()
        outs = {name: f.result(timeout=30)
                for name, f in [("decoy", f_decoy), ("low", f_low),
                                ("high", f_high)]}
    assert gate.calls == [["decoy"], ["high"], ["low"]]
    for name, r in outs.items():
        assert r.name == name              # out-of-order completion, yet
        assert r.matches is not None       # each future got ITS response


def test_deadline_expiry(engine, fake_clock):
    """Deadline lapse under an injected clock: no wall-clock sleep, no
    race between the 5 ms deadline and a hoped-for-slow scheduler."""
    gate = _Gate(engine)
    with RequestScheduler(engine,
                          SchedulerConfig(max_wait_ms=0.0,
                                          clock=fake_clock)) as sch:
        f_decoy = sch.submit(DiscoveryRequest(name="decoy", column_id=0))
        assert gate.entered.wait(30)
        f_dead = sch.submit(DiscoveryRequest(name="dead", column_id=1),
                            deadline_ms=5.0)
        f_live = sch.submit(DiscoveryRequest(name="live", column_id=2),
                            deadline_ms=60_000.0)
        fake_clock.advance(0.050)          # deadline lapses while queued
        gate.release.set()
        with pytest.raises(DeadlineExpired):
            f_dead.result(timeout=30)
        assert f_live.result(timeout=30).name == "live"
        assert f_decoy.result(timeout=30).name == "decoy"
        s = sch.stats()
    assert s["expired"] == 1 and s["completed"] == 2


def test_overload_shedding_and_backpressure(engine):
    gate = _Gate(engine)
    sch = RequestScheduler(engine, SchedulerConfig(max_wait_ms=0.0,
                                                   max_batch=1,
                                                   max_queue=2))
    try:
        futs = [sch.submit(DiscoveryRequest(name="q0", column_id=0))]
        assert gate.entered.wait(30)       # q0 popped: worker is busy
        futs += [sch.submit(DiscoveryRequest(name=f"q{i}", column_id=0))
                 for i in range(1, 3)]     # 2 queued = full
        with pytest.raises(SchedulerOverloadError):
            sch.submit(DiscoveryRequest(name="shed", column_id=1))
        assert sch.stats()["shed"] == 1
        # block=True is backpressure, not shedding
        blocked = []
        t = threading.Thread(target=lambda: blocked.append(
            sch.submit(DiscoveryRequest(name="patient", column_id=1),
                       block=True)))
        t.start()
        time.sleep(0.05)
        assert not blocked                 # still waiting for queue space
        gate.release.set()
        t.join(30)
        assert not t.is_alive()
        assert blocked[0].result(timeout=30).name == "patient"
        for f in futs:
            f.result(timeout=30)
        assert sch.stats()["shed"] == 1    # backpressure never sheds
    finally:
        gate.release.set()
        sch.close()


def test_close_drain_false_fails_queued(engine):
    gate = _Gate(engine)
    sch = RequestScheduler(engine, SchedulerConfig(max_wait_ms=0.0,
                                                   max_batch=1))
    f_running = sch.submit(DiscoveryRequest(name="running", column_id=0))
    assert gate.entered.wait(30)
    f_queued = sch.submit(DiscoveryRequest(name="queued", column_id=1))
    closer = threading.Thread(target=lambda: sch.close(drain=False))
    closer.start()
    with pytest.raises(RuntimeError, match="closed"):
        f_queued.result(timeout=30)
    gate.release.set()
    closer.join(30)
    assert not closer.is_alive()
    assert f_running.result(timeout=30).name == "running"  # in-flight lands
    with pytest.raises(RuntimeError, match="closed"):
        sch.submit(DiscoveryRequest(name="late", column_id=0))


# ---------------------------------------------------------------------------
# bucket ladder
# ---------------------------------------------------------------------------

def test_planner_snap_batch():
    p = Planner(PlannerConfig(batch_buckets=(4, 8, 32)))
    assert [p.snap_batch(n) for n in (1, 3, 4, 5, 8, 9, 32)] == \
        [4, 4, 4, 8, 8, 32, 32]
    assert p.snap_batch(33) == 64          # beyond the ladder: top multiple
    assert p.snap_batch(65) == 96
    # no ladder: identity (callers pad by their own multiple)
    assert Planner(PlannerConfig()).snap_batch(13) == 13


def test_scheduler_installs_ladder_and_engine_pads_to_bucket(engine):
    assert engine.config.batch_buckets is None
    gate = _Gate(engine)
    with RequestScheduler(engine,
                          SchedulerConfig(max_wait_ms=50.0,
                                          batch_buckets=(4, 8))) as sch:
        assert engine.planner.config.batch_buckets == (4, 8)
        assert engine._pad_target(3) == 4 and engine._pad_target(5) == 8
        futs = [sch.submit(DiscoveryRequest(name=f"q{i}",
                                            column_id=i % engine.n_columns))
                for i in range(5)]
        gate.release.set()
        for f in futs:
            f.result(timeout=30)
        s = sch.stats()
    # the 5 arrivals coalesced (50ms window) into batches the engine
    # padded up the ladder; the planner only ever saw bucket shapes
    assert s["buckets"] == [4, 8]
    assert engine.last_plan.cost["n_queries"] in (4, 8)
    assert sum(s["batch_size_hist"].values()) == s["batches"]
    assert s["bucket_hits"] + s["bucket_misses"] == s["batches"]


def test_derive_batch_buckets(tmp_path):
    from repro.launch.costmodel import derive_batch_buckets
    rec = {"batch_sweep": {"batches": [{"batch": 32}, {"batch": 8},
                                       {"batch": 64}]}}
    assert derive_batch_buckets(rec) == (8, 32, 64)
    assert derive_batch_buckets({}) == DEFAULT_BATCH_BUCKETS
    assert derive_batch_buckets(str(tmp_path / "missing.json")) == \
        DEFAULT_BATCH_BUCKETS


# ---------------------------------------------------------------------------
# external (uploaded) columns
# ---------------------------------------------------------------------------

def test_external_request_profiled_at_submit(engine):
    gate = _Gate(engine)
    vals = [f"v0_{i}" for i in range(40)]
    with RequestScheduler(engine, SchedulerConfig(max_wait_ms=0.0)) as sch:
        req = DiscoveryRequest(name="up", values=vals)
        fut = sch.submit(req)
        assert req._profile is not None    # profiled in the submitter
        gate.release.set()
        got = fut.result(timeout=30)
    direct = engine.query(DiscoveryRequest(name="up2", values=vals))
    assert [m.column_id for m in got.matches] == \
        [m.column_id for m in direct.matches]


# ---------------------------------------------------------------------------
# serve_discovery compat adapter
# ---------------------------------------------------------------------------

def test_serve_discovery_order_parity_with_pr4_chunking(snapshot):
    """The adapter must look exactly like the old synchronous loop to its
    caller: same responses, same request order, regardless of how the
    scheduler formed batches underneath."""
    model = _tiny_model()
    eng_sync = DiscoveryEngine(snapshot, model,
                               EngineConfig(k=3, mode="full",
                                            cache_entries=0))
    eng_async = DiscoveryEngine(snapshot, model,
                                EngineConfig(k=3, mode="full",
                                             cache_entries=0))
    reqs = [DiscoveryRequest(name=f"q{i}", column_id=(i * 3) % 8)
            for i in range(11)]
    # PR-4 semantics: drain in fixed max_batch chunks, in order
    baseline = []
    for i in range(0, len(reqs), 4):
        baseline.extend(eng_sync.query_batch(reqs[i:i + 4]))
    got = list(serve_discovery(eng_async, reqs, max_batch=4))
    assert [r.name for r in got] == [r.name for r in reqs]
    for b, g in zip(baseline, got):
        assert b.name == g.name
        assert [m.column_id for m in b.matches] == \
            [m.column_id for m in g.matches]
        np.testing.assert_allclose([m.score for m in b.matches],
                                   [m.score for m in g.matches],
                                   rtol=1e-5)


def test_serve_discovery_backpressures_instead_of_shedding(engine):
    """A tiny bounded queue under the adapter must slow the producer, not
    drop requests — every response arrives, in order."""
    reqs = [DiscoveryRequest(name=f"q{i}", column_id=i % engine.n_columns)
            for i in range(12)]
    sch = RequestScheduler(engine, SchedulerConfig(max_queue=2, max_batch=2,
                                                   max_wait_ms=0.0))
    try:
        got = list(serve_discovery(engine, reqs, scheduler=sch))
    finally:
        stats = sch.stats()
        sch.close()
    assert [r.name for r in got] == [r.name for r in reqs]
    assert stats["shed"] == 0 and stats["completed"] == 12


# ---------------------------------------------------------------------------
# stats() consistency under a live worker (torn-read regression)
# ---------------------------------------------------------------------------

def test_stats_snapshot_consistent_under_live_worker(engine):
    """Regression for torn stats() reads: the worker used to bump
    ``batches`` / ``batch_size_hist`` / ``bucket_hits`` outside the lock,
    so a concurrent stats() could observe a batch counted in one counter
    but not yet in its sibling.  Hammer stats() against a live worker and
    assert the cross-counter invariants on EVERY snapshot."""
    torn = []
    stop = threading.Event()

    def hammer(sch):
        while not stop.is_set():
            s = sch.stats()
            if sum(s["batch_size_hist"].values()) != s["batches"]:
                torn.append(("hist", s))
            if s["bucket_hits"] + s["bucket_misses"] != s["batches"]:
                torn.append(("bucket", s))
            if s["completed"] + s["failed"] + s["expired"] > s["submitted"]:
                torn.append(("resolved", s))

    with RequestScheduler(engine, SchedulerConfig(max_wait_ms=0.0,
                                                  max_batch=2)) as sch:
        readers = [threading.Thread(target=hammer, args=(sch,))
                   for _ in range(2)]
        for t in readers:
            t.start()
        futs = [sch.submit(DiscoveryRequest(name=f"q{i}",
                                            column_id=i % engine.n_columns))
                for i in range(40)]
        for f in futs:
            f.result(timeout=60)
        stop.set()
        for t in readers:
            t.join(10)
        s = sch.stats()
    assert not torn, f"torn stats snapshots: {torn[:3]}"
    assert s["completed"] == 40
    assert sum(s["batch_size_hist"].values()) == s["batches"]
    assert s["bucket_hits"] + s["bucket_misses"] == s["batches"]
