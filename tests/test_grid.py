"""Mesh-geometry parity suite for 2-D (query × data) grid execution.

The 2-D grid refactor (``repro.exec.sharded``) must be *invisible* in
results: for fixed seeds, every grid factorization of the mesh — from the
legacy replicated-query ``(1, d)`` to the corpus-replicating ``(q, 1)`` —
returns the same ranked output (scores and global ids, up to the order of
exact score ties) as the single-device executor, for every candidate kind
and for the k ≥ n / empty-candidate edge cases.

The in-process tests sweep every factorization of the *ambient* device
count, so the CI grid-matrix job (XLA device counts {4, 8}) exercises the
degenerate q=1 and d=1 geometries at both widths; a subprocess test
forces 8 host devices whenever the ambient count differs, so the full
{1×8, 2×4, 4×2, 8×1} sweep runs even under a plain 1-device pytest.

Also here: the hypothesis-driven planner invariants for the grid
placement dimension, and the regression test for recall/coverage
accounting under query sharding (``n_scored`` must psum over the data
axis only — a query-sharded grid must not double-count its replicas).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.exec import Planner, PlannerConfig, QueryPlan

ROOT = os.path.join(os.path.dirname(__file__), "..")


class _FakeMesh:
    """Planner only reads mesh.shape — keeps placement tests jax-free."""

    def __init__(self, **shape):
        self.shape = shape


def _factorizations(n: int):
    return [(q, n // q) for q in range(1, n + 1) if n % q == 0]


def _assert_same_ranking(s_ref, i_ref, s, i, tol=1e-4):
    """Ranked output equal up to the order of exact score ties.

    Score vectors must match elementwise (so a missing true top-k column
    can't hide — its absence would shift every later score). Where the id
    sequences disagree, the disagreeing id's score must equal (within
    tol) some score in the other ranking: a tie, not a wrong result.
    """
    s_ref, i_ref = np.asarray(s_ref), np.asarray(i_ref)
    s, i = np.asarray(s), np.asarray(i)
    assert s.shape == s_ref.shape and i.shape == i_ref.shape
    both = np.isfinite(s) & np.isfinite(s_ref)
    assert (np.isfinite(s) == np.isfinite(s_ref)).all()
    np.testing.assert_allclose(s[both], s_ref[both], rtol=tol, atol=tol)
    for row in range(s.shape[0]):
        a = {int(x) for x in i_ref[row] if x >= 0}
        b = {int(x) for x in i[row] if x >= 0}
        for side, (ids, sc, other_sc) in enumerate(
                ((i_ref[row], s_ref[row], s[row]),
                 (i[row], s[row], s_ref[row]))):
            diff = (a - b) if side == 0 else (b - a)
            for d in diff:
                sd = sc[list(ids).index(d)]
                near = np.min(np.abs(other_sc[np.isfinite(other_sc)] - sd))
                assert near <= tol * max(1.0, abs(sd)), (
                    f"row {row}: id {d} (score {sd}) in one ranking has no "
                    f"tied score in the other (closest {near})")


# ---------------------------------------------------------------------------
# executor parity across grid geometries (ambient devices)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def grid_setup():
    import jax

    from repro.core import (GBDTConfig, LakeSpec, generate_lake, profile_lake,
                            select_queries, train_quality_model)
    from repro.exec import Executor
    from repro.kernels import ops
    from repro.service.lsh import band_keys

    lake = generate_lake(LakeSpec(n_domains=10, n_tables=24, row_budget=2048,
                                  rows_log_mean=6.8, coverage_range=(0.5, 1.0),
                                  gran_ratio=(4, 8), seed=7))
    prof = profile_lake(lake.batch)
    model = train_quality_model([lake], GBDTConfig(n_trees=30, depth=4),
                                n_query=64)
    sigs = np.asarray(ops.minhash(lake.batch.values32, n_perm=128, seed=0))
    keys = band_keys(sigs, 64)
    gb = model.gbdt.astuple()
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    ex_local = Executor(prof.zscored, prof.words, gb, table_ids=lake.table,
                        band_keys=keys)
    ex_mesh = Executor(prof.zscored, prof.words, gb, table_ids=lake.table,
                       band_keys=keys, mesh=mesh)
    qids = select_queries(lake, 16)
    batch = {
        "zq": prof.zscored[qids].astype(np.float32),
        "wq": prof.words[qids],
        "tq": lake.table[qids].astype(np.int32),
        "qid": qids.astype(np.int32),
        "qkeys": keys[qids],
    }
    return lake, ex_local, ex_mesh, batch, n_dev


def _run(ex, plan, batch, qkeys=None):
    return ex.execute(plan, batch["zq"], batch["wq"], batch["tq"],
                      batch["qid"], qkeys=qkeys)


def test_grid_parity_all_kind(grid_setup):
    """Full scan: every grid geometry must match the local executor
    exactly (same candidate set by construction, so even ids align up to
    tie order)."""
    lake, ex_local, ex_mesh, batch, n_dev = grid_setup
    n = lake.n_columns
    ref_s, ref_i, ref_n = _run(
        ex_local, QueryPlan(candidates="all", sharded=False, budget=n, k=10),
        batch)
    for grid in _factorizations(n_dev):
        s, i, nn = _run(
            ex_mesh, QueryPlan(candidates="all", sharded=True, budget=n,
                               k=10, grid=grid), batch)
        _assert_same_ranking(ref_s, ref_i, s, i)
        np.testing.assert_array_equal(nn, ref_n)


def test_grid_parity_lsh_kind(grid_setup):
    """Pure-LSH candidates with an uncut budget: the hit set is a pure
    function of the band keys, so every geometry scores exactly the same
    columns — parity must be exact up to tie order."""
    lake, ex_local, ex_mesh, batch, n_dev = grid_setup
    n = lake.n_columns
    ref = _run(ex_local, QueryPlan(candidates="lsh", sharded=False, budget=n,
                                   k=10), batch, qkeys=batch["qkeys"])
    for grid in _factorizations(n_dev):
        s, i, nn = _run(
            ex_mesh, QueryPlan(candidates="lsh", sharded=True, budget=n,
                               k=10, grid=grid), batch, qkeys=batch["qkeys"])
        _assert_same_ranking(ref[0], ref[1], s, i)
        np.testing.assert_array_equal(nn, ref[2])


def test_grid_parity_hybrid_kind(grid_setup):
    """Hybrid blocking at a realistic budget: per-shard truncation may
    swap exact score ties between geometries, but the ranked score
    vectors (and every non-tied id) must be identical."""
    lake, ex_local, ex_mesh, batch, n_dev = grid_setup
    budget = 128
    ref = _run(ex_local, QueryPlan(candidates="hybrid", sharded=False,
                                   budget=budget, k=10), batch,
               qkeys=batch["qkeys"])
    for grid in _factorizations(n_dev):
        s, i, _ = _run(
            ex_mesh, QueryPlan(candidates="hybrid", sharded=True,
                               budget=budget, k=10, grid=grid), batch,
            qkeys=batch["qkeys"])
        _assert_same_ranking(ref[0], ref[1], s, i)


def test_grid_k_exceeds_lake(grid_setup):
    """k ≥ n: every geometry pads out to k with -inf / -1 and agrees with
    the local executor on the real prefix."""
    lake, ex_local, ex_mesh, batch, n_dev = grid_setup
    n = lake.n_columns
    k = n + 7
    ref_s, ref_i, _ = _run(
        ex_local, QueryPlan(candidates="all", sharded=False, budget=n, k=k),
        batch)
    for grid in _factorizations(n_dev):
        s, i, _ = _run(
            ex_mesh, QueryPlan(candidates="all", sharded=True, budget=n,
                               k=k, grid=grid), batch)
        assert s.shape == (len(batch["qid"]), k)
        assert (i[~np.isfinite(s)] == -1).all()
        _assert_same_ranking(ref_s, ref_i, s, i)


def test_grid_empty_candidates(grid_setup):
    """Query keys that hit no bucket: all geometries must return the empty
    result (-inf scores, -1 ids, zero scored columns) — exercises the
    merge path when every tile contributes nothing."""
    from repro.kernels.lsh_probe import PAD_QUERY

    lake, ex_local, ex_mesh, batch, n_dev = grid_setup
    dead = np.full_like(batch["qkeys"], PAD_QUERY)
    for grid in _factorizations(n_dev):
        s, i, nn = _run(
            ex_mesh, QueryPlan(candidates="lsh", sharded=True,
                               budget=lake.n_columns, k=10, grid=grid),
            batch, qkeys=dead)
        assert not np.isfinite(s).any()
        assert (i == -1).all()
        assert (nn == 0).all()


def test_grid_accounting_no_double_count(grid_setup):
    """Recall/coverage regression (ISSUE satellite): ``n_scored`` psums
    over the DATA axis only, so a query-sharded grid reports the same
    candidate count — and hence the same candidate fraction — as the 1-D
    plan. External queries (no exclusions) with a budget divisible by
    every shard count make the expected count exact: the budget itself."""
    lake, ex_local, ex_mesh, batch, n_dev = grid_setup
    budget = 64
    ext = dict(batch)
    ext["tq"] = np.full_like(batch["tq"], -1)
    ext["qid"] = np.full_like(batch["qid"], -1)
    counts = {}
    for grid in _factorizations(n_dev):
        _, _, nn = _run(
            ex_mesh, QueryPlan(candidates="hybrid", sharded=True,
                               budget=budget, k=10, grid=grid), ext,
            qkeys=ext["qkeys"])
        counts[grid] = nn
        # the double-count bug would report q_shards × budget here
        np.testing.assert_array_equal(nn, np.full_like(nn, budget))
    fracs = {g: float(np.mean(nn)) / lake.n_columns
             for g, nn in counts.items()}
    assert len(set(fracs.values())) == 1, fracs


# ---------------------------------------------------------------------------
# acceptance: the full {1×8, 2×4, 4×2, 8×1} sweep on 8 forced host devices
# ---------------------------------------------------------------------------

def test_grid_parity_8dev_subprocess():
    """Runs the in-process parity tests above under 8 forced host devices
    whenever the ambient count differs (a plain 1-device pytest still
    proves the 8-device geometries; the CI grid job covers 4)."""
    import jax

    if len(jax.devices()) == 8:
        pytest.skip("ambient device count is already 8; the in-process "
                    "parity tests above cover every geometry")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "pytest",
         os.path.join(ROOT, "tests", "test_grid.py"),
         "-q", "-k", "not subprocess and (parity or grid_k or empty or "
         "double_count)"],
        capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr


# ---------------------------------------------------------------------------
# hypothesis-driven planner invariants for the grid dimension
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(1, 512), st.integers(1, 100_000))
def test_grid_factorizations_admissible(n_dev, n_queries, n_columns):
    """Every option factorizes the mesh exactly, never idles a query
    shard, and never under-fills a data shard."""
    p = Planner(PlannerConfig(k=10, min_columns_per_shard=64))
    for q, d in p.grid_options(n_dev, n_queries, n_columns):
        assert q * d == n_dev
        assert 1 <= q <= max(n_queries, 1)
        assert d == 1 or -(-n_columns // d) >= 64


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 16), st.integers(1, 256), st.integers(1, 50_000))
def test_plan_grid_within_mesh_and_batch(n_dev, n_queries, n_columns):
    """Planned grids use exactly the mesh's devices and keep q_shards
    within the padded batch, for every serving mode."""
    p = Planner(PlannerConfig(k=5))
    mesh = _FakeMesh(data=n_dev, model=1)
    for mode in ("sharded", "lsh", "auto"):
        plan = p.plan(n_columns=n_columns, n_queries=n_queries, mode=mode,
                      mesh=mesh)
        q, d = plan.grid
        assert plan.q_shards == q and plan.n_shards == d
        if plan.sharded:
            assert q * d == n_dev
            assert q <= max(n_queries, 1)
        else:
            assert plan.grid == (1, 1)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 5_000), st.integers(1, 5_000))
def test_candidate_budget_monotone(n1, n2):
    p = Planner(PlannerConfig(k=10))
    lo, hi = sorted((n1, n2))
    assert p.candidate_budget(lo) <= p.candidate_budget(hi)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 128), st.integers(1, 128), st.integers(1, 20_000),
       st.integers(1, 20_000))
def test_cost_monotone_in_both_axes(q1, q2, c1, c2):
    """At a fixed grid, the modeled cost never decreases when the batch or
    the lake grows (in either axis, or both at once)."""
    from repro.launch.costmodel import discovery_stage_costs

    ql, qh = sorted((q1, q2))
    cl, ch = sorted((c1, c2))
    for grid in ((1, 1), (1, 4), (2, 2), (4, 1)):
        cost = lambda q, c: discovery_stage_costs(
            q, c, budget=max(10, c // 5), candidates="hybrid",
            n_shards=grid[1], q_shards=grid[0])["total_flops"]
        assert cost(ql, cl) <= cost(qh, cl) <= cost(qh, ch)
        assert cost(ql, cl) <= cost(ql, ch) <= cost(qh, ch)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(1, 256), st.integers(1, 50_000))
def test_plan_deterministic(n_dev, n_queries, n_columns):
    """plan() is a pure function of its inputs — equal inputs, equal plan
    (grid included) and equal modeled cost."""
    mesh = _FakeMesh(data=n_dev, model=1)
    for mode in ("auto", "lsh", "sharded", "full"):
        a = Planner(PlannerConfig(k=10)).plan(
            n_columns=n_columns, n_queries=n_queries, mode=mode, mesh=mesh)
        b = Planner(PlannerConfig(k=10)).plan(
            n_columns=n_columns, n_queries=n_queries, mode=mode, mesh=mesh)
        assert a == b
        assert a.grid == b.grid
        assert a.cost == b.cost


def test_plan_explicit_grid_validation():
    p = Planner(PlannerConfig(k=10))
    mesh = _FakeMesh(data=8, model=1)
    plan = p.plan(n_columns=10_000, n_queries=16, mode="sharded", mesh=mesh,
                  grid=(2, 4))
    assert plan.grid == (2, 4) and plan.n_shards == 4 and plan.q_shards == 2
    with pytest.raises(ValueError):        # does not tile the mesh
        p.plan(n_columns=10_000, n_queries=16, mode="sharded", mesh=mesh,
               grid=(3, 2))
    with pytest.raises(ValueError):        # idle query shards
        p.plan(n_columns=10_000, n_queries=4, mode="sharded", mesh=mesh,
               grid=(8, 1))
    with pytest.raises(ValueError):        # not a 2-tuple / bad values
        QueryPlan(candidates="all", sharded=True, budget=10, k=5,
                  grid=(0, 8))


def test_plan_auto_small_lake_stays_local_despite_big_batch():
    """A (q, 1) corpus-replicating grid alone must not drag a tiny lake
    onto the mesh in auto mode: sharding is gated on an admissible d > 1
    factorization (the lake justifying the mesh), batch size or not."""
    p = Planner(PlannerConfig(k=10, min_columns_per_shard=64))
    mesh = _FakeMesh(data=8, model=1)
    tiny = p.plan(n_columns=32, n_queries=64, mode="auto", mesh=mesh)
    assert not tiny.sharded and tiny.grid == (1, 1)
    big = p.plan(n_columns=10_000, n_queries=64, mode="auto", mesh=mesh)
    assert big.sharded and big.n_grid_devices == 8


def test_plan_budget_splits_data_axis_only():
    """The per-query candidate budget must not shrink when the batch is
    sharded: budget_per_shard divides over d_shards only."""
    plan = QueryPlan(candidates="hybrid", sharded=True, budget=128, k=10,
                     grid=(4, 2))
    assert plan.budget_per_shard == 64          # 128 / d=2, NOT /8
    legacy = QueryPlan(candidates="hybrid", sharded=True, budget=128, k=10,
                       n_shards=8)
    assert legacy.grid == (1, 8)                # 1-D construction upgrades
    assert legacy.budget_per_shard == 16
