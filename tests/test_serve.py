"""Serving engine + beyond-paper serving optimizations (int8 KV cache)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.serve.engine import generate


def test_generate_greedy_deterministic():
    cfg = registry.reduced_config(registry.get_config("smollm-360m"))
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(1, cfg.vocab, (2, 16)).astype(np.int32)
    out1 = generate(params, cfg, jnp.asarray(prompts), max_new=8)
    out2 = generate(params, cfg, jnp.asarray(prompts), max_new=8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(out1, out2)


def test_int8_kv_cache_close_to_fp():
    cfg = registry.reduced_config(registry.get_config("qwen3-4b"))
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(1))
    b, n = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, n), 0, cfg.vocab)
    c1 = registry.init_caches(cfg, b, 64)
    c2 = registry.init_caches(cfgq, b, 64)
    assert c2["kv"]["k"].dtype == jnp.int8
    o1, o2 = [], []
    for i in range(n):
        l1, c1 = registry.decode_step(params, cfg, {"tokens": toks[:, i:i + 1]}, c1)
        l2, c2 = registry.decode_step(params, cfgq, {"tokens": toks[:, i:i + 1]}, c2)
        o1.append(np.asarray(l1))
        o2.append(np.asarray(l2))
    a, b_ = np.concatenate(o1, 1), np.concatenate(o2, 1)
    rel = np.abs(a - b_).max() / np.abs(a).max()
    assert rel < 0.05, rel
    # and greedy argmax decisions should essentially agree
    agree = (a.argmax(-1) == b_.argmax(-1)).mean()
    assert agree > 0.9, agree


def test_int8_kv_prefill_path():
    from repro.models.transformer import forward_with_caches
    cfg = dataclasses.replace(
        registry.reduced_config(registry.get_config("smollm-360m")),
        kv_quant=True)
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0, cfg.vocab)
    logits, caches = forward_with_caches(params, cfg, toks, 64)
    assert caches["kv"]["k"].dtype == jnp.int8
    lg, caches = registry.decode_step(params, cfg, {"tokens": toks[:, -1:]}, caches)
    assert np.isfinite(np.asarray(lg)).all()


def test_generate_with_vlm_image():
    cfg = registry.reduced_config(registry.get_config("phi-3-vision-4.2b"))
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    prompts = jnp.asarray(r.integers(1, cfg.vocab, (2, 16)), jnp.int32)
    img = jnp.asarray(r.normal(size=(2, cfg.n_patches, cfg.d_model)) * 0.02,
                      jnp.float32)
    out = generate(params, cfg, prompts, max_new=4, img=img)
    assert out.shape == (2, 4)
