"""Minimal deterministic stand-in for the ``hypothesis`` library.

The container this repo runs in does not ship ``hypothesis`` and installing
packages is not allowed, so ``conftest.py`` puts this directory on
``sys.path`` only when the real library is missing. The shim implements the
small API surface the test-suite uses (``given``, ``settings``,
``strategies.integers/floats/lists/randoms``) by sampling a fixed number of
pseudo-random examples from a per-test deterministic seed — property tests
still execute and still catch regressions, just without shrinking or
adaptive example generation.
"""
from __future__ import annotations

import os
import random
import zlib

import numpy as np

# Property tests ask for up to 200 examples; the shim caps the count so the
# whole suite stays fast on CPU (override with HYPOTHESIS_SHIM_MAX_EXAMPLES).
_EXAMPLE_CAP = int(os.environ.get("HYPOTHESIS_SHIM_MAX_EXAMPLES", "20"))


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return SearchStrategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return SearchStrategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements: SearchStrategy, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example_from(rng) for _ in range(n)]
        return SearchStrategy(draw)

    @staticmethod
    def randoms():
        return SearchStrategy(
            lambda rng: random.Random(int(rng.integers(0, 2 ** 32))))


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats: SearchStrategy):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_shim_max_examples", 20), _EXAMPLE_CAP)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                example = [s.example_from(rng) for s in strats]
                fn(*args, *example, **kwargs)
        # no functools.wraps: __wrapped__ would make pytest introspect the
        # original signature and treat strategy arguments as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__dict__.update(fn.__dict__)
        return wrapper
    return deco
