"""Online discovery service: catalog persistence, incremental maintenance,
LSH pruning quality, engine batching/caching — the acceptance end-to-end."""
import os

import numpy as np
import pytest

from repro.core import GBDTConfig, LakeSpec, generate_lake, select_queries, \
    train_quality_model
from repro.service import (ColumnCatalog, DiscoveryEngine, DiscoveryRequest,
                           EngineConfig, LSHConfig, add_lake, band_keys,
                           measure_recall, serve_discovery)


@pytest.fixture(scope="module")
def lake_and_model():
    lake = generate_lake(LakeSpec(n_domains=10, n_tables=24, row_budget=2048,
                                  rows_log_mean=6.8, coverage_range=(0.5, 1.0),
                                  gran_ratio=(4, 8), seed=7))
    model = train_quality_model([lake], GBDTConfig(n_trees=30, depth=4),
                                n_query=64)
    return lake, model


@pytest.fixture(scope="module")
def catalog_dir(lake_and_model, tmp_path_factory):
    lake, _ = lake_and_model
    root = str(tmp_path_factory.mktemp("catalog"))
    catalog = ColumnCatalog(root, n_perm=128)
    add_lake(catalog, lake)
    return root


# ---------------------------------------------------------------------------
# catalog
# ---------------------------------------------------------------------------

def test_catalog_persists_and_restarts(lake_and_model, catalog_dir):
    lake, _ = lake_and_model
    reopened = ColumnCatalog(catalog_dir)            # fresh process analogue
    snap = reopened.snapshot()
    assert snap.n_columns == lake.n_columns
    assert len(snap.names) == lake.n_columns
    assert snap.signatures.shape == (lake.n_columns, 128)
    assert len(reopened.tables()) == len(np.unique(lake.batch.table_ids))
    # profiles survived the disk round-trip bit-exact
    from repro.core import profile_lake
    prof = profile_lake(lake.batch)
    # catalog ingests per-table; column order is table-major and the lake
    # generator already emits table-major order, so rows align
    np.testing.assert_allclose(snap.profiles.numeric, prof.numeric,
                               rtol=1e-5, atol=1e-5)


def test_catalog_incremental_add_drop_compact(tmp_path):
    cat = ColumnCatalog(str(tmp_path), n_perm=64)
    cat.add_table("a", [("x", [f"v{i}" for i in range(50)]),
                        ("y", [f"w{i % 7}" for i in range(50)])])
    cat.add_table("b", [("z", [f"v{i}" for i in range(30)])])
    assert cat.snapshot().n_columns == 3

    with pytest.raises(ValueError):
        cat.add_table("a", [("dup", ["1"])])         # duplicate name

    cat.drop_table("a")
    snap = cat.snapshot()
    assert snap.n_columns == 1 and snap.names == ["z"]

    n_seg_before = len(cat.manifest["segments"])
    cat.compact()
    assert len(cat.manifest["segments"]) == 1
    snap2 = cat.snapshot()
    assert snap2.n_columns == 1 and snap2.names == ["z"]
    np.testing.assert_array_equal(snap.signatures, snap2.signatures)
    # old segment dirs are gone
    segs = [d for d in os.listdir(str(tmp_path)) if d.startswith("seg-")]
    assert len(segs) == 1 and n_seg_before > 1

    with pytest.raises(KeyError):
        cat.drop_table("nope")


def test_catalog_empty_snapshot(tmp_path):
    cat = ColumnCatalog(str(tmp_path))
    snap = cat.snapshot()
    assert snap.n_columns == 0
    # engine over an empty catalog answers gracefully
    eng = DiscoveryEngine(snap, _tiny_model())
    r = eng.query(DiscoveryRequest(values=["a", "b"]))
    assert r.matches == []


def _tiny_model():
    from repro.core.gbdt import GBDTParams
    from repro.core.predictor import JoinQualityModel
    p = GBDTParams(feats=np.zeros((1, 1), np.int32),
                   thrs=np.zeros((1, 1), np.float32),
                   leaves=np.zeros((1, 2), np.float32), base=0.0)
    return JoinQualityModel(gbdt=p)


# ---------------------------------------------------------------------------
# LSH layer
# ---------------------------------------------------------------------------

def test_band_keys_shape_and_determinism(lake_and_model, catalog_dir):
    snap = ColumnCatalog(catalog_dir).snapshot()
    k1 = band_keys(snap.signatures, 64)
    k2 = band_keys(snap.signatures, 64)
    assert k1.shape == (snap.n_columns, 64)
    np.testing.assert_array_equal(k1, k2)
    # identical signatures -> identical keys; different rows differ somewhere
    assert (band_keys(snap.signatures[:1], 64) == k1[:1]).all()
    assert (k1[0] != k1[1]).any()


def test_band_keys_rejects_too_many_bands():
    sigs = np.zeros((2, 16), np.uint32)
    with pytest.raises(ValueError):
        band_keys(sigs, 32)


def test_lsh_tradeoff_is_monotone(lake_and_model, catalog_dir):
    """More bands (fewer rows per band) -> candidate sets only grow."""
    from repro.core import DiscoveryIndex, rank
    from repro.service.lsh import measure_tradeoff
    lake, model = lake_and_model
    snap = ColumnCatalog(catalog_dir).snapshot()
    idx = DiscoveryIndex(profiles=snap.profiles, model=model,
                         table_ids=snap.table_ids)
    qids = np.arange(0, min(12, snap.n_columns))
    _, top_ids = rank(idx, qids, k=10)
    curve = measure_tradeoff(snap.signatures, top_ids, qids,
                             band_choices=(16, 32, 64))
    fracs = [p["candidate_fraction"] for p in curve]
    assert fracs == sorted(fracs), curve
    recalls = [p["recall"] for p in curve]
    assert recalls[-1] >= recalls[0], curve


# ---------------------------------------------------------------------------
# engine: acceptance end-to-end
# ---------------------------------------------------------------------------

def test_end_to_end_service(lake_and_model, catalog_dir):
    """ISSUE acceptance: persist → restart → incremental add → serve a batch
    with recall@10 ≥ 0.9 vs brute force while scoring < 25% of the lake."""
    lake, model = lake_and_model

    # restart the engine from disk
    engine = DiscoveryEngine.from_catalog(
        ColumnCatalog(catalog_dir), model,
        EngineConfig(k=10, mode="lsh", lsh=LSHConfig(n_bands=64),
                     candidate_frac=0.2))
    n0 = engine.n_columns
    assert n0 == lake.n_columns

    # incremental add: a new table appears without reprofiling the lake
    catalog = ColumnCatalog(catalog_dir)
    if "incremental" not in catalog.tables():
        catalog.add_table("incremental",
                          [("inc_a", [f"v{i}" for i in range(400)]),
                           ("inc_b", [f"u{i % 13}" for i in range(200)])])
    engine.refresh(catalog.snapshot())
    assert engine.n_columns == n0 + 2

    # serve a batch; recall + pruning vs the brute-force scan
    qids = select_queries(lake, 16)
    reqs = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q))
            for q in qids]
    responses = list(serve_discovery(engine, reqs, max_batch=8))
    assert len(responses) == len(reqs)
    for r in responses:
        assert r.n_candidates < 0.25 * engine.n_columns
        assert all(np.isfinite(m.score) for m in r.matches)

    rec = measure_recall(engine, qids, k=10)
    assert rec["recall"] >= 0.9, rec
    assert rec["scored_fraction"] < 0.25, rec


def test_engine_lru_cache(lake_and_model, catalog_dir):
    lake, model = lake_and_model
    engine = DiscoveryEngine.from_catalog(ColumnCatalog(catalog_dir), model,
                                          EngineConfig(k=5))
    req = DiscoveryRequest(name="q", column_id=3)
    r1 = engine.query(req)
    r2 = engine.query(DiscoveryRequest(name="q2", column_id=3))
    assert not r1.cached and r2.cached
    assert [m.column_id for m in r1.matches] == \
           [m.column_id for m in r2.matches]
    # refresh invalidates
    engine.refresh(engine.snapshot)
    assert engine.query(req).cached is False


def test_engine_cache_eviction(lake_and_model, catalog_dir):
    lake, model = lake_and_model
    engine = DiscoveryEngine.from_catalog(
        ColumnCatalog(catalog_dir), model,
        EngineConfig(k=3, cache_entries=4))
    for cid in range(8):
        engine.query(DiscoveryRequest(column_id=cid))
    assert len(engine._cache) == 4
    assert engine.query(DiscoveryRequest(column_id=0)).cached is False
    assert engine.query(DiscoveryRequest(column_id=7)).cached is True


def test_engine_external_query_matches_resident(lake_and_model, catalog_dir):
    """Uploading a column's values finds the same neighborhood as querying
    the resident column id."""
    lake, model = lake_and_model
    engine = DiscoveryEngine.from_catalog(ColumnCatalog(catalog_dir), model,
                                          EngineConfig(k=5))
    # rebuild raw-ish strings for a resident column is impossible (the lake
    # is hash-level), so check the external path on string columns instead:
    vals_a = [f"city_{i % 60}" for i in range(600)]
    vals_b = [f"city_{i % 60}" for i in range(300)]
    catalog = ColumnCatalog(catalog_dir)
    if "strtab" not in catalog.tables():
        catalog.add_table("strtab", [("cities", vals_a)])
    engine.refresh(catalog.snapshot())
    r = engine.query(DiscoveryRequest(name="upload", values=vals_b))
    assert any(m.column == "cities" for m in r.matches), r.matches


def test_engine_full_mode_matches_core_rank(lake_and_model, catalog_dir):
    lake, model = lake_and_model
    from repro.core import DiscoveryIndex, rank
    snap = ColumnCatalog(catalog_dir).snapshot()
    engine = DiscoveryEngine(snap, model,
                             EngineConfig(k=5, mode="full"))
    idx = DiscoveryIndex(profiles=snap.profiles, model=model,
                         table_ids=snap.table_ids)
    qids = select_queries(lake, 6)
    scores, ids = rank(idx, qids, k=5)
    responses = engine.query_batch(
        [DiscoveryRequest(column_id=int(q)) for q in qids])
    for row, resp in enumerate(responses):
        got = [m.column_id for m in resp.matches]
        want = [int(i) for i, s in zip(ids[row], scores[row])
                if np.isfinite(s)]
        assert got == want


@pytest.mark.parametrize("exclude", [False, True])
def test_engine_sharded_mode(lake_and_model, catalog_dir, exclude):
    import jax
    lake, model = lake_and_model
    snap = ColumnCatalog(catalog_dir).snapshot()
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    eng_sh = DiscoveryEngine(snap, model,
                             EngineConfig(k=5, mode="sharded",
                                          exclude_same_table=exclude),
                             mesh=mesh)
    eng_full = DiscoveryEngine(snap, model,
                               EngineConfig(k=5, mode="full",
                                            exclude_same_table=exclude))
    qids = select_queries(lake, 4)
    reqs = [DiscoveryRequest(column_id=int(q)) for q in qids]
    r_sh = eng_sh.query_batch(reqs)
    r_full = eng_full.query_batch(list(reqs))
    for q, a, b in zip(qids, r_sh, r_full):
        sa = np.asarray([m.score for m in a.matches])
        sb = np.asarray([m.score for m in b.matches])
        np.testing.assert_allclose(sa, sb, rtol=1e-4, atol=1e-5)
        if exclude:
            qt = int(snap.table_ids[int(q)])
            assert all(int(snap.table_ids[m.column_id]) != qt
                       for m in a.matches)


def test_request_validation():
    with pytest.raises(ValueError):
        DiscoveryRequest()                      # neither
    with pytest.raises(ValueError):
        DiscoveryRequest(column_id=1, values=["a"])   # both


# ---------------------------------------------------------------------------
# executor-era engine surface: stats(), cost-aware cache, signature upkeep
# ---------------------------------------------------------------------------

def test_engine_stats_expose_plan_and_cache(lake_and_model, catalog_dir):
    lake, model = lake_and_model
    engine = DiscoveryEngine.from_catalog(ColumnCatalog(catalog_dir), model,
                                          EngineConfig(k=5, mode="lsh"))
    engine.query(DiscoveryRequest(column_id=1))
    engine.query(DiscoveryRequest(column_id=1))        # cache hit
    engine.query(DiscoveryRequest(column_id=2))
    s = engine.stats()
    assert s["queries"] == 3
    assert s["cache"]["hits"] == 1 and s["cache"]["misses"] == 2
    assert s["cache"]["admitted"] == 2
    assert s["plans"] == {"local-hybrid": 2}           # hits skip the planner
    assert s["last_plan"]["kind"] == "local-hybrid"
    assert s["last_plan"]["cost"]["total_flops"] > 0
    assert s["last_plan"]["budget"] == engine.candidate_budget


def test_engine_cache_cost_aware_admission(lake_and_model, catalog_dir):
    """Cheap results are refused admission when the cache is full of more
    expensive ones, and eviction removes the cheapest entry first."""
    lake, model = lake_and_model
    engine = DiscoveryEngine.from_catalog(ColumnCatalog(catalog_dir), model,
                                          EngineConfig(cache_entries=2))
    engine._cache_put(b"full-scan", ["A"], 100.0)
    engine._cache_put(b"pruned", ["B"], 40.0)
    engine._cache_put(b"cheap", ["C"], 10.0)           # < every resident cost
    assert b"cheap" not in engine._cache
    assert engine.stats()["cache"]["rejected"] == 1
    engine._cache_put(b"mid", ["D"], 60.0)             # evicts the 40.0 entry
    assert set(engine._cache) == {b"full-scan", b"mid"}
    assert engine.stats()["cache"]["evicted"] == 1
    # capacity 0 disables caching entirely
    engine2 = DiscoveryEngine.from_catalog(ColumnCatalog(catalog_dir), model,
                                           EngineConfig(cache_entries=0))
    r1 = engine2.query(DiscoveryRequest(column_id=3))
    r2 = engine2.query(DiscoveryRequest(column_id=3))
    assert not r1.cached and not r2.cached


def test_engine_auto_mode_plans_by_cost(lake_and_model, catalog_dir):
    """auto on a big lake prunes; on a tiny catalog it falls back to the
    brute scan (probe overhead beats the savings)."""
    lake, model = lake_and_model
    big = DiscoveryEngine.from_catalog(ColumnCatalog(catalog_dir), model,
                                       EngineConfig(k=10, mode="auto"))
    big.query(DiscoveryRequest(column_id=0))
    assert big.stats()["last_plan"]["kind"] == "local-hybrid"

    import tempfile
    root = tempfile.mkdtemp(prefix="freyja_tiny_")
    tiny_cat = ColumnCatalog(root, n_perm=128)
    tiny_cat.add_table("t", [("x", [f"v{i}" for i in range(40)]),
                             ("y", [f"w{i}" for i in range(40)])])
    tiny = DiscoveryEngine.from_catalog(tiny_cat, model,
                                        EngineConfig(k=10, mode="auto"))
    tiny.query(DiscoveryRequest(column_id=0))
    assert tiny.stats()["last_plan"]["kind"] == "local-all"


def test_compact_resigns_signatures(tmp_path):
    """compact(n_perm=, minhash_seed=) re-MinHashes from the stored value
    sketches instead of silently keeping stale signatures."""
    from repro.kernels import ops
    cat = ColumnCatalog(str(tmp_path), n_perm=64, minhash_seed=0)
    cat.add_table("a", [("x", [f"v{i}" for i in range(100)]),
                        ("y", [f"w{i % 9}" for i in range(50)])])
    cat.add_table("b", [("z", [f"v{i}" for i in range(40, 140)])])
    cat.drop_table("b")
    old = cat.snapshot()
    assert old.signatures.shape == (2, 64)      # b is tombstoned already

    cat.compact(n_perm=128, minhash_seed=3)
    assert cat.n_perm == 128
    snap = cat.snapshot()
    assert snap.n_columns == 2 and snap.names == ["x", "y"]
    assert snap.signatures.shape == (2, 128)
    assert snap.minhash_seed == 3
    # bit-exact vs re-MinHashing the surviving stored values
    seg = cat.manifest["segments"][0]
    vals = np.load(os.path.join(str(tmp_path), seg, "values.npy"))
    want = np.asarray(ops.minhash(vals, n_perm=128, seed=3))
    np.testing.assert_array_equal(snap.signatures, want)
    # a reopened catalog signs external queries with the new geometry
    assert ColumnCatalog(str(tmp_path)).n_perm == 128

    # a second compaction without params keeps the new signatures
    cat.compact()
    np.testing.assert_array_equal(cat.snapshot().signatures, snap.signatures)


def test_compact_resign_requires_stored_values(tmp_path):
    cat = ColumnCatalog(str(tmp_path), n_perm=64)
    cat.add_table("a", [("x", [f"v{i}" for i in range(30)])])
    seg = cat.manifest["segments"][0]
    os.remove(os.path.join(str(tmp_path), seg, "values.npy"))  # legacy seg
    with pytest.raises(ValueError, match="predate value storage"):
        cat.compact(n_perm=128)
    cat.compact()                        # plain merge still works
    assert cat.snapshot().signatures.shape == (1, 64)


def test_compact_preserves_resign_source_across_legacy_merge(tmp_path):
    """A plain compact() over a mix of legacy and value-carrying segments
    must keep the re-sign source of the segments that have one (tracked by
    a validity mask), so dropping the legacy tables later restores full
    signature maintenance without re-ingesting everything."""
    cat = ColumnCatalog(str(tmp_path), n_perm=64)
    cat.add_table("a", [("x", [f"v{i}" for i in range(30)])])
    cat.add_table("b", [("y", [f"w{i}" for i in range(20)])])
    seg_b = cat.manifest["segments"][1]
    os.remove(os.path.join(str(tmp_path), seg_b, "values.npy"))   # legacy

    cat.compact()                        # plain merge: source survives
    seg = cat.manifest["segments"][0]
    valid = np.load(os.path.join(str(tmp_path), seg, "values_valid.npy"))
    assert valid.tolist() == [True, False]
    with pytest.raises(ValueError, match="predate value storage"):
        cat.compact(n_perm=128)          # the legacy row still blocks

    cat.drop_table("b")                  # shed the legacy rows...
    cat.compact(n_perm=128, minhash_seed=5)     # ...and re-sign works again
    snap = cat.snapshot()
    assert snap.names == ["x"]
    assert snap.signatures.shape == (1, 128) and snap.minhash_seed == 5


def test_bench_sweep_blocks_smoke(lake_and_model, monkeypatch):
    """--sweep-blocks plumbing: the tile sweep times every grid point and
    records a best configuration per kernel (tiny grid, tiny lake)."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import benchmarks.bench_service as bs
    lake, model = lake_and_model
    monkeypatch.setattr(bs, "bench_lake", lambda **kw: lake)
    monkeypatch.setattr(bs, "bench_model", lambda: model)
    monkeypatch.setattr(bs, "SWEEP_BLOCK_Q", (8,))
    monkeypatch.setattr(bs, "SWEEP_BLOCK_C", (128, 256))
    monkeypatch.setattr(bs, "SWEEP_BLOCK_N", (256,))
    out = bs.sweep_block_sizes(n_queries=4, repeats=1)
    assert len(out["lsh_probe"]["grid"]) == 2
    assert len(out["fused_score"]["grid"]) == 1
    best = out["lsh_probe"]["best"]
    assert best in out["lsh_probe"]["grid"] and best["ms"] > 0
    assert best["ms"] == min(g["ms"] for g in out["lsh_probe"]["grid"])
    assert out["fused_score"]["best"]["block_n"] == 256
    assert out["n_columns"] == lake.n_columns


def test_resigned_catalog_still_serves(lake_and_model, tmp_path):
    """End-to-end: retune the LSH geometry at compaction, refresh the
    engine, and keep recall on the pruned plan."""
    lake, model = lake_and_model
    from repro.core import select_queries
    root = str(tmp_path)
    cat = ColumnCatalog(root, n_perm=64, minhash_seed=0)
    add_lake(cat, lake)
    cat.compact(n_perm=128, minhash_seed=11)
    engine = DiscoveryEngine.from_catalog(
        ColumnCatalog(root), model,
        EngineConfig(k=10, mode="lsh", lsh=LSHConfig(n_bands=64)))
    qids = select_queries(lake, 8)
    rec = measure_recall(engine, qids, k=10)
    assert rec["recall"] >= 0.9, rec
    assert rec["scored_fraction"] < 0.25, rec
