"""Online discovery service: catalog persistence, incremental maintenance,
LSH pruning quality, engine batching/caching — the acceptance end-to-end."""
import os

import numpy as np
import pytest

from repro.core import GBDTConfig, LakeSpec, generate_lake, select_queries, \
    train_quality_model
from repro.service import (ColumnCatalog, DiscoveryEngine, DiscoveryRequest,
                           EngineConfig, LSHConfig, add_lake, band_keys,
                           measure_recall, serve_discovery)


@pytest.fixture(scope="module")
def lake_and_model():
    lake = generate_lake(LakeSpec(n_domains=10, n_tables=24, row_budget=2048,
                                  rows_log_mean=6.8, coverage_range=(0.5, 1.0),
                                  gran_ratio=(4, 8), seed=7))
    model = train_quality_model([lake], GBDTConfig(n_trees=30, depth=4),
                                n_query=64)
    return lake, model


@pytest.fixture(scope="module")
def catalog_dir(lake_and_model, tmp_path_factory):
    lake, _ = lake_and_model
    root = str(tmp_path_factory.mktemp("catalog"))
    catalog = ColumnCatalog(root, n_perm=128)
    add_lake(catalog, lake)
    return root


# ---------------------------------------------------------------------------
# catalog
# ---------------------------------------------------------------------------

def test_catalog_persists_and_restarts(lake_and_model, catalog_dir):
    lake, _ = lake_and_model
    reopened = ColumnCatalog(catalog_dir)            # fresh process analogue
    snap = reopened.snapshot()
    assert snap.n_columns == lake.n_columns
    assert len(snap.names) == lake.n_columns
    assert snap.signatures.shape == (lake.n_columns, 128)
    assert len(reopened.tables()) == len(np.unique(lake.batch.table_ids))
    # profiles survived the disk round-trip bit-exact
    from repro.core import profile_lake
    prof = profile_lake(lake.batch)
    # catalog ingests per-table; column order is table-major and the lake
    # generator already emits table-major order, so rows align
    np.testing.assert_allclose(snap.profiles.numeric, prof.numeric,
                               rtol=1e-5, atol=1e-5)


def test_catalog_incremental_add_drop_compact(tmp_path):
    cat = ColumnCatalog(str(tmp_path), n_perm=64)
    cat.add_table("a", [("x", [f"v{i}" for i in range(50)]),
                        ("y", [f"w{i % 7}" for i in range(50)])])
    cat.add_table("b", [("z", [f"v{i}" for i in range(30)])])
    assert cat.snapshot().n_columns == 3

    with pytest.raises(ValueError):
        cat.add_table("a", [("dup", ["1"])])         # duplicate name

    cat.drop_table("a")
    snap = cat.snapshot()
    assert snap.n_columns == 1 and snap.names == ["z"]

    n_seg_before = len(cat.manifest["segments"])
    cat.compact()
    assert len(cat.manifest["segments"]) == 1
    snap2 = cat.snapshot()
    assert snap2.n_columns == 1 and snap2.names == ["z"]
    np.testing.assert_array_equal(snap.signatures, snap2.signatures)
    # old segment dirs are gone
    segs = [d for d in os.listdir(str(tmp_path)) if d.startswith("seg-")]
    assert len(segs) == 1 and n_seg_before > 1

    with pytest.raises(KeyError):
        cat.drop_table("nope")


def test_catalog_empty_snapshot(tmp_path):
    cat = ColumnCatalog(str(tmp_path))
    snap = cat.snapshot()
    assert snap.n_columns == 0
    # engine over an empty catalog answers gracefully
    eng = DiscoveryEngine(snap, _tiny_model())
    r = eng.query(DiscoveryRequest(values=["a", "b"]))
    assert r.matches == []


def _tiny_model():
    from repro.core.gbdt import GBDTParams
    from repro.core.predictor import JoinQualityModel
    p = GBDTParams(feats=np.zeros((1, 1), np.int32),
                   thrs=np.zeros((1, 1), np.float32),
                   leaves=np.zeros((1, 2), np.float32), base=0.0)
    return JoinQualityModel(gbdt=p)


# ---------------------------------------------------------------------------
# LSH layer
# ---------------------------------------------------------------------------

def test_band_keys_shape_and_determinism(lake_and_model, catalog_dir):
    snap = ColumnCatalog(catalog_dir).snapshot()
    k1 = band_keys(snap.signatures, 64)
    k2 = band_keys(snap.signatures, 64)
    assert k1.shape == (snap.n_columns, 64)
    np.testing.assert_array_equal(k1, k2)
    # identical signatures -> identical keys; different rows differ somewhere
    assert (band_keys(snap.signatures[:1], 64) == k1[:1]).all()
    assert (k1[0] != k1[1]).any()


def test_band_keys_rejects_too_many_bands():
    sigs = np.zeros((2, 16), np.uint32)
    with pytest.raises(ValueError):
        band_keys(sigs, 32)


def test_lsh_tradeoff_is_monotone(lake_and_model, catalog_dir):
    """More bands (fewer rows per band) -> candidate sets only grow."""
    from repro.core import DiscoveryIndex, rank
    from repro.service.lsh import measure_tradeoff
    lake, model = lake_and_model
    snap = ColumnCatalog(catalog_dir).snapshot()
    idx = DiscoveryIndex(profiles=snap.profiles, model=model,
                         table_ids=snap.table_ids)
    qids = np.arange(0, min(12, snap.n_columns))
    _, top_ids = rank(idx, qids, k=10)
    curve = measure_tradeoff(snap.signatures, top_ids, qids,
                             band_choices=(16, 32, 64))
    fracs = [p["candidate_fraction"] for p in curve]
    assert fracs == sorted(fracs), curve
    recalls = [p["recall"] for p in curve]
    assert recalls[-1] >= recalls[0], curve


# ---------------------------------------------------------------------------
# engine: acceptance end-to-end
# ---------------------------------------------------------------------------

def test_end_to_end_service(lake_and_model, catalog_dir):
    """ISSUE acceptance: persist → restart → incremental add → serve a batch
    with recall@10 ≥ 0.9 vs brute force while scoring < 25% of the lake."""
    lake, model = lake_and_model

    # restart the engine from disk
    engine = DiscoveryEngine.from_catalog(
        ColumnCatalog(catalog_dir), model,
        EngineConfig(k=10, mode="lsh", lsh=LSHConfig(n_bands=64),
                     candidate_frac=0.2))
    n0 = engine.n_columns
    assert n0 == lake.n_columns

    # incremental add: a new table appears without reprofiling the lake
    catalog = ColumnCatalog(catalog_dir)
    if "incremental" not in catalog.tables():
        catalog.add_table("incremental",
                          [("inc_a", [f"v{i}" for i in range(400)]),
                           ("inc_b", [f"u{i % 13}" for i in range(200)])])
    engine.refresh(catalog.snapshot())
    assert engine.n_columns == n0 + 2

    # serve a batch; recall + pruning vs the brute-force scan
    qids = select_queries(lake, 16)
    reqs = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q))
            for q in qids]
    responses = list(serve_discovery(engine, reqs, max_batch=8))
    assert len(responses) == len(reqs)
    for r in responses:
        assert r.n_candidates < 0.25 * engine.n_columns
        assert all(np.isfinite(m.score) for m in r.matches)

    rec = measure_recall(engine, qids, k=10)
    assert rec["recall"] >= 0.9, rec
    assert rec["scored_fraction"] < 0.25, rec


def test_engine_lru_cache(lake_and_model, catalog_dir):
    lake, model = lake_and_model
    engine = DiscoveryEngine.from_catalog(ColumnCatalog(catalog_dir), model,
                                          EngineConfig(k=5))
    req = DiscoveryRequest(name="q", column_id=3)
    r1 = engine.query(req)
    r2 = engine.query(DiscoveryRequest(name="q2", column_id=3))
    assert not r1.cached and r2.cached
    assert [m.column_id for m in r1.matches] == \
           [m.column_id for m in r2.matches]
    # refresh invalidates
    engine.refresh(engine.snapshot)
    assert engine.query(req).cached is False


def test_engine_cache_eviction(lake_and_model, catalog_dir):
    lake, model = lake_and_model
    engine = DiscoveryEngine.from_catalog(
        ColumnCatalog(catalog_dir), model,
        EngineConfig(k=3, cache_entries=4))
    for cid in range(8):
        engine.query(DiscoveryRequest(column_id=cid))
    assert len(engine._cache) == 4
    assert engine.query(DiscoveryRequest(column_id=0)).cached is False
    assert engine.query(DiscoveryRequest(column_id=7)).cached is True


def test_engine_external_query_matches_resident(lake_and_model, catalog_dir):
    """Uploading a column's values finds the same neighborhood as querying
    the resident column id."""
    lake, model = lake_and_model
    engine = DiscoveryEngine.from_catalog(ColumnCatalog(catalog_dir), model,
                                          EngineConfig(k=5))
    # rebuild raw-ish strings for a resident column is impossible (the lake
    # is hash-level), so check the external path on string columns instead:
    vals_a = [f"city_{i % 60}" for i in range(600)]
    vals_b = [f"city_{i % 60}" for i in range(300)]
    catalog = ColumnCatalog(catalog_dir)
    if "strtab" not in catalog.tables():
        catalog.add_table("strtab", [("cities", vals_a)])
    engine.refresh(catalog.snapshot())
    r = engine.query(DiscoveryRequest(name="upload", values=vals_b))
    assert any(m.column == "cities" for m in r.matches), r.matches


def test_engine_full_mode_matches_core_rank(lake_and_model, catalog_dir):
    lake, model = lake_and_model
    from repro.core import DiscoveryIndex, rank
    snap = ColumnCatalog(catalog_dir).snapshot()
    engine = DiscoveryEngine(snap, model,
                             EngineConfig(k=5, mode="full"))
    idx = DiscoveryIndex(profiles=snap.profiles, model=model,
                         table_ids=snap.table_ids)
    qids = select_queries(lake, 6)
    scores, ids = rank(idx, qids, k=5)
    responses = engine.query_batch(
        [DiscoveryRequest(column_id=int(q)) for q in qids])
    for row, resp in enumerate(responses):
        got = [m.column_id for m in resp.matches]
        want = [int(i) for i, s in zip(ids[row], scores[row])
                if np.isfinite(s)]
        assert got == want


@pytest.mark.parametrize("exclude", [False, True])
def test_engine_sharded_mode(lake_and_model, catalog_dir, exclude):
    import jax
    lake, model = lake_and_model
    snap = ColumnCatalog(catalog_dir).snapshot()
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    eng_sh = DiscoveryEngine(snap, model,
                             EngineConfig(k=5, mode="sharded",
                                          exclude_same_table=exclude),
                             mesh=mesh)
    eng_full = DiscoveryEngine(snap, model,
                               EngineConfig(k=5, mode="full",
                                            exclude_same_table=exclude))
    qids = select_queries(lake, 4)
    reqs = [DiscoveryRequest(column_id=int(q)) for q in qids]
    r_sh = eng_sh.query_batch(reqs)
    r_full = eng_full.query_batch(list(reqs))
    for q, a, b in zip(qids, r_sh, r_full):
        sa = np.asarray([m.score for m in a.matches])
        sb = np.asarray([m.score for m in b.matches])
        np.testing.assert_allclose(sa, sb, rtol=1e-4, atol=1e-5)
        if exclude:
            qt = int(snap.table_ids[int(q)])
            assert all(int(snap.table_ids[m.column_id]) != qt
                       for m in a.matches)


def test_request_validation():
    with pytest.raises(ValueError):
        DiscoveryRequest()                      # neither
    with pytest.raises(ValueError):
        DiscoveryRequest(column_id=1, values=["a"])   # both
