"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import features as FT
from repro.core.gbdt import GBDTParams
from repro.kernels import ops, ref
from repro.kernels.minhash import make_permutations, minhash_pallas
from repro.kernels.gbdt_infer import gbdt_infer_pallas
from repro.kernels.profile_distance import (fused_score_pallas,
                                            profile_distance_pallas)

RNG = np.random.default_rng(42)


def _gbdt(t, d, f, seed=0):
    r = np.random.default_rng(seed)
    return GBDTParams(feats=r.integers(0, f, (t, d)).astype(np.int32),
                      thrs=r.normal(size=(t, d)).astype(np.float32),
                      leaves=r.normal(size=(t, 2 ** d)).astype(np.float32),
                      base=float(r.normal()))


@pytest.mark.parametrize("n", [1, 7, 128, 1000, 4096])
@pytest.mark.parametrize("t,d", [(1, 1), (50, 5), (13, 6)])
def test_gbdt_infer_sweep(n, t, d):
    f = FT.F_DIST
    x = RNG.normal(size=(n, f)).astype(np.float32)
    p = _gbdt(t, d, f)
    out = gbdt_infer_pallas(jnp.asarray(x), *map(jnp.asarray, p.astuple()[:3]),
                            base=p.base, block_n=256, interpret=True)
    want = ref.gbdt_infer_ref(jnp.asarray(x), *map(jnp.asarray, p.astuple()[:3]),
                              p.base)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("q,n", [(1, 1), (3, 50), (8, 256), (11, 513)])
def test_profile_distance_sweep(q, n):
    zq = RNG.normal(size=(q, FT.F_NUM)).astype(np.float32)
    zc = RNG.normal(size=(n, FT.F_NUM)).astype(np.float32)
    wq = RNG.integers(0, 30, (q, FT.F_WORDS)).astype(np.uint32)
    wc = RNG.integers(0, 30, (n, FT.F_WORDS)).astype(np.uint32)
    wq[0, :3] = FT.HASH_SENTINEL
    out = profile_distance_pallas(*map(jnp.asarray, (zq, wq, zc, wc)),
                                  block_q=4, block_n=64, interpret=True)
    want = ref.profile_distance_ref(*map(jnp.asarray, (zq, wq, zc, wc)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("q,n,t,d", [(2, 64, 10, 4), (5, 300, 50, 5)])
def test_fused_score_sweep(q, n, t, d):
    zq = RNG.normal(size=(q, FT.F_NUM)).astype(np.float32)
    zc = RNG.normal(size=(n, FT.F_NUM)).astype(np.float32)
    wq = RNG.integers(0, 9, (q, FT.F_WORDS)).astype(np.uint32)
    wc = RNG.integers(0, 9, (n, FT.F_WORDS)).astype(np.uint32)
    p = _gbdt(t, d, FT.F_DIST)
    out = fused_score_pallas(*map(jnp.asarray, (zq, wq, zc, wc)),
                             *map(jnp.asarray, p.astuple()[:3]), base=p.base,
                             block_q=4, block_n=128, interpret=True)
    want = ref.fused_score_ref(*map(jnp.asarray, (zq, wq, zc, wc)),
                               *map(jnp.asarray, p.astuple()[:3]), p.base)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("c,r,p", [(1, 10, 16), (7, 700, 64), (16, 1024, 128)])
def test_minhash_sweep(c, r, p):
    vals = RNG.integers(0, 5000, (c, r)).astype(np.uint32)
    vals[0, r // 2:] = FT.HASH_SENTINEL
    a, b = make_permutations(p, seed=3)
    out = minhash_pallas(jnp.asarray(vals), a, b, block_c=4, block_r=128,
                         interpret=True)
    want = ref.minhash_ref(jnp.asarray(vals), a, b)
    assert (np.asarray(out) == np.asarray(want)).all()


@pytest.mark.parametrize("q,c,b", [(1, 1, 4), (3, 100, 16), (8, 512, 64),
                                   (11, 777, 32)])
def test_lsh_probe_sweep(q, c, b):
    from repro.kernels.lsh_probe import lsh_probe_pallas
    qk = RNG.integers(0, 50, (q, b)).astype(np.uint32)   # small key space
    ck = RNG.integers(0, 50, (c, b)).astype(np.uint32)   # -> plenty of hits
    ck[-1, 0] = qk[0, 0]                                 # guaranteed hit
    out = lsh_probe_pallas(jnp.asarray(qk), jnp.asarray(ck),
                           block_q=4, block_c=128, interpret=True)
    want = ref.lsh_probe_ref(jnp.asarray(qk), jnp.asarray(ck))
    assert (np.asarray(out) == np.asarray(want)).all()
    assert np.asarray(out).any()                         # sweep isn't vacuous


def test_minhash_jaccard_estimator():
    """Signatures estimate set Jaccard within MinHash sampling error."""
    n = 4000
    a = np.arange(n, dtype=np.uint32)
    b = np.arange(n // 2, n + n // 2, dtype=np.uint32)   # true J = 1/3
    sig = ops.minhash(np.stack([a, b]), n_perm=256)
    est = float(ref.minhash_jaccard_ref(sig[0], sig[1]))
    assert abs(est - 1 / 3) < 0.08


@pytest.mark.parametrize("shape", [(5,), (64,), (1000,), (7, 13)])
@pytest.mark.parametrize("s", [0.0, 0.25, 0.5])
def test_quality_cdf_sweep(shape, s):
    j = RNG.uniform(0, 0.5, shape).astype(np.float32)
    k = RNG.uniform(0, 1, shape).astype(np.float32)
    out = ops.quality_cdf(j, k, strictness=s)
    want = ref.quality_cdf_ref(jnp.asarray(j), jnp.asarray(k), s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
