"""AOT bucket-ladder warmup and the persistent executable cache.

Covers the zero-compile serving contract end-to-end: a warmed engine
serves every ladder bucket through AOT-dispatched executables (no compile
events, no ``compile_ms`` trace attribution, zero fallback dispatches); a
restarted engine re-warms from the on-disk cache without compiling;
signature drift (jax version, device kind, device count) and corrupt
entries degrade to fresh compiles; two engines share one cache directory;
the scheduler holds batch dispatch while a warmup runs; and the
lazy-snapshot + quantized-sidecar path streams the sidecar off the memmap
without ever materializing the lake-sized fp32 z-score matrix.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

import repro.core.profiles as core_profiles
from repro.core import GBDTConfig, LakeSpec, generate_lake, train_quality_model
from repro.exec import CANDIDATE_KINDS, ExecutableCache, environment_signature
from repro.kernels.profile_distance import (quantize_profiles,
                                            quantize_profiles_streamed)
from repro.service import (ColumnCatalog, DiscoveryEngine, DiscoveryRequest,
                           EngineConfig, LSHConfig, RequestScheduler,
                           SchedulerConfig, add_lake)

BUCKETS = (4, 8)


@pytest.fixture(scope="module")
def warm_lake():
    return generate_lake(LakeSpec(n_domains=6, n_tables=10, row_budget=512,
                                  seed=5))


@pytest.fixture(scope="module")
def model(warm_lake):
    return train_quality_model([warm_lake], GBDTConfig(n_trees=10, depth=3),
                               n_query=32)


@pytest.fixture(scope="module")
def catalog_dir(tmp_path_factory, warm_lake):
    root = str(tmp_path_factory.mktemp("warm_catalog"))
    cat = ColumnCatalog(root)
    add_lake(cat, warm_lake)
    cat.compact()          # single segment: the lazy fast path needs it
    return root


def _config(**kw):
    kw.setdefault("k", 4)
    kw.setdefault("mode", "lsh")
    kw.setdefault("lsh", LSHConfig(n_bands=16, n_coarse_bands=4))
    kw.setdefault("batch_buckets", BUCKETS)
    return EngineConfig(**kw)


def _engine(catalog_dir, model, **kw):
    return DiscoveryEngine.from_catalog(ColumnCatalog(catalog_dir), model,
                                        _config(**kw))


def _reqs(n):
    return [DiscoveryRequest(name=f"q{i}", column_id=i) for i in range(n)]


def _match_rows(responses):
    return [[(m.column_id, round(m.score, 5)) for m in r.matches]
            for r in responses]


# ---------------------------------------------------------------------------
# warmed serving: no compiles on the request path
# ---------------------------------------------------------------------------

def test_warmed_engine_serves_every_bucket_without_compiles(
        catalog_dir, model, tmp_path):
    eng = _engine(catalog_dir, model, metrics=True, warmup="serve",
                  executable_cache_dir=str(tmp_path / "cache"))
    rep = eng.warmup_report
    assert rep is not None and eng.warm_event.is_set()
    assert rep["scope"] == "serve" and rep["buckets"] == list(BUCKETS)
    assert rep["n_executables"] > 0
    assert rep["cache_misses"] == rep["n_executables"]  # cold start
    assert rep["wall_ms"] > 0

    cursor = eng.events.subscribe("test")     # tails only post-warmup events
    for b in BUCKETS:
        for r in eng.query_batch(_reqs(b)):
            assert not any("compile_ms" in s for s in r.trace), r.trace
    types = [ev.type for ev in cursor.poll()]
    assert "compile_begin" not in types and "compile_end" not in types
    stats = eng._executor.dispatch_stats()
    assert stats["fallback"] == 0 and stats["aot"] > 0


def test_warmup_installs_default_ladder_when_none(catalog_dir, model):
    eng = _engine(catalog_dir, model, batch_buckets=None)
    assert not eng.planner.config.batch_buckets
    rep = eng.warmup("serve")
    from repro.exec import DEFAULT_BATCH_BUCKETS
    assert tuple(eng.planner.config.batch_buckets) == DEFAULT_BATCH_BUCKETS
    assert rep["buckets"] == sorted(DEFAULT_BATCH_BUCKETS)


# ---------------------------------------------------------------------------
# persistent cache: restart, invalidation, corruption, sharing
# ---------------------------------------------------------------------------

def test_restart_reuses_persisted_executables(catalog_dir, model, tmp_path):
    cache_dir = str(tmp_path / "cache")
    e1 = _engine(catalog_dir, model, warmup="serve",
                 executable_cache_dir=cache_dir)
    r1 = e1.warmup_report
    assert r1["cache_misses"] == r1["n_executables"] > 0

    e2 = _engine(catalog_dir, model, warmup="serve",
                 executable_cache_dir=cache_dir)
    r2 = e2.warmup_report
    assert r2["cache_misses"] == 0
    assert r2["cache_hits"] == r1["n_executables"]
    # deserialized executables produce the compiled executables' results
    out1 = _match_rows(e1.query_batch(_reqs(BUCKETS[0])))
    out2 = _match_rows(e2.query_batch(_reqs(BUCKETS[0])))
    assert out1 == out2
    assert e2._executor.dispatch_stats()["fallback"] == 0


@pytest.mark.parametrize("drift", [{"jax": "0.0.0-different"},
                                   {"device_kind": "TPU v9"},
                                   {"n_devices": 1234}])
def test_environment_drift_invalidates_entries(catalog_dir, model, tmp_path,
                                               drift):
    cache_dir = str(tmp_path / "cache")
    e1 = _engine(catalog_dir, model, warmup="serve",
                 executable_cache_dir=cache_dir)
    n = e1.warmup_report["n_executables"]

    e2 = _engine(catalog_dir, model)
    e2._exec_cache = ExecutableCache(
        cache_dir, env={**environment_signature(), **drift})
    rep = e2.warmup("serve")
    assert rep["cache_hits"] == 0 and rep["cache_misses"] == n


def test_corrupt_entries_fall_back_to_fresh_compiles(catalog_dir, model,
                                                     tmp_path):
    cache_dir = tmp_path / "cache"
    e1 = _engine(catalog_dir, model, warmup="serve",
                 executable_cache_dir=str(cache_dir))
    n = e1.warmup_report["n_executables"]
    entries = list(cache_dir.glob("*.exe"))
    assert len(entries) == n
    for p in entries:
        p.write_bytes(b"not a pickled executable")

    e2 = _engine(catalog_dir, model, warmup="serve",
                 executable_cache_dir=str(cache_dir))
    rep = e2.warmup_report
    assert rep["cache_hits"] == 0 and rep["cache_misses"] == n
    assert e2._exec_cache.stats["errors"] >= n
    out1 = _match_rows(e1.query_batch(_reqs(BUCKETS[0])))
    out2 = _match_rows(e2.query_batch(_reqs(BUCKETS[0])))
    assert out1 == out2
    # the fresh compiles re-stored good entries: a third start hits
    e3 = _engine(catalog_dir, model, warmup="serve",
                 executable_cache_dir=str(cache_dir))
    assert e3.warmup_report["cache_hits"] == n


def test_two_engines_share_one_cache_dir(catalog_dir, model, tmp_path):
    cache_dir = str(tmp_path / "cache")
    engines, errors = [None, None], []

    def boot(slot):
        try:
            engines[slot] = _engine(catalog_dir, model, warmup="serve",
                                    executable_cache_dir=cache_dir)
        except BaseException as e:   # surfaced in the main thread below
            errors.append(e)

    threads = [threading.Thread(target=boot, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errors
    reps = [e.warmup_report for e in engines]
    for rep in reps:
        assert rep["cache_hits"] + rep["cache_misses"] + \
            rep["already_warm"] == rep["n_executables"]
    outs = [_match_rows(e.query_batch(_reqs(BUCKETS[0]))) for e in engines]
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# scheduler integration + metrics
# ---------------------------------------------------------------------------

def test_scheduler_holds_dispatch_until_warm(catalog_dir, model):
    eng = _engine(catalog_dir, model)
    with RequestScheduler(eng, SchedulerConfig(batch_buckets=BUCKETS,
                                               max_wait_ms=1.0)) as sch:
        eng.warm_event.clear()       # a warmup is "running"
        fut = sch.submit(DiscoveryRequest(name="held", column_id=0))
        time.sleep(0.25)
        assert not fut.done()
        eng.warm_event.set()
        assert fut.result(timeout=30).name == "held"
        assert sch.stats()["warm_held"] >= 1


def test_warmup_metrics_and_exposition(catalog_dir, model, tmp_path):
    eng = _engine(catalog_dir, model, metrics=True, warmup="serve",
                  executable_cache_dir=str(tmp_path / "cache"))
    rep = eng.warmup_report
    snap = eng.metrics.collect()
    assert snap["warmups_total"]["values"][""] == 1.0
    assert snap["executable_cache_misses_total"]["values"][""] == \
        rep["cache_misses"]
    assert snap["warmup_remaining"]["values"][""] == 0.0
    # warmup compiles land in the same compile_ms histogram first-contact
    # serving compiles feed
    assert snap["compile_ms"]["values"]["count"] == rep["cache_misses"]
    from repro.service.metrics import parse_exposition
    parsed = parse_exposition(eng.metrics.render())
    assert "warmup_remaining" in parsed
    assert parsed["executable_cache_misses_total"][""] == rep["cache_misses"]


def test_refresh_rewarms_new_version(catalog_dir, model, tmp_path):
    eng = _engine(catalog_dir, model, metrics=True, warmup="serve",
                  batch_buckets=(4,),
                  executable_cache_dir=str(tmp_path / "cache"))
    writer = ColumnCatalog(catalog_dir)
    if "warm_refresh_demo" not in writer.tables():
        writer.add_table("warm_refresh_demo",
                         [("ids", [f"wr_{i}" for i in range(50)])])
    eng.refresh(ColumnCatalog(catalog_dir).snapshot())
    assert eng.warm_event.is_set()
    assert eng.warmup_report["n_executables"] > 0
    cursor = eng.events.subscribe("test")
    for r in eng.query_batch(_reqs(4)):
        assert not any("compile_ms" in s for s in r.trace)
    types = [ev.type for ev in cursor.poll()]
    assert "compile_begin" not in types


# ---------------------------------------------------------------------------
# plan_set enumeration
# ---------------------------------------------------------------------------

def test_plan_set_serve_scope_covers_served_and_baseline(catalog_dir, model):
    eng = _engine(catalog_dir, model)
    plans = eng.planner.plan_set(n_columns=eng.n_columns, n_queries=4,
                                 mode="lsh", scope="serve")
    kinds = {p.candidates for p in plans}
    assert "all" in kinds            # the recall baseline rides along
    assert len(kinds) == len(plans) == 2


def test_plan_set_full_scope_enumerates_admissible_kinds(catalog_dir, model):
    eng = _engine(catalog_dir, model)
    plans = eng.planner.plan_set(n_columns=eng.n_columns, n_queries=4,
                                 mode="lsh", scope="full")
    kinds = {p.candidates for p in plans}
    assert kinds.issuperset(set(CANDIDATE_KINDS) & {"all", "lsh", "hybrid"})
    assert "tiered" in kinds         # n_coarse_bands > 0 admits it
    keys = [(p.candidates, p.sharded, p.budget, p.k, p.grid,
             p.survivor_budget) for p in plans]
    assert len(keys) == len(set(keys))          # deduped
    with pytest.raises(ValueError):
        eng.planner.plan_set(n_columns=eng.n_columns, scope="everything")


# ---------------------------------------------------------------------------
# lazy snapshots: streamed quantized sidecar, no eager z-score pass
# ---------------------------------------------------------------------------

def test_streamed_quantizer_matches_eager_bytes(catalog_dir):
    prof = ColumnCatalog(catalog_dir).snapshot().profiles
    z = prof.zscored.astype(np.float32)
    for dt in ("int8", "fp16", "fp32"):
        a, sa = quantize_profiles(z, dt)
        b, sb = quantize_profiles_streamed(prof.numeric, prof.mean,
                                           prof.std, dt, block=17)
        assert a.dtype == b.dtype
        assert np.array_equal(a, b) and np.array_equal(sa, sb)
    with pytest.raises(ValueError):
        quantize_profiles_streamed(prof.numeric, prof.mean, prof.std, "int4")


def test_lazy_int8_engine_never_materializes_zscores(catalog_dir, model,
                                                     monkeypatch):
    cat = ColumnCatalog(catalog_dir)
    cat.compact()       # back to one segment (an earlier test may append)
    snap = cat.snapshot(lazy=True)
    assert snap.lazy
    # same arrays + moments through the legacy eager build path
    legacy = dataclasses.replace(snap, lazy=False)

    def boom(self):
        raise AssertionError("lazy path materialized the fp32 z-score "
                             "matrix")

    monkeypatch.setattr(core_profiles.LakeProfiles, "zscored",
                        property(boom))
    e_lazy = DiscoveryEngine(snap, model, _config(profile_dtype="int8"))
    lazy_out = _match_rows(e_lazy.query_batch(_reqs(6)))
    monkeypatch.undo()

    e_legacy = DiscoveryEngine(legacy, model, _config(profile_dtype="int8"))
    assert lazy_out == _match_rows(e_legacy.query_batch(_reqs(6)))


def test_zscore_view_indexing(catalog_dir):
    prof = ColumnCatalog(catalog_dir).snapshot().profiles
    view = prof.zscored_view()
    full = prof.zscored.astype(np.float32)
    assert view.shape == full.shape and len(view) == full.shape[0]
    assert np.array_equal(view[3], full[3])
    idx2d = np.array([[0, 2], [5, 1]])
    assert np.array_equal(view[idx2d], full[idx2d])
    assert view[idx2d].dtype == np.float32
