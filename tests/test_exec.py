"""Unified query-execution layer: plan selection, stage semantics, executor
parity, and the distributed-LSH acceptance path on a forced 8-device host
mesh (subprocess: jax device count must be set before first init)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.exec import Planner, PlannerConfig, QueryPlan

ROOT = os.path.join(os.path.dirname(__file__), "..")


class _FakeMesh:
    """Planner only reads mesh.shape — keep plan tests jax-free."""

    def __init__(self, **shape):
        self.shape = shape


# ---------------------------------------------------------------------------
# planner: mode mapping + thresholds
# ---------------------------------------------------------------------------

def test_plan_mode_mapping():
    p = Planner(PlannerConfig(k=10))
    mesh = _FakeMesh(data=8, model=1)
    assert p.plan(n_columns=1000, mode="full").kind == "local-all"
    assert p.plan(n_columns=1000, mode="lsh").kind == "local-hybrid"
    assert p.plan(n_columns=1000, mode="lsh", mesh=mesh).kind == \
        "sharded-hybrid"
    assert p.plan(n_columns=1000, mode="sharded", mesh=mesh).kind == \
        "sharded-all"
    with pytest.raises(ValueError):
        p.plan(n_columns=1000, mode="sharded")          # sharded needs a mesh
    with pytest.raises(ValueError):
        p.plan(n_columns=1000, mode="warp")


def test_plan_auto_lake_size_threshold():
    """Tiny lakes: probe+proxy overhead exceeds the pruning savings, the
    cost model must fall back to the brute scan; big lakes must prune
    (hybrid probe or, once the lake dwarfs the survivor budget, the
    tiered coarse-digest pipeline)."""
    p = Planner(PlannerConfig(k=10))
    pruned = ("hybrid", "tiered")
    assert p.plan(n_columns=12, mode="auto").candidates == "all"
    assert p.plan(n_columns=4096, mode="auto").candidates in pruned
    # the crossover is monotone: once pruning wins it keeps winning
    kinds = [p.plan(n_columns=n, mode="auto").candidates
             for n in (8, 64, 512, 4096, 32768)]
    first_pruned = next(i for i, c in enumerate(kinds) if c in pruned)
    assert all(c in pruned for c in kinds[first_pruned:]), kinds
    # without a coarse digest the tier is not a contender
    p0 = Planner(PlannerConfig(k=10, n_coarse_bands=0))
    for n in (8, 64, 512, 4096, 32768):
        assert p0.plan(n_columns=n, mode="auto").candidates != "tiered"


def test_plan_auto_mesh_threshold():
    """Sharding in auto mode is gated on columns-per-shard: a small lake on
    a big mesh stays local, a big lake shards."""
    p = Planner(PlannerConfig(k=10, min_columns_per_shard=64))
    mesh = _FakeMesh(data=8, model=1)
    small = p.plan(n_columns=100, mode="auto", mesh=mesh)
    big = p.plan(n_columns=10_000, mode="auto", mesh=mesh)
    assert not small.sharded and small.n_shards == 1
    assert big.sharded and big.n_shards == 8


def test_plan_budget_clamps():
    p = Planner(PlannerConfig(k=10, candidate_frac=0.2, max_candidates=100))
    assert p.plan(n_columns=20, mode="lsh").budget == 10      # k floor
    assert p.plan(n_columns=200, mode="lsh").budget == 40     # frac
    assert p.plan(n_columns=10_000, mode="lsh").budget == 100  # cap
    assert p.plan(n_columns=5, mode="lsh").budget == 5        # lake size
    # full-scan plans see the whole lake
    assert p.plan(n_columns=200, mode="full").budget == 200


def test_plan_budget_per_shard_and_cost():
    p = Planner(PlannerConfig(k=10, max_candidates=4096))
    mesh = _FakeMesh(data=8, model=1)
    plan = p.plan(n_columns=10_000, mode="lsh", mesh=mesh)
    assert plan.budget_per_shard == -(-plan.budget // 8)
    assert plan.cost["n_shards"] == 8
    assert plan.cost["total_collective_bytes"] > 0           # the all_gather
    local = p.plan(n_columns=10_000, mode="lsh")
    assert local.cost["total_collective_bytes"] == 0.0
    # pruning must model cheaper than the brute scan at this size
    full = p.plan(n_columns=10_000, mode="full")
    assert plan.cost["total_flops"] < full.cost["total_flops"]
    assert set(plan.cost["stages"]) == {"candidates", "score", "merge"}


def test_plan_rejects_unknown_candidate_kind():
    with pytest.raises(ValueError):
        QueryPlan(candidates="psychic", sharded=False, budget=1, k=1)


def test_planner_cost_fn_hook_is_used():
    calls = []

    def fake_cost(nq, nc, **kw):
        calls.append(kw["candidates"])
        # force the opposite decision: make pruning look expensive
        return {"total_flops": 1e18 if kw["candidates"] != "all" else 1.0}

    p = Planner(PlannerConfig(k=10), cost_fn=fake_cost)
    plan = p.plan(n_columns=100_000, mode="auto")
    assert plan.candidates == "all"
    assert "all" in calls and "hybrid" in calls


def test_planner_prefers_measured_total_cost():
    """A calibrated cost_fn reports seconds as total_cost; auto mode must
    decide on it, not on the (contradicting) flop counts."""
    def measured(nq, nc, **kw):
        pruned = kw["candidates"] != "all"
        # flops say "prune"; the measured seconds say the probe dominates
        return {"total_flops": 1.0 if pruned else 1e9,
                "total_cost": 5.0 if pruned else 0.1}

    p = Planner(PlannerConfig(k=10), cost_fn=measured)
    assert p.plan(n_columns=100_000, mode="auto").candidates == "all"


# ---------------------------------------------------------------------------
# calibrated cost model (launch.costmodel.calibrate_stage_costs)
# ---------------------------------------------------------------------------

def _synthetic_bench_record(score_s_per_flop=2e-9, cand_s_per_flop=5e-10,
                            merge_s_per_flop=1e-9, fixed_s=2e-4):
    """A BENCH_service.json-shaped record whose timings follow known
    per-stage constants exactly."""
    from repro.launch.costmodel import discovery_stage_costs
    lakes = []
    for c in (128, 512, 2048, 8192):
        modes = {}
        for mode, cand, budget in (("full", "all", c),
                                   ("lsh", "hybrid", max(10, c // 5))):
            stg = discovery_stage_costs(1, c, budget=budget,
                                        candidates=cand)["stages"]
            s = (fixed_s + cand_s_per_flop * stg["candidates"]["flops"]
                 + score_s_per_flop * stg["score"]["flops"]
                 + merge_s_per_flop * stg["merge"]["flops"])
            modes[mode] = {
                "plan": f"local-{cand}", "plan_budget": budget,
                "batch_ms_per_query": s * 1e3,
            }
        lakes.append({"n_columns": c, "modes": modes})
    return {"lakes": lakes}


def test_calibrate_recovers_planted_constants(tmp_path):
    import json

    from repro.launch.costmodel import calibrate_stage_costs
    record = _synthetic_bench_record()
    path = tmp_path / "BENCH_service.json"
    path.write_text(json.dumps(record))

    constants, cost_fn = calibrate_stage_costs(str(path))
    assert constants["r2"] > 0.999, constants
    assert np.isclose(constants["score_s_per_flop"], 2e-9, rtol=0.05)
    assert np.isclose(constants["fixed_s_per_query"], 2e-4, rtol=0.05)

    c = cost_fn(4, 10_000, budget=2000, candidates="hybrid")
    assert c["calibrated"] and c["total_cost"] > 0
    assert "total_flops" in c            # still a superset of the analytic

    # end-to-end: the planner decides on the measured crossover — on this
    # host pruning wins (hybrid, or tiered once the coarse digest beats
    # the full-lake probe), but a probe-hostile measurement flips the
    # same lake to the brute scan (the analytic flops alone never would)
    p = Planner(PlannerConfig(k=10), cost_fn=cost_fn)
    assert p.plan(n_columns=50_000, mode="auto").candidates in \
        ("hybrid", "tiered")
    _, hostile = calibrate_stage_costs(
        _synthetic_bench_record(cand_s_per_flop=1e-7))
    p2 = Planner(PlannerConfig(k=10), cost_fn=hostile)
    assert p2.plan(n_columns=50_000, mode="auto").candidates == "all"


def test_calibrate_needs_enough_observations():
    from repro.launch.costmodel import calibrate_stage_costs
    with pytest.raises(ValueError, match="observations"):
        calibrate_stage_costs({"lakes": [
            {"n_columns": 10,
             "modes": {"full": {"plan": "local-all", "plan_budget": 10,
                                "batch_ms_per_query": 1.0}}}]})


def test_engine_accepts_calibrated_cost_fn(tmp_path):
    """EngineConfig.cost_fn reaches the planner: a measured model that
    makes pruning look slow flips auto mode to the brute scan."""
    from repro.service import CatalogStore, DiscoveryEngine, DiscoveryRequest, \
        EngineConfig

    def probe_hostile(nq, nc, **kw):
        pruned = kw["candidates"] != "all"
        return {"total_flops": float(nc), "n_queries": nq,
                "total_cost": 9.0 if pruned else 1.0}

    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("t", [("x", [f"v{i}" for i in range(300)]),
                          ("y", [f"w{i}" for i in range(300)])])
    from repro.core import GBDTConfig, LakeSpec, generate_lake, \
        train_quality_model
    lake = generate_lake(LakeSpec(n_domains=4, n_tables=6, row_budget=256,
                                  rows_log_mean=5.0, seed=1))
    model = train_quality_model([lake], GBDTConfig(n_trees=10, depth=3),
                                n_query=16)
    engine = DiscoveryEngine.from_catalog(
        store, model, EngineConfig(k=3, mode="auto",
                                   cost_fn=probe_hostile))
    engine.query(DiscoveryRequest(column_id=0))
    assert engine.stats()["last_plan"]["kind"] == "local-all"


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------

def test_exclusion_mask_semantics():
    import jax.numpy as jnp
    from repro.exec.stages import exclusion_mask
    cids = jnp.asarray([0, 1, 2, -1])        # last column is padding
    tids = jnp.asarray([7, 7, 8, -2])
    tq = jnp.asarray([7, -1])                # row 1: table mask disabled
    qid = jnp.asarray([2, -1])               # row 1: external query
    m = np.asarray(exclusion_mask(cids, tids, tq, qid))
    assert m.tolist() == [[True, True, True, True],     # table 7 + self + pad
                          [False, False, False, True]]  # only padding


def test_merge_topk_id_conventions():
    import jax.numpy as jnp
    from repro.exec.stages import merge_topk
    s = jnp.asarray([[1.0, -jnp.inf, 3.0]])
    cids = jnp.asarray([10, 11, 12])
    sc, ids = merge_topk(s, cids, k=3)
    assert ids.tolist() == [[12, 10, -1]]               # -inf slot -> -1
    # per-query 2-D candidate ids (gathered sets)
    sc2, ids2 = merge_topk(s, jnp.asarray([[10, 11, 12]]), k=2)
    assert ids2.tolist() == [[12, 10]]


def test_candidate_priorities_lsh_vs_hybrid(rng):
    import jax.numpy as jnp
    from repro.exec.stages import candidate_priorities
    c, b, f = 16, 8, 21
    ckeys = rng.integers(0, 2**31, (c, b)).astype(np.uint32)
    qkeys = np.full((1, b), 0xAAAA, np.uint32)
    qkeys[0, 0] = ckeys[3, 0]                # bucket hit on column 3 only
    z = rng.normal(size=(c, f)).astype(np.float32)
    zq = z[3:4]
    cids = jnp.arange(c)
    tids = jnp.zeros((c,), jnp.int32)
    tq = jnp.asarray([-1])
    qid = jnp.asarray([-1])
    lsh = np.asarray(candidate_priorities("lsh", jnp.asarray(zq), qkeys, z,
                                          ckeys, cids, tids, tq, qid))
    assert np.isfinite(lsh[0, 3]) and np.isinf(lsh[0, :3]).all()
    hyb = np.asarray(candidate_priorities("hybrid", jnp.asarray(zq), qkeys,
                                          z, ckeys, cids, tids, tq, qid))
    assert np.isfinite(hyb).all()            # proxy fills the whole lake
    assert hyb[0].argmax() == 3              # the bucket hit still outranks
    with pytest.raises(ValueError):
        candidate_priorities("nope", jnp.asarray(zq), qkeys, z, ckeys, cids,
                             tids, tq, qid)


# ---------------------------------------------------------------------------
# executor (single device)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def exec_setup():
    from repro.core import (GBDTConfig, LakeSpec, generate_lake, profile_lake,
                            train_quality_model)
    from repro.exec import Executor
    from repro.service.lsh import band_keys
    from repro.kernels import ops
    lake = generate_lake(LakeSpec(n_domains=10, n_tables=24, row_budget=2048,
                                  rows_log_mean=6.8, coverage_range=(0.5, 1.0),
                                  gran_ratio=(4, 8), seed=7))
    prof = profile_lake(lake.batch)
    model = train_quality_model([lake], GBDTConfig(n_trees=30, depth=4),
                                n_query=64)
    sigs = np.asarray(ops.minhash(lake.batch.values32, n_perm=128, seed=0))
    keys = band_keys(sigs, 64)
    ex = Executor(prof.zscored, prof.words, model.gbdt.astuple(),
                  table_ids=lake.table, band_keys=keys)
    return lake, prof, model, ex, keys


def test_executor_full_matches_rank(exec_setup):
    from repro.core import DiscoveryIndex, rank, select_queries
    lake, prof, model, ex, keys = exec_setup
    idx = DiscoveryIndex(profiles=prof, model=model, table_ids=lake.table)
    qids = select_queries(lake, 6)
    plan = Planner(PlannerConfig(k=5)).plan(n_columns=lake.n_columns,
                                            mode="full")
    zq = prof.zscored[qids].astype(np.float32)
    tq = lake.table[qids].astype(np.int32)
    sc, ids, n = ex.execute(plan, zq, prof.words[qids], tq,
                            qids.astype(np.int32))
    s_ref, i_ref = rank(idx, qids, k=5, exclude_same_table=True)
    np.testing.assert_allclose(sc, s_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(ids, i_ref)
    assert (n == lake.n_columns).all()


def test_executor_pruned_recall_and_accounting(exec_setup):
    from repro.core import select_queries
    lake, prof, model, ex, keys = exec_setup
    qids = select_queries(lake, 8)
    planner = Planner(PlannerConfig(k=10, candidate_frac=0.2))
    zq = prof.zscored[qids].astype(np.float32)
    wq = prof.words[qids]
    tq = np.full(len(qids), -1, np.int32)
    qid = qids.astype(np.int32)
    full = planner.plan(n_columns=lake.n_columns, mode="full")
    hyb = planner.plan(n_columns=lake.n_columns, mode="lsh")
    fs, fi, _ = ex.execute(full, zq, wq, tq, qid)
    hs, hi, hn = ex.execute(hyb, zq, wq, tq, qid, qkeys=keys[qids])
    assert (hn <= hyb.budget).all()                  # honest accounting
    rec = np.mean([len(set(a[a >= 0]) & set(b[b >= 0])) /
                   max((b >= 0).sum(), 1) for a, b in zip(hi, fi)])
    assert rec >= 0.9, rec
    # pure-LSH plan scores only bucket hits: strictly fewer than the budget
    lsh = QueryPlan(candidates="lsh", sharded=False, budget=hyb.budget, k=10)
    _, _, ln = ex.execute(lsh, zq, wq, tq, qid, qkeys=keys[qids])
    assert (ln <= hn).all()


def test_executor_missing_keys_raise(exec_setup):
    from repro.exec import Executor
    lake, prof, model, ex, keys = exec_setup
    bare = Executor(prof.zscored, prof.words, model.gbdt.astuple())
    plan = Planner(PlannerConfig(k=3)).plan(n_columns=lake.n_columns,
                                            mode="lsh")
    z1 = prof.zscored[:1].astype(np.float32)
    args = (z1, prof.words[:1], np.asarray([-1], np.int32),
            np.asarray([0], np.int32))
    with pytest.raises(ValueError):
        bare.execute(plan, *args)                    # no corpus band keys
    with pytest.raises(ValueError):
        ex.execute(plan, *args)                      # no query band keys
    with pytest.raises(ValueError):
        plan_sh = QueryPlan(candidates="all", sharded=True,
                            budget=lake.n_columns, k=3)
        ex.execute(plan_sh, *args)                   # no mesh


def test_executor_empty_corpus():
    from repro.core.gbdt import GBDTParams
    from repro.exec import Executor
    gb = GBDTParams(feats=np.zeros((1, 1), np.int32),
                    thrs=np.zeros((1, 1), np.float32),
                    leaves=np.zeros((1, 2), np.float32), base=0.0)
    from repro.core import features as FT
    ex = Executor(np.zeros((0, FT.F_NUM), np.float32),
                  np.zeros((0, FT.F_WORDS), np.uint32), gb.astuple())
    plan = Planner(PlannerConfig(k=4)).plan(n_columns=0, mode="full")
    sc, ids, n = ex.execute(plan, np.zeros((2, FT.F_NUM), np.float32),
                            np.zeros((2, FT.F_WORDS), np.uint32),
                            np.full((2,), -1, np.int32),
                            np.full((2,), -1, np.int32))
    assert sc.shape == (2, 4) and (ids == -1).all() and (n == 0).all()


# ---------------------------------------------------------------------------
# acceptance: distributed LSH on 8 host devices (subprocess)
# ---------------------------------------------------------------------------

def test_sharded_lsh_acceptance_8dev():
    """ISSUE acceptance: mode="lsh" end-to-end on an 8-device mesh —
    per-device bucket probe + single all_gather, recall@10 ≥ 0.9 vs the
    sharded full scan while scoring ≤ 30% of lake columns; plus
    sharded-vs-local LSH parity on the same snapshot."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = """
        import numpy as np, jax
        from repro.core import (GBDTConfig, LakeSpec, generate_lake,
                                select_queries, train_quality_model)
        from repro.core.lakegen import Lake
        from repro.service import (DiscoveryEngine, DiscoveryRequest,
                                   EngineConfig, LSHConfig, measure_recall)
        from repro.service.catalog import CatalogSnapshot, ColumnCatalog, \\
            add_lake
        import tempfile

        assert len(jax.devices()) == 8
        lake = generate_lake(LakeSpec(n_domains=10, n_tables=24,
                                      row_budget=2048, rows_log_mean=6.8,
                                      coverage_range=(0.5, 1.0),
                                      gran_ratio=(4, 8), seed=7))
        model = train_quality_model([lake], GBDTConfig(n_trees=30, depth=4),
                                    n_query=64)
        root = tempfile.mkdtemp(prefix="freyja_shlsh_")
        add_lake(ColumnCatalog(root, n_perm=128), lake)
        snap = ColumnCatalog(root).snapshot()

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        cfg = dict(k=10, lsh=LSHConfig(n_bands=64), candidate_frac=0.2)
        eng_sh = DiscoveryEngine(snap, model,
                                 EngineConfig(mode="lsh", **cfg), mesh=mesh)
        eng_lo = DiscoveryEngine(snap, model, EngineConfig(mode="lsh", **cfg))

        qids = select_queries(lake, 16)
        reqs = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q))
                for q in qids]
        r_sh = eng_sh.query_batch(reqs)
        r_lo = eng_lo.query_batch(list(reqs))
        assert eng_sh.stats()["last_plan"]["kind"] == "sharded-hybrid"
        assert eng_lo.stats()["last_plan"]["kind"] == "local-hybrid"

        # parity: sharded and local pruning agree on the neighborhoods
        overlap = np.mean([
            len({m.column_id for m in a.matches} &
                {m.column_id for m in b.matches}) /
            max(len(b.matches), 1)
            for a, b in zip(r_sh, r_lo)])
        assert overlap >= 0.8, overlap

        # acceptance: recall vs the SHARDED full scan + pruning bound
        rec = measure_recall(eng_sh, qids, k=10)
        assert rec["plan"] == "sharded-hybrid", rec
        assert rec["baseline_plan"] == "sharded-all", rec
        assert rec["recall"] >= 0.9, rec
        assert rec["scored_fraction"] <= 0.30, rec
        print("OK sharded_lsh", overlap, rec["recall"],
              rec["scored_fraction"])
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "OK sharded_lsh" in r.stdout
