"""Enc-dec serving path + MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import encdec, registry
from repro.models.moe import _dispatch


def test_whisper_decode_matches_teacher_forcing():
    cfg = registry.reduced_config(registry.get_config("whisper-base"))
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(1))
    b, n, enc_len = 2, 10, 64
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(1, cfg.vocab, (b, n)), jnp.int32)
    frames = jnp.asarray(r.normal(size=(b, enc_len, cfg.d_model)) * 0.02,
                         jnp.float32)
    pad = max(cfg.attn_chunk, n)
    full = np.asarray(encdec.forward(
        params, cfg, jnp.pad(toks, ((0, 0), (0, pad - n))), frames))[:, :n]

    memory = encdec.encode(params, cfg, frames)
    caches = encdec.init_decode_caches(cfg, b, 128, enc_len)
    caches["cross"] = encdec.precompute_cross_kv(params, cfg, memory)
    outs = []
    for i in range(n):
        lg, caches = encdec.decode_step(params, cfg, toks[:, i:i + 1], caches)
        outs.append(np.asarray(lg)[:, 0])
    dec = np.stack(outs, 1)
    err = np.abs(dec - full).max() / (np.abs(full).max() + 1e-9)
    assert err < 2e-2, err


@given(st.integers(4, 64), st.integers(2, 8), st.integers(1, 2),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_moe_dispatch_invariants(n_tokens, n_experts, top_k, seed):
    """Every slot holds at most one copy; no expert exceeds capacity; kept
    copies preserve their router weight."""
    r = np.random.default_rng(seed)
    eids = jnp.asarray(r.integers(0, n_experts, n_tokens * top_k), jnp.int32)
    w = jnp.asarray(r.uniform(0.1, 1.0, n_tokens * top_k), jnp.float32)
    tok = jnp.asarray(np.repeat(np.arange(n_tokens), top_k), jnp.int32)
    cap = max(1, (n_tokens * top_k) // n_experts)
    slot_token, slot_weight, slot_copy = map(
        np.asarray, _dispatch(eids, w, tok, n_experts, cap))
    assert slot_token.shape == (n_experts * cap,)
    filled = slot_copy >= 0
    # copies are unique
    assert len(np.unique(slot_copy[filled])) == filled.sum()
    # slot contents are consistent with the original routing
    for s in np.flatnonzero(filled):
        c = slot_copy[s]
        e = s // cap
        assert int(eids[c]) == e
        assert slot_token[s] == int(tok[c])
        assert np.isclose(slot_weight[s], float(w[c]), atol=1e-6)
    # per-expert occupancy ≤ capacity and equals min(capacity, routed count)
    for e in range(n_experts):
        routed = int((np.asarray(eids) == e).sum())
        used = int(filled[e * cap:(e + 1) * cap].sum())
        assert used == min(routed, cap)
