"""MVCC catalog runtime: CAS multi-writer manifest, writer lease, follower
replication, background compaction, and snapshot-pinned serving.

Acceptance (ISSUE 3): queries issued during an in-flight ``compact()``
return results identical to a pinned pre-compaction snapshot (no torn
reads), and two concurrent writers both land their segments with the
manifest version advancing monotonically.
"""
import os
import threading

import numpy as np
import pytest

from repro.service import (BackgroundCompactor, CatalogReader, CatalogStore,
                           DiscoveryEngine, DiscoveryRequest, EngineConfig,
                           LeaseHeldError, WriterLease)
from repro.service.catalog import read_latest_manifest, read_manifest_version


def _cols(prefix: str, n: int = 40, start: int = 0):
    return [(f"{prefix}_x", [f"{prefix}v{i}" for i in range(start, start + n)])]


def _tiny_model():
    from repro.core.gbdt import GBDTParams
    from repro.core.predictor import JoinQualityModel
    p = GBDTParams(feats=np.zeros((1, 1), np.int32),
                   thrs=np.zeros((1, 1), np.float32),
                   leaves=np.zeros((1, 2), np.float32), base=0.0)
    return JoinQualityModel(gbdt=p)


@pytest.fixture(scope="module")
def model():
    from repro.core import GBDTConfig, LakeSpec, generate_lake, \
        train_quality_model
    lake = generate_lake(LakeSpec(n_domains=8, n_tables=12, row_budget=512,
                                  rows_log_mean=5.5, seed=3))
    return train_quality_model([lake], GBDTConfig(n_trees=20, depth=4),
                               n_query=48)


# ---------------------------------------------------------------------------
# CAS primitive + deterministic race
# ---------------------------------------------------------------------------

def test_cas_publish_rejects_taken_version(tmp_path):
    """The low-level CAS: version v+1 can be created exactly once."""
    a = CatalogStore(str(tmp_path), n_perm=64)
    b = CatalogStore(str(tmp_path))
    m = dict(a.manifest, version=a.version + 1)
    assert b._publish(dict(b.manifest, version=b.version + 1))
    assert not a._publish(m)               # same version: a lost the race
    assert read_latest_manifest(str(tmp_path))["version"] == 1


def test_add_table_retries_lost_cas(tmp_path, monkeypatch):
    """Deterministic writer race: B publishes between A's manifest read and
    A's publish; A must retry against the new head — both tables land,
    neither segment is lost, and the version advances by exactly two."""
    a = CatalogStore(str(tmp_path), n_perm=64)
    b = CatalogStore(str(tmp_path))

    real_publish = CatalogStore._publish
    fired = []

    def racing_publish(self, m):
        if self is a and not fired:
            fired.append(True)
            b.add_table("from_b", _cols("b"))      # sneaks in ahead of A
        return real_publish(self, m)

    monkeypatch.setattr(CatalogStore, "_publish", racing_publish)
    tid_a = a.add_table("from_a", _cols("a"))

    assert a.stats["cas_retries"] >= 1
    head = read_latest_manifest(str(tmp_path))
    assert head["version"] == 2
    assert set(head["tables"]) == {"from_a", "from_b"}
    assert len(head["segments"]) == 2
    # tids are unique even though both writers started from tid 0
    assert sorted(head["tables"].values()) == [0, 1]
    assert tid_a == head["tables"]["from_a"]
    snap = a.snapshot()
    assert snap.n_columns == 2


def test_two_writers_race_stress(tmp_path):
    """ISSUE acceptance: two concurrent writers both land every segment and
    the manifest version advances monotonically (strictly +1 per publish,
    no gaps, no lost updates)."""
    root = str(tmp_path)
    CatalogStore(root, n_perm=64)          # create v0
    n_each = 6
    barrier = threading.Barrier(2)
    errors = []

    def writer(tag):
        try:
            store = CatalogStore(root)     # its own handle, like a worker
            barrier.wait()
            for i in range(n_each):
                store.add_table(f"{tag}{i}", _cols(f"{tag}{i}"))
        except Exception as e:             # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,)) for t in "ab"]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors

    head = read_latest_manifest(root)
    assert head["version"] == 2 * n_each   # one CAS advance per add
    assert len(head["tables"]) == 2 * n_each
    assert len(head["segments"]) == 2 * n_each
    assert sorted(head["tables"].values()) == list(range(2 * n_each))
    # every intermediate version is present on disk, in order
    versions = [read_manifest_version(root, v)["version"]
                for v in range(2 * n_each + 1)]
    assert versions == list(range(2 * n_each + 1))
    # no orphaned segment directories
    segs = {d for d in os.listdir(root) if d.startswith("seg-")}
    assert segs == set(head["segments"])
    assert CatalogStore(root).snapshot().n_columns == 2 * n_each


def test_duplicate_name_race_cleans_orphan(tmp_path, monkeypatch):
    """A writer that loses the race to the same table name raises and
    removes its orphaned segment directory."""
    a = CatalogStore(str(tmp_path), n_perm=64)
    b = CatalogStore(str(tmp_path))

    real_publish = CatalogStore._publish
    fired = []

    def racing_publish(self, m):
        if self is a and not fired:
            fired.append(True)
            b.add_table("dup", _cols("b"))
        return real_publish(self, m)

    monkeypatch.setattr(CatalogStore, "_publish", racing_publish)
    with pytest.raises(ValueError, match="already in catalog"):
        a.add_table("dup", _cols("a"))
    head = read_latest_manifest(str(tmp_path))
    segs = {d for d in os.listdir(str(tmp_path)) if d.startswith("seg-")}
    assert segs == set(head["segments"])   # A's orphan was removed


# ---------------------------------------------------------------------------
# writer lease
# ---------------------------------------------------------------------------

def test_writer_lease_mutual_exclusion_and_expiry(tmp_path, fake_clock):
    """Expiry under an injected clock: the old version faked expiry with
    ``ttl_s=-1`` (a lease born dead); here a *valid* lease genuinely ages
    past its TTL when the clock advances — no wall-clock wait, and the
    pre-expiry exclusion check exercises the real code path."""
    root = str(tmp_path)
    os.makedirs(root, exist_ok=True)
    a = WriterLease(root, owner="a", ttl_s=60, clock=fake_clock).acquire()
    with pytest.raises(LeaseHeldError):
        WriterLease(root, owner="b", ttl_s=60, clock=fake_clock).acquire()
    fake_clock.advance(59)                 # aged but still live: still held
    with pytest.raises(LeaseHeldError):
        WriterLease(root, owner="b", ttl_s=60, clock=fake_clock).acquire()
    fake_clock.advance(2)                  # now past a's 60 s TTL
    c = WriterLease(root, owner="c", ttl_s=60,
                    clock=fake_clock).acquire()    # steals expired
    a.release()                            # stale token: must not unlink c's
    with pytest.raises(LeaseHeldError):
        WriterLease(root, owner="b", ttl_s=60, clock=fake_clock).acquire()
    c.release()
    d = WriterLease(root, owner="d", ttl_s=60, clock=fake_clock)
    with d:
        assert d._held
    assert not os.path.exists(d.path)


def test_compact_requires_free_lease(tmp_path):
    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("t0", _cols("t0"))
    held = WriterLease(str(tmp_path), owner="other", ttl_s=60).acquire()
    try:
        with pytest.raises(LeaseHeldError):
            store.compact()
    finally:
        held.release()
    store.compact()                        # released lease: proceeds
    assert len(store.manifest["segments"]) == 1


# ---------------------------------------------------------------------------
# follower replication
# ---------------------------------------------------------------------------

def test_follower_observes_versions_in_order(tmp_path):
    root = str(tmp_path)
    store = CatalogStore(root, n_perm=64)
    reader = CatalogReader(root)
    assert reader.version == 0 and reader.poll() == []

    store.add_table("t0", _cols("t0"))
    store.add_table("t1", _cols("t1"))
    assert reader.poll() == [1, 2]         # both versions, in order
    store.drop_table("t0")
    assert reader.poll() == [3]
    assert reader.version == 3

    snap2 = reader.snapshot(2)             # pinned historical version
    snap3 = reader.snapshot()
    assert snap2.version == 2 and snap2.n_columns == 2
    assert snap3.version == 3 and snap3.n_columns == 1
    # snapshots are immutable: compaction deletes old segments, but the
    # materialized pinned snapshot keeps serving
    store.compact()
    assert snap2.n_columns == 2
    assert reader.poll() == [4]
    assert reader.snapshot(4).n_columns == 1


def test_follower_sees_both_racing_writers(tmp_path):
    root = str(tmp_path)
    CatalogStore(root, n_perm=64)
    reader = CatalogReader(root)
    barrier = threading.Barrier(2)

    def writer(tag):
        store = CatalogStore(root)
        barrier.wait()
        for i in range(4):
            store.add_table(f"{tag}{i}", _cols(f"{tag}{i}"))

    threads = [threading.Thread(target=writer, args=(t,)) for t in "ab"]
    observed = []
    for t in threads:
        t.start()
    while any(t.is_alive() for t in threads):
        observed.extend(reader.poll())
    for t in threads:
        t.join()
    observed.extend(reader.poll())
    assert observed == list(range(1, 9))   # every version, strictly in order


# ---------------------------------------------------------------------------
# compaction: replay, background scheduling, pinned serving
# ---------------------------------------------------------------------------

def test_compaction_replays_concurrent_writes(tmp_path):
    """Adds and drops landing between the compactor's pin and its publish
    survive the swap via manifest replay."""
    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("old0", _cols("old0"))
    store.add_table("old1", _cols("old1"))
    store.drop_table("old1")

    other = CatalogStore(str(tmp_path))

    def concurrent_writes():               # runs after build, before publish
        other.add_table("during", _cols("during"))
        other.drop_table("old0")           # tombstone laid after the pin

    store.compact(on_built=concurrent_writes)

    head = read_latest_manifest(str(tmp_path))
    assert set(head["tables"]) == {"during"}
    assert len(head["segments"]) == 2      # compacted + the concurrent delta
    # old0's columns live inside the compacted segment but stay tombstoned
    snap = store.snapshot()
    assert snap.n_columns == 1
    assert snap.names == ["during_x"]
    # the next compaction clears the replayed tombstone too
    store.compact()
    assert read_latest_manifest(str(tmp_path))["dropped_ids"] == []
    assert store.snapshot().names == ["during_x"]


def test_resign_compaction_restarts_over_concurrent_add(tmp_path):
    """A geometry change cannot replay segments signed with the old
    geometry — it rebuilds from the new head instead (and converges)."""
    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("t0", _cols("t0"))
    other = CatalogStore(str(tmp_path))
    fired = []

    def add_once():
        if not fired:
            fired.append(True)
            other.add_table("mid", _cols("mid"))

    store.compact(n_perm=128, on_built=add_once)
    snap = store.snapshot()
    assert store.n_perm == 128
    assert snap.signatures.shape == (2, 128)     # BOTH tables re-signed
    assert set(store.tables()) == {"t0", "mid"}
    assert len(store.manifest["segments"]) == 1  # second pass absorbed mid


def test_background_compactor_serves_during_compaction(tmp_path, model):
    """ISSUE acceptance: queries during an in-flight compact() are
    identical to the pinned pre-compaction snapshot — no torn reads."""
    store = CatalogStore(str(tmp_path), n_perm=64)
    for i in range(6):
        store.add_table(f"t{i}", [(f"c{i}", [f"v{j}" for j in range(30 + i)]),
                                  (f"d{i}", [f"w{j % 7}" for j in range(25)])])
    engine = DiscoveryEngine.from_catalog(store, model,
                                          EngineConfig(k=5, mode="full"))
    reqs = [DiscoveryRequest(name=f"q{i}", column_id=i) for i in range(8)]
    baseline = [[(m.column_id, m.score) for m in r.matches]
                for r in engine.query_batch(reqs)]
    v0 = engine.version

    built = threading.Event()
    release = threading.Event()

    def hold():
        built.set()
        assert release.wait(timeout=30)

    with BackgroundCompactor(store) as compactor:
        fut = compactor.submit(on_built=hold)
        assert built.wait(timeout=30)      # compaction is now in flight
        assert compactor.busy
        during = [[(m.column_id, m.score) for m in r.matches]
                  for r in engine.query_batch(reqs)]
        assert during == baseline          # pinned snapshot: bit-identical
        assert engine.version == v0
        release.set()
        fut.result(timeout=30)

    assert len(store.manifest["segments"]) == 1
    # the engine still serves its pinned pre-compaction snapshot (the old
    # segments are deleted, but the materialized snapshot is immutable)...
    after = [[(m.column_id, m.score) for m in r.matches]
             for r in engine.query_batch(reqs)]
    assert after == baseline and engine.version == v0
    # ...and refreshing onto the post-compaction version keeps the results
    # (compaction must not change what is served, only the layout)
    engine.refresh(store.snapshot())
    assert engine.version > v0
    refreshed = [[(m.column_id, m.score) for m in r.matches]
                 for r in engine.query_batch(reqs)]
    assert refreshed == baseline


def test_racing_compactors_never_duplicate_columns(tmp_path, monkeypatch):
    """Two compactors racing over the same pinned segments (possible when
    the advisory lease fails) must not publish overlapping merges — the
    loser detects its inputs were swapped out and rebuilds from the head."""
    root = str(tmp_path)
    a = CatalogStore(root, n_perm=64)
    a.add_table("t0", _cols("t0"))
    a.add_table("t1", _cols("t1"))
    b = CatalogStore(root)
    # disable lease exclusion so both compactors run "concurrently"
    def fake_acquire(self):
        self._held = True
        return self

    monkeypatch.setattr(WriterLease, "acquire", fake_acquire)
    monkeypatch.setattr(WriterLease, "renew", lambda self: None)
    monkeypatch.setattr(WriterLease, "release", lambda self: None)

    fired = []

    def a_compacts_first():                # fires after B built, pre-publish
        if not fired:
            fired.append(True)
            a.compact()                    # A swaps the same two segments

    b.compact(on_built=a_compacts_first)
    snap = CatalogStore(root).snapshot()
    assert snap.n_columns == 2             # NOT 4: no duplicated columns
    assert sorted(snap.names) == ["t0_x", "t1_x"]
    assert len(read_latest_manifest(root)["segments"]) == 1


def test_reader_snapshot_survives_compaction_race(tmp_path, monkeypatch):
    """A compaction that publishes and deletes segments between the
    reader's poll and its materialize must not crash the latest-snapshot
    path (the follower retries at the new head)."""
    import repro.service.catalog as cat
    root = str(tmp_path)
    store = CatalogStore(root, n_perm=64)
    store.add_table("t0", _cols("t0"))
    store.add_table("t1", _cols("t1"))
    reader = CatalogReader(root)

    real = cat.materialize_snapshot
    fired = []

    def racing(root_, manifest, **kw):
        if not fired:                      # compaction lands mid-materialize
            fired.append(True)
            store.compact()
        return real(root_, manifest, **kw)

    monkeypatch.setattr(cat, "materialize_snapshot", racing)
    snap = reader.snapshot()               # must retry at the head, not die
    assert snap.version == store.version
    assert snap.n_columns == 2
    # an EXPLICITLY pinned version whose segments are gone raises clearly
    with pytest.raises(KeyError, match="compacted away"):
        reader.snapshot(1)


def test_compact_renews_lease_during_build(tmp_path, monkeypatch):
    """Long builds renew the lease (per merged segment / re-sign chunk) so
    mutual exclusion outlives ttl_s."""
    renews = []
    real_renew = WriterLease.renew
    monkeypatch.setattr(WriterLease, "renew",
                        lambda self: (renews.append(1), real_renew(self))[1])
    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("t0", _cols("t0"))
    store.add_table("t1", _cols("t1"))
    store.compact(n_perm=128, resign_chunk=1)
    assert len(renews) >= 4                # 2 segments + 2 chunks + final


def test_maybe_compact_counts_other_handles_segments(tmp_path):
    """The threshold must see deltas appended through OTHER store handles
    (each ingest worker has its own), not this handle's stale view."""
    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("t0", _cols("t0"))
    other = CatalogStore(str(tmp_path))
    for i in range(3):
        other.add_table(f"o{i}", _cols(f"o{i}"))
    with BackgroundCompactor(store, min_segments=4) as compactor:
        fut = compactor.maybe_compact()
        assert fut is not None             # 4 segments live at the head
        fut.result(timeout=30)
    assert len(read_latest_manifest(str(tmp_path))["segments"]) == 1


def test_background_compactor_coalesces_and_thresholds(tmp_path):
    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("t0", _cols("t0"))
    with BackgroundCompactor(store, min_segments=3) as compactor:
        assert compactor.maybe_compact() is None       # below threshold
        store.add_table("t1", _cols("t1"))
        store.add_table("t2", _cols("t2"))
        gate = threading.Event()
        f1 = compactor.submit(on_built=lambda: gate.wait(timeout=30))
        f2 = compactor.submit()                        # coalesces onto f1
        assert f1 is f2
        gate.set()
        f1.result(timeout=30)
    assert len(store.manifest["segments"]) == 1


# ---------------------------------------------------------------------------
# engine MVCC: version pinning, follow mode, cache namespacing
# ---------------------------------------------------------------------------

def test_engine_follow_picks_up_new_versions(tmp_path, model):
    """Follower engine: a post-add_table query must see the new version —
    the version-namespaced cache makes a stale hit impossible even though
    the request hashes identically."""
    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("base", [("ids", [f"v{i}" for i in range(200)])])
    engine = DiscoveryEngine.from_catalog(store, model,
                                          EngineConfig(k=5, mode="full"))
    engine.follow(CatalogReader(str(tmp_path)))

    req = DiscoveryRequest(name="q", column_id=0)
    r1 = engine.query(req)                 # miss; admitted under version v1
    assert engine.query(req).cached        # hit within the same version
    assert r1.matches == []                # nothing else in the lake yet

    store.add_table("joinable", [("ids2", [f"v{i}" for i in range(100, 300)])])
    r2 = engine.query(req)                 # follower refreshes -> new cache
    assert not r2.cached                   # namespace: stale hit impossible
    assert engine.version == store.version
    assert [m.column for m in r2.matches] == ["ids2"]
    s = engine.stats()["snapshot"]
    assert s["version"] == store.version and s["refreshes"] >= 2


def test_engine_retires_old_versions_by_refcount(tmp_path, model):
    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("t0", _cols("t0"))
    engine = DiscoveryEngine.from_catalog(store, model,
                                          EngineConfig(k=3, mode="full"))
    st0 = engine._pin()                    # an in-flight batch's pin
    store.add_table("t1", _cols("t1"))
    engine.refresh(store.snapshot())
    assert not st0.executor.closed         # still pinned: must stay usable
    assert engine.stats()["snapshot"]["live_states"] == 2
    engine._release(st0)                   # last unpin retires the version
    assert st0.executor.closed
    assert engine.stats()["snapshot"]["live_states"] == 1
    with pytest.raises(RuntimeError, match="closed"):
        st0.executor.execute(engine.planner.plan(n_columns=1, mode="full"),
                             np.zeros((1, engine._z_np.shape[1]), np.float32),
                             np.zeros((1, engine._w_np.shape[1]), np.uint32),
                             np.full((1,), -1, np.int32),
                             np.full((1,), -1, np.int32))


def test_engine_empty_catalog_still_answers(tmp_path):
    store = CatalogStore(str(tmp_path), n_perm=64)
    engine = DiscoveryEngine(store.snapshot(), _tiny_model())
    r = engine.query(DiscoveryRequest(values=["a", "b"]))
    assert r.matches == []


def test_scheduler_submitters_race_catalog_refresh(tmp_path, model):
    """Concurrent submitters drive the continuous-batching scheduler while
    a writer publishes new versions and the engine refreshes onto them:
    every future resolves to its own request's response, no batch is torn
    by a swap, and the engine retires old versions cleanly."""
    from repro.service import RequestScheduler, SchedulerConfig

    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("base0", _cols("base0"))
    store.add_table("base1", _cols("base1"))
    engine = DiscoveryEngine.from_catalog(
        store, model, EngineConfig(k=3, mode="full", cache_entries=0))
    n0 = engine.n_columns
    errors: list[Exception] = []
    results: list[tuple[str, object]] = []
    start = threading.Barrier(3)

    def submitter(tag, scheduler):
        try:
            start.wait()
            futs = []
            for i in range(24):
                name = f"{tag}{i}"
                futs.append((name, scheduler.submit(
                    DiscoveryRequest(name=name, column_id=i % n0))))
            results.extend(futs)
        except Exception as e:              # pragma: no cover
            errors.append(e)

    def refresher():
        try:
            start.wait()
            for i in range(4):
                store.add_table(f"extra{i}", _cols(f"extra{i}"))
                engine.refresh(store.snapshot())
        except Exception as e:              # pragma: no cover
            errors.append(e)

    with RequestScheduler(engine,
                          SchedulerConfig(max_wait_ms=0.5)) as scheduler:
        threads = [threading.Thread(target=submitter,
                                    args=(t, scheduler)) for t in "ab"]
        threads.append(threading.Thread(target=refresher))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        for name, fut in results:
            r = fut.result(timeout=60)
            assert r.name == name           # futures never cross wires
    s = engine.stats()
    assert s["queries"] >= 48
    assert s["snapshot"]["refreshes"] >= 5  # initial + 4 concurrent swaps
    assert s["snapshot"]["live_states"] == 1    # retired states released
    assert s["scheduler"]["completed"] == 48


def test_reader_poll_stat_cache_fast_path(tmp_path):
    """Idle polls are a single pointer stat (no JSON read); a publish
    moves the pointer and the next poll goes deep and observes it."""
    root = str(tmp_path)
    store = CatalogStore(root, n_perm=64)
    reader = CatalogReader(root)
    for _ in range(6):
        assert reader.poll() == []
    assert reader.stats["fast_polls"] >= 5
    assert reader.stats["deep_polls"] <= 1

    store.add_table("t0", _cols("t0"))
    assert reader.poll() == [1]            # pointer moved -> deep probe
    deep_after_add = reader.stats["deep_polls"]
    assert deep_after_add >= 1
    assert reader.poll() == []             # idle again: back on the stat
    assert reader.stats["fast_polls"] >= 6

    # the hint is best-effort: even with the pointer frozen (crashed
    # writer), the periodic deep probe still observes the new version
    lazy = CatalogReader(root, deep_poll_every=3)
    real_stat = lazy._stat_pointer()
    lazy._stat_pointer = lambda: real_stat
    store.add_table("t1", _cols("t1"))
    observed = []
    for _ in range(3):
        observed.extend(lazy.poll())
    assert observed == [2]


def test_compact_retention_window_keeps_recent_versions(tmp_path):
    """compact(retain_versions=N) defers deletion of replaced segments so
    the last N manifest versions stay materializable; a later compaction
    GCs segments past the window."""
    root = str(tmp_path)
    store = CatalogStore(root, n_perm=64)
    store.add_table("t0", _cols("t0"))     # v1
    store.add_table("t1", _cols("t1"))     # v2
    segs_v2 = set(store.manifest["segments"])

    store.compact(retain_versions=2)       # v3: replaced segments retained
    for s in segs_v2:
        assert os.path.isdir(os.path.join(root, s))
    assert store.manifest["retired"] == [[3, s] for s in sorted(segs_v2)] \
        or {s for _, s in store.manifest["retired"]} == segs_v2
    # a FRESH follower can still materialize the pre-compaction version
    assert CatalogReader(root).snapshot(2).n_columns == 2

    store.add_table("t2", _cols("t2"))     # v4
    store.add_table("t3", _cols("t3"))     # v5
    store.compact(retain_versions=2)       # v6: v3's retirees are past the
    for s in segs_v2:                      # window -> deleted
        assert not os.path.exists(os.path.join(root, s))
    with pytest.raises(KeyError, match="compacted away"):
        CatalogReader(root).snapshot(2)
    # versions inside the window stay readable
    assert CatalogReader(root).snapshot(5).n_columns == 4
    assert CatalogReader(root).snapshot(6).n_columns == 4

    # retain_versions=0 (default) purges any remaining window
    store.compact()
    assert store.manifest["retired"] == []
    segs = [d for d in os.listdir(root) if d.startswith("seg-")]
    assert len(segs) == 1


def test_legacy_single_manifest_catalog_upgrades(tmp_path):
    """A pre-CAS catalog (pointer file only, no chain) opens, serves, and
    joins the chain on the first write."""
    import json
    store = CatalogStore(str(tmp_path), n_perm=64)
    store.add_table("t0", _cols("t0"))
    # strip the chain + lease: what a PR-1-era catalog directory held
    for f in os.listdir(str(tmp_path)):
        if f.startswith("MANIFEST-") or f == "LEASE.json":
            os.unlink(os.path.join(str(tmp_path), f))
    with open(os.path.join(str(tmp_path), "MANIFEST.json")) as f:
        assert json.load(f)["version"] == 1

    reopened = CatalogStore(str(tmp_path))
    assert reopened.version == 1
    assert reopened.snapshot().n_columns == 1
    reader = CatalogReader(str(tmp_path))
    reopened.add_table("t1", _cols("t1"))
    assert reader.poll() == [2]
    assert reader.snapshot(2).n_columns == 2
