"""int8 error-feedback gradient compression: quantization error is bounded
per step and the error-feedback buffer cancels bias across steps."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_int8_ef_allreduce_unbiased_over_steps():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.compression import make_int8_ef_allreduce
        mesh = jax.make_mesh((8,), ("data",))
        init, compress = make_int8_ef_allreduce(mesh, ("data",))
        rng = np.random.default_rng(0)
        g_true = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
        ef = init(g_true)
        # single step: bounded relative error
        g1, ef1 = compress(g_true, ef)
        rel = float(jnp.max(jnp.abs(g1["w"] - g_true["w"])) /
                    jnp.max(jnp.abs(g_true["w"])))
        assert rel < 2e-2, rel
        # across steps with the same gradient, the EF-corrected SUM converges
        # to the true sum (bias cancels)
        total = jnp.zeros_like(g_true["w"])
        ef_state = init(g_true)
        for _ in range(8):
            g_hat, ef_state = compress(g_true, ef_state)
            total = total + g_hat["w"]
        drift = float(jnp.max(jnp.abs(total - 8 * g_true["w"])) /
                      jnp.max(jnp.abs(g_true["w"])))
        assert drift < 2e-2, drift
        print("OK compression")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK compression" in r.stdout
