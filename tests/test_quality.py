"""Property tests for the paper's join-quality metric (Section III/IV)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quality


@given(st.floats(0, 0.5), st.floats(0, 0.5), st.floats(0.01, 1.0))
@settings(max_examples=200, deadline=None)
def test_continuous_monotone_in_j(j1, j2, k):
    q1 = float(quality.continuous_quality(jnp.float32(j1), jnp.float32(k)))
    q2 = float(quality.continuous_quality(jnp.float32(j2), jnp.float32(k)))
    if j1 < j2:
        assert q1 <= q2 + 1e-6
    assert 0.0 <= q1 <= 1.0


@given(st.floats(0, 0.5), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(max_examples=200, deadline=None)
def test_continuous_monotone_in_k(j, k1, k2):
    q1 = float(quality.continuous_quality(jnp.float32(j), jnp.float32(k1)))
    q2 = float(quality.continuous_quality(jnp.float32(j), jnp.float32(k2)))
    if k1 < k2:
        assert q1 <= q2 + 1e-6


@given(st.floats(0, 0.5), st.floats(0, 1))
@settings(max_examples=100, deadline=None)
def test_strictness_penalizes(j, k):
    relaxed = float(quality.continuous_quality(jnp.float32(j), jnp.float32(k), 0.0))
    strict = float(quality.continuous_quality(jnp.float32(j), jnp.float32(k), 0.5))
    assert strict <= relaxed + 1e-6


def test_paper_example_3():
    """Scenario 1 (J=.25, K=1) must rank above scenario 2 (J=.25, K=.33);
    discrete buckets: High (3) vs Medium (2) for L=4."""
    j = jnp.float32(0.25)
    q1 = quality.discrete_quality(j, jnp.float32(1.0), 4)
    q2 = quality.discrete_quality(j, jnp.float32(0.33), 4)
    assert int(q1) == 3 and int(q2) == 2
    c1 = float(quality.continuous_quality(j, jnp.float32(1.0)))
    c2 = float(quality.continuous_quality(j, jnp.float32(0.33)))
    assert c1 > c2


@given(st.integers(1, 10_000), st.integers(1, 10_000))
@settings(max_examples=100, deadline=None)
def test_k_bounds(ca, cb):
    k = float(quality.cardinality_proportion(jnp.int32(ca), jnp.int32(cb)))
    assert 0 < k <= 1.0
    assert k == pytest.approx(min(ca, cb) / max(ca, cb), rel=1e-5)


@given(st.integers(0, 500), st.integers(1, 1000), st.integers(1, 1000))
@settings(max_examples=100, deadline=None)
def test_multiset_jaccard_bounds(inter, na, nb):
    inter = min(inter, na, nb)
    j = float(quality.multiset_jaccard(jnp.int32(inter), jnp.int32(na), jnp.int32(nb)))
    assert 0.0 <= j <= 0.5 + 1e-6


def test_discrete_quality_monotone_grid():
    js = jnp.linspace(0, 0.5, 21)
    ks = jnp.linspace(0, 1, 21)
    q = quality.discrete_quality(js[:, None], ks[None, :], 4)
    q = np.asarray(q)
    assert (np.diff(q, axis=0) >= 0).all()      # increasing in J
    assert (np.diff(q, axis=1) >= 0).all()      # increasing in K
    assert q.min() == 0 and q.max() == 4


def test_wasserstein_fit_recovers_params():
    rng = np.random.default_rng(0)
    mu, sg = 0.4, 0.25
    from scipy.stats import truncnorm
    a, b = (0 - mu) / sg, (1 - mu) / sg
    samples = truncnorm.rvs(a, b, loc=mu, scale=sg, size=4000, random_state=rng)
    fit = quality.fit_truncated_gaussian(
        samples, mus=np.linspace(0.2, 0.6, 9), sigmas=np.linspace(0.1, 0.4, 7))
    assert abs(fit["mu"] - mu) <= 0.1
    assert abs(fit["sigma"] - sg) <= 0.1
