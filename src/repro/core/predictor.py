"""Join-quality prediction from profiles (paper Section IV-B).

Pipeline: z-score numeric profiles lake-wide → per-pair distance vector
(|Δz| per numeric feature + frequent-word overlap + first-word equality) →
regression model (oblivious GBDT; optional MLP) → predicted continuous
quality Q(A,B,s).

The model is trained once on a synthetic lake at s = 0.25 (as the paper's
released model is) and reused across lakes with no fine-tuning; benchmarks
validate the generalization claim on held-out lakes with different seeds and
spec parameters.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as FT
from repro.core import quality
from repro.core.gbdt import GBDTConfig, GBDTParams, fit_gbdt, predict_np
from repro.core.lakegen import Lake
from repro.core.profiles import LakeProfiles, profile_lake
from repro.core.sketches import batch_exact_metrics


# ---------------------------------------------------------------------------
# distance features (pure-jnp reference; the Pallas kernel mirrors this)
# ---------------------------------------------------------------------------

def distance_features_ref(z_a, words_a, z_b, words_b):
    """Distance vector for pairs. Shapes: z (…, F_NUM), words (…, F_WORDS).

    Broadcasting: ``z_a``/``words_a`` of shape (Q, 1, F), ``z_b``/``words_b``
    of shape (1, N, F) yield (Q, N, F_DIST).
    """
    d_num = jnp.abs(z_a - z_b)
    top_a = words_a[..., :FT.N_FREQ_WORDS]
    top_b = words_b[..., :FT.N_FREQ_WORDS]
    sent = jnp.uint32(FT.HASH_SENTINEL)
    eq = (top_a[..., :, None] == top_b[..., None, :]) & (top_a[..., :, None] != sent)
    overlap = jnp.sum(eq.any(axis=-1).astype(jnp.float32), axis=-1) / FT.N_FREQ_WORDS
    fw_a = words_a[..., FT.FIRST_WORD]
    fw_b = words_b[..., FT.FIRST_WORD]
    first_eq = ((fw_a == fw_b) & (fw_a != sent)).astype(jnp.float32)
    return jnp.concatenate(
        [d_num, overlap[..., None], first_eq[..., None]], axis=-1)


def pairwise_distances(profiles: LakeProfiles, query_ids: np.ndarray) -> jnp.ndarray:
    """(Q, N, F_DIST) distance tensor for query columns vs the whole lake."""
    z = jnp.asarray(profiles.zscored, jnp.float32)
    w = jnp.asarray(profiles.words)
    zq, wq = z[query_ids], w[query_ids]
    return distance_features_ref(zq[:, None, :], wq[:, None, :], z[None], w[None])


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JoinQualityModel:
    gbdt: GBDTParams
    strictness: float = quality.DEFAULT_STRICTNESS
    train_r2: float = float("nan")

    def save(self, path: str) -> None:
        np.savez(path, feats=self.gbdt.feats, thrs=self.gbdt.thrs,
                 leaves=self.gbdt.leaves, base=np.float32(self.gbdt.base),
                 strictness=np.float32(self.strictness),
                 train_r2=np.float32(self.train_r2))

    @staticmethod
    def load(path: str) -> "JoinQualityModel":
        z = np.load(path)
        return JoinQualityModel(
            gbdt=GBDTParams(feats=z["feats"], thrs=z["thrs"], leaves=z["leaves"],
                            base=float(z["base"])),
            strictness=float(z["strictness"]), train_r2=float(z["train_r2"]))


def exact_jk(lake: Lake, query_ids: np.ndarray, corpus_ids: np.ndarray | None = None,
             chunk: int = 64):
    """Exact (J, K) for query×corpus pairs from packed sketches (chunked)."""
    p = lake.packed
    cids = np.arange(lake.n_columns) if corpus_ids is None else corpus_ids
    cv, cc = jnp.asarray(p.values[cids]), jnp.asarray(p.counts[cids])
    ccard, crows = jnp.asarray(p.card[cids]), jnp.asarray(p.n_rows[cids])
    out_j, out_k = [], []
    for i in range(0, len(query_ids), chunk):
        q = query_ids[i:i + chunk]
        m = batch_exact_metrics(jnp.asarray(p.values[q]), jnp.asarray(p.counts[q]),
                                jnp.asarray(p.card[q]), jnp.asarray(p.n_rows[q]),
                                cv, cc, ccard, crows)
        out_j.append(np.asarray(m["j_multi"]))
        out_k.append(np.asarray(m["k"]))
    return np.concatenate(out_j), np.concatenate(out_k)


def build_training_set(lake: Lake, profiles: LakeProfiles | None = None,
                       n_query: int = 192, strictness: float = quality.DEFAULT_STRICTNESS,
                       seed: int = 0):
    """(X, y) training pairs: distance features -> continuous quality label."""
    rng = np.random.default_rng(seed)
    profiles = profiles if profiles is not None else profile_lake(lake.batch)
    c = lake.n_columns
    qids = rng.choice(c, size=min(n_query, c), replace=False)
    j, k = exact_jk(lake, qids)                           # (Q, N)
    d = np.asarray(pairwise_distances(profiles, qids))    # (Q, N, F_DIST)
    y = np.asarray(quality.continuous_quality(jnp.asarray(j), jnp.asarray(k), strictness))

    # drop self pairs; subsample the huge zero-quality mass for balance
    qi = np.repeat(qids, c)
    ci = np.tile(np.arange(c), len(qids))
    keep = qi != ci
    x = d.reshape(-1, FT.F_DIST)[keep]
    yy = y.reshape(-1)[keep]
    pos = yy > 0.02
    neg = np.flatnonzero(~pos)
    n_neg = min(len(neg), max(1, 3 * int(pos.sum())))
    sel = np.concatenate([np.flatnonzero(pos), rng.choice(neg, size=n_neg, replace=False)])
    rng.shuffle(sel)
    return x[sel].astype(np.float32), yy[sel].astype(np.float32)


def train_quality_model(lakes: list[Lake], cfg: GBDTConfig = GBDTConfig(),
                        strictness: float = quality.DEFAULT_STRICTNESS,
                        n_query: int = 192, seed: int = 0) -> JoinQualityModel:
    xs, ys = [], []
    for i, lake in enumerate(lakes):
        x, y = build_training_set(lake, n_query=n_query, strictness=strictness,
                                  seed=seed + i)
        xs.append(x)
        ys.append(y)
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    params = fit_gbdt(x, y, cfg)
    pred = predict_np(params, x)
    ss_res = float(np.sum((pred - y) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1.0
    return JoinQualityModel(gbdt=params, strictness=strictness,
                            train_r2=1.0 - ss_res / ss_tot)


# ---------------------------------------------------------------------------
# inference (jnp reference; discovery.py wires the Pallas kernels)
# ---------------------------------------------------------------------------

def gbdt_predict_ref(params_tuple, x: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oblivious-GBDT inference. x: (..., F) -> (...)."""
    feats, thrs, leaves, base = params_tuple
    t, d = feats.shape

    def tree(carry, tp):
        f_l, t_l, lv = tp
        xf = jnp.take(x, f_l, axis=-1)                     # (..., D)
        bits = (xf >= t_l).astype(jnp.int32)
        idx = jnp.sum(bits * (2 ** jnp.arange(d, dtype=jnp.int32)), axis=-1)
        return carry + jnp.take(lv, idx, axis=0), None

    out, _ = jax.lax.scan(tree, jnp.full(x.shape[:-1], base, jnp.float32),
                          (jnp.asarray(feats), jnp.asarray(thrs), jnp.asarray(leaves)))
    return out


def predict_scores_ref(model: JoinQualityModel, profiles: LakeProfiles,
                       query_ids: np.ndarray) -> np.ndarray:
    """(Q, N) predicted join quality for query columns vs the lake."""
    d = pairwise_distances(profiles, query_ids)
    return np.asarray(gbdt_predict_ref(
        tuple(map(jnp.asarray, model.gbdt.astuple())), d))
