"""Discovery-by-attribute (paper Definition 1) — local and multi-pod paths.

The lake index holds profiles only (the paper's point: a few KB per column).
Query path: distance features → GBDT inference → top-k ranking.

Distributed path (`rank_sharded`): profiles are sharded over the mesh's
batch-like axes (``data``, and ``pod`` when multi-pod) with `shard_map`;
every device scores its shard of the lake against the (replicated) query
profiles, takes a **local** top-k, and a single small `all_gather`
(k × devices candidate (score, id) pairs) merges rankings — collective
bytes are O(Q · k · devices), independent of lake size.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import features as FT
from repro.core.predictor import (JoinQualityModel, distance_features_ref,
                                  gbdt_predict_ref)
from repro.core.profiles import LakeProfiles


@dataclasses.dataclass
class DiscoveryIndex:
    profiles: LakeProfiles
    model: JoinQualityModel
    names: list[str] | None = None
    table_ids: np.ndarray | None = None

    @property
    def n_columns(self) -> int:
        return self.profiles.n_columns


def _score_block(z_q, w_q, z_c, w_c, gbdt_tuple, exclude_table=None, tq=None, tc=None):
    """Scores (Q, N) for query profiles vs a corpus block."""
    d = distance_features_ref(z_q[:, None], w_q[:, None], z_c[None], w_c[None])
    s = gbdt_predict_ref(gbdt_tuple, d)
    if exclude_table is not None and tq is not None:
        same = tq[:, None] == tc[None]
        s = jnp.where(same, -jnp.inf, s)
    return s


@partial(jax.jit, static_argnames=("k", "exclude_same_table"))
def _rank_local(z, w, tids, query_ids, gbdt_tuple, k: int,
                exclude_same_table: bool = True):
    zq, wq, tq = z[query_ids], w[query_ids], tids[query_ids]
    s = _score_block(zq, wq, z, w, gbdt_tuple,
                     exclude_table=exclude_same_table or None, tq=tq, tc=tids)
    # never return the query itself
    n = z.shape[0]
    s = jnp.where(jnp.arange(n)[None] == query_ids[:, None], -jnp.inf, s)
    scores, ids = jax.lax.top_k(s, k)
    return scores, ids


def _pad_topk(scores: np.ndarray, ids: np.ndarray, k: int):
    """Pad (Q, k_eff) top-k results out to k columns (-inf scores, -1 ids)."""
    k_eff = scores.shape[1]
    if k_eff >= k:
        return scores, ids
    pad = ((0, 0), (0, k - k_eff))
    return (np.pad(scores, pad, constant_values=-np.inf),
            np.pad(ids, pad, constant_values=-1))


def rank(index: DiscoveryIndex, query_ids: np.ndarray, k: int = 10,
         exclude_same_table: bool = True):
    """Single-device ranking. Returns (scores (Q, k), column ids (Q, k)).

    ``k`` may exceed the lake size; the tail is padded with -inf / -1.
    """
    n = index.n_columns
    q = len(query_ids)
    if n == 0:
        return (np.full((q, k), -np.inf, np.float32),
                np.full((q, k), -1, np.int32))
    k_eff = min(k, n)
    z = jnp.asarray(index.profiles.zscored, jnp.float32)
    w = jnp.asarray(index.profiles.words)
    t = jnp.asarray(index.table_ids if index.table_ids is not None
                    else np.zeros((index.n_columns,), np.int32))
    gb = tuple(map(jnp.asarray, index.model.gbdt.astuple()))
    scores, ids = _rank_local(z, w, t, jnp.asarray(query_ids, jnp.int32), gb,
                              k_eff, exclude_same_table)
    return _pad_topk(np.asarray(scores), np.asarray(ids), k)


# ---------------------------------------------------------------------------
# sharded path
# ---------------------------------------------------------------------------

def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad, constant_values=fill)


def build_rank_sharded(mesh: Mesh, k: int, gbdt_tuple, *, shard_axes=("data",),
                       block: int = 4096, with_tables: bool = False):
    """Builds the jitted sharded ranking fn over ``mesh``.

    Column-axis tensors are sharded over ``shard_axes``; queries and model
    parameters are replicated. Returns fn(z, w, cids, zq, wq, qids) ->
    (scores, ids) with global column ids. With ``with_tables`` the fn takes
    two extra args (tids sharded, tq replicated) and masks columns whose
    table matches the query's (tq=-1 disables the mask for that query).

    Scoring streams the local corpus in blocks of ``block`` columns (the
    jnp mirror of the fused Pallas kernel): the (Q, N, F) distance tensor
    never materializes, so HBM traffic is the profiles themselves + the
    (Q, N) score row — bandwidth-bound at profile size.
    """
    from jax.experimental.shard_map import shard_map

    axes = tuple(shard_axes)

    def local_rank(z, w, cids, zq, wq, qids, *rest):
        nloc = z.shape[0]
        kl = min(k, nloc)              # shard may hold fewer than k columns
        nb = max(nloc // block, 1)

        def score_blk(args):
            zb, wb = args
            d = distance_features_ref(zq[:, None], wq[:, None], zb[None], wb[None])
            return gbdt_predict_ref(gbdt_tuple, d)          # (Q, block)

        if nloc % block == 0 and nloc > block:
            zc = z.reshape(nb, block, z.shape[1])
            wc = w.reshape(nb, block, w.shape[1])
            s = jax.lax.map(score_blk, (zc, wc))            # (nb, Q, block)
            s = jnp.moveaxis(s, 0, 1).reshape(zq.shape[0], nloc)
        else:
            s = score_blk((z, w))
        s = jnp.where(cids[None] >= 0, s, -jnp.inf)        # padding columns
        s = jnp.where(cids[None] == qids[:, None], -jnp.inf, s)  # self
        if with_tables:
            tids, tq = rest
            same = (tq[:, None] >= 0) & (tids[None] == tq[:, None])
            s = jnp.where(same, -jnp.inf, s)
        ls, li = jax.lax.top_k(s, kl)                      # (Q, kl) local
        lids = cids[li]
        # gather the small candidate sets from every shard and re-rank
        all_s = ls
        all_i = lids
        for ax in axes:
            all_s = jax.lax.all_gather(all_s, ax, axis=1, tiled=True)
            all_i = jax.lax.all_gather(all_i, ax, axis=1, tiled=True)
        gs, gi = jax.lax.top_k(all_s, min(k, all_s.shape[1]))
        return gs, jnp.take_along_axis(all_i, gi, axis=1)

    in_specs = (P(axes), P(axes), P(axes), P(), P(), P())
    if with_tables:
        in_specs = in_specs + (P(axes), P())
    out_specs = (P(), P())
    fn = shard_map(local_rank, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return jax.jit(fn)


def place_sharded_corpus(mesh: Mesh, shard_axes, z: np.ndarray, w: np.ndarray,
                         table_ids: np.ndarray | None = None) -> dict:
    """Pad the column axis to a multiple of the shard count and device_put
    the corpus tensors for ``build_rank_sharded``.

    Returns ``{"z", "w", "cids", "rep"[, "tids"]}`` — ``cids`` are global
    column ids (-1 on padding), ``tids`` pad with -2 (matches no real table
    and no disabled-query sentinel), ``rep`` is the replicated sharding for
    the query-side tensors.
    """
    n = z.shape[0]
    n_shards = int(np.prod([mesh.shape[a] for a in shard_axes]))
    n_pad = -(-n // n_shards) * n_shards
    shard = NamedSharding(mesh, P(tuple(shard_axes)))
    out = {
        "z": jax.device_put(_pad_to(z.astype(np.float32), n_pad, 0.0), shard),
        "w": jax.device_put(_pad_to(w, n_pad, FT.HASH_SENTINEL), shard),
        "cids": jax.device_put(
            _pad_to(np.arange(n, dtype=np.int32), n_pad, -1), shard),
        "rep": NamedSharding(mesh, P()),
    }
    if table_ids is not None:
        out["tids"] = jax.device_put(
            _pad_to(np.asarray(table_ids, np.int32), n_pad, -2), shard)
    return out


def rank_sharded(index: DiscoveryIndex, query_ids: np.ndarray, mesh: Mesh,
                 k: int = 10, shard_axes=("data",)):
    """Multi-device ranking over ``mesh`` (profiles sharded over columns).

    Like :func:`rank`, ``k`` may exceed the lake (or shard) size; results are
    padded out to k with -inf / -1.
    """
    n = index.n_columns
    if n == 0:
        q = len(query_ids)
        return (np.full((q, k), -np.inf, np.float32),
                np.full((q, k), -1, np.int32))

    corpus = place_sharded_corpus(mesh, shard_axes,
                                  index.profiles.zscored,
                                  index.profiles.words)
    zq = index.profiles.zscored[query_ids].astype(np.float32)
    wq = index.profiles.words[query_ids]

    gb = tuple(map(jnp.asarray, index.model.gbdt.astuple()))
    fn = build_rank_sharded(mesh, k, gb, shard_axes=shard_axes)

    rep = corpus["rep"]
    scores, ids = fn(corpus["z"], corpus["w"], corpus["cids"],
                     jax.device_put(zq, rep), jax.device_put(wq, rep),
                     jax.device_put(np.asarray(query_ids, np.int32), rep))
    return _pad_topk(np.asarray(scores), np.asarray(ids), k)
