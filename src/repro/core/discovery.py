"""Discovery-by-attribute (paper Definition 1) — thin adapters over
``repro.exec``.

The lake index holds profiles only (the paper's point: a few KB per
column). Both entry points route through the unified candidate→score→merge
executor (``repro.exec``): :func:`rank` runs the local full-scan plan,
:func:`rank_sharded` the mesh-sharded one — profiles sharded over the
mesh's batch-like axes, every device scores its shard, takes a local
top-k, and a single small ``all_gather`` (k × devices candidate
(score, id) pairs) merges rankings; collective bytes are
O(Q · k · devices), independent of lake size.

The legacy in-module pipelines (``_rank_local``, ``build_rank_sharded``)
were deleted in the executor refactor; ``service.engine`` shares the same
executor, so the scoring math exists exactly once.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh

from repro.core.predictor import JoinQualityModel
from repro.core.profiles import LakeProfiles


@dataclasses.dataclass
class DiscoveryIndex:
    profiles: LakeProfiles
    model: JoinQualityModel
    names: list[str] | None = None
    table_ids: np.ndarray | None = None

    @property
    def n_columns(self) -> int:
        return self.profiles.n_columns


def _executor(index: DiscoveryIndex, mesh=None):
    from repro.exec import Executor
    return Executor(index.profiles.zscored, index.profiles.words,
                    index.model.gbdt.astuple(), table_ids=index.table_ids,
                    mesh=mesh)


def _query_rows(index: DiscoveryIndex, query_ids: np.ndarray,
                exclude_same_table: bool):
    qid = np.asarray(query_ids, np.int32)
    zq = index.profiles.zscored[qid].astype(np.float32)
    wq = index.profiles.words[qid]
    if exclude_same_table and index.table_ids is not None:
        tq = np.asarray(index.table_ids, np.int32)[qid]
    else:
        tq = np.full((len(qid),), -1, np.int32)
    return zq, wq, tq, qid


def _empty(q: int, k: int):
    return (np.full((q, k), -np.inf, np.float32),
            np.full((q, k), -1, np.int32))


def rank(index: DiscoveryIndex, query_ids: np.ndarray, k: int = 10,
         exclude_same_table: bool = True):
    """Single-device ranking. Returns (scores (Q, k), column ids (Q, k)).

    ``k`` may exceed the lake size; the tail is padded with -inf / -1.
    """
    from repro.exec import Planner, PlannerConfig
    if index.n_columns == 0:
        return _empty(len(query_ids), k)
    plan = Planner(PlannerConfig(k=k)).plan(
        n_columns=index.n_columns, n_queries=len(query_ids), mode="full")
    zq, wq, tq, qid = _query_rows(index, query_ids, exclude_same_table)
    scores, ids, _ = _executor(index).execute(plan, zq, wq, tq, qid)
    return scores, ids


def rank_sharded(index: DiscoveryIndex, query_ids: np.ndarray, mesh: Mesh,
                 k: int = 10, shard_axes=("data",)):
    """Multi-device ranking over ``mesh`` (profiles sharded over columns).

    Like :func:`rank`, ``k`` may exceed the lake (or shard) size; results
    are padded out to k with -inf / -1. Same-table exclusion is off (the
    historical convention of this entry point — pass table ids through the
    service engine for masked sharded queries).
    """
    from repro.exec import Planner, PlannerConfig
    if index.n_columns == 0:
        return _empty(len(query_ids), k)
    plan = Planner(PlannerConfig(k=k, shard_axes=tuple(shard_axes))).plan(
        n_columns=index.n_columns, n_queries=len(query_ids), mode="sharded",
        mesh=mesh)
    zq, wq, tq, qid = _query_rows(index, query_ids, exclude_same_table=False)
    scores, ids, _ = _executor(index, mesh=mesh).execute(plan, zq, wq, tq, qid)
    return scores, ids
