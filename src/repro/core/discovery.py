"""Discovery-by-attribute (paper Definition 1) — local and multi-pod paths.

The lake index holds profiles only (the paper's point: a few KB per column).
Query path: distance features → GBDT inference → top-k ranking.

Distributed path (`rank_sharded`): profiles are sharded over the mesh's
batch-like axes (``data``, and ``pod`` when multi-pod) with `shard_map`;
every device scores its shard of the lake against the (replicated) query
profiles, takes a **local** top-k, and a single small `all_gather`
(k × devices candidate (score, id) pairs) merges rankings — collective
bytes are O(Q · k · devices), independent of lake size.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import features as FT
from repro.core.predictor import (JoinQualityModel, distance_features_ref,
                                  gbdt_predict_ref)
from repro.core.profiles import LakeProfiles


@dataclasses.dataclass
class DiscoveryIndex:
    profiles: LakeProfiles
    model: JoinQualityModel
    names: list[str] | None = None
    table_ids: np.ndarray | None = None

    @property
    def n_columns(self) -> int:
        return self.profiles.n_columns


def _score_block(z_q, w_q, z_c, w_c, gbdt_tuple, exclude_table=None, tq=None, tc=None):
    """Scores (Q, N) for query profiles vs a corpus block."""
    d = distance_features_ref(z_q[:, None], w_q[:, None], z_c[None], w_c[None])
    s = gbdt_predict_ref(gbdt_tuple, d)
    if exclude_table is not None and tq is not None:
        same = tq[:, None] == tc[None]
        s = jnp.where(same, -jnp.inf, s)
    return s


@partial(jax.jit, static_argnames=("k", "exclude_same_table"))
def _rank_local(z, w, tids, query_ids, gbdt_tuple, k: int,
                exclude_same_table: bool = True):
    zq, wq, tq = z[query_ids], w[query_ids], tids[query_ids]
    s = _score_block(zq, wq, z, w, gbdt_tuple,
                     exclude_table=exclude_same_table or None, tq=tq, tc=tids)
    # never return the query itself
    n = z.shape[0]
    s = jnp.where(jnp.arange(n)[None] == query_ids[:, None], -jnp.inf, s)
    scores, ids = jax.lax.top_k(s, k)
    return scores, ids


def rank(index: DiscoveryIndex, query_ids: np.ndarray, k: int = 10,
         exclude_same_table: bool = True):
    """Single-device ranking. Returns (scores (Q, k), column ids (Q, k))."""
    z = jnp.asarray(index.profiles.zscored, jnp.float32)
    w = jnp.asarray(index.profiles.words)
    t = jnp.asarray(index.table_ids if index.table_ids is not None
                    else np.zeros((index.n_columns,), np.int32))
    gb = tuple(map(jnp.asarray, index.model.gbdt.astuple()))
    scores, ids = _rank_local(z, w, t, jnp.asarray(query_ids, jnp.int32), gb, k,
                              exclude_same_table)
    return np.asarray(scores), np.asarray(ids)


# ---------------------------------------------------------------------------
# sharded path
# ---------------------------------------------------------------------------

def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad, constant_values=fill)


def build_rank_sharded(mesh: Mesh, k: int, gbdt_tuple, *, shard_axes=("data",),
                       block: int = 4096):
    """Builds the jitted sharded ranking fn over ``mesh``.

    Column-axis tensors are sharded over ``shard_axes``; queries and model
    parameters are replicated. Returns fn(z, w, cids, zq, wq, qids) ->
    (scores, ids) with global column ids.

    Scoring streams the local corpus in blocks of ``block`` columns (the
    jnp mirror of the fused Pallas kernel): the (Q, N, F) distance tensor
    never materializes, so HBM traffic is the profiles themselves + the
    (Q, N) score row — bandwidth-bound at profile size.
    """
    from jax.experimental.shard_map import shard_map

    axes = tuple(shard_axes)

    def local_rank(z, w, cids, zq, wq, qids):
        nloc = z.shape[0]
        nb = max(nloc // block, 1)

        def score_blk(args):
            zb, wb = args
            d = distance_features_ref(zq[:, None], wq[:, None], zb[None], wb[None])
            return gbdt_predict_ref(gbdt_tuple, d)          # (Q, block)

        if nloc % block == 0 and nloc > block:
            zc = z.reshape(nb, block, z.shape[1])
            wc = w.reshape(nb, block, w.shape[1])
            s = jax.lax.map(score_blk, (zc, wc))            # (nb, Q, block)
            s = jnp.moveaxis(s, 0, 1).reshape(zq.shape[0], nloc)
        else:
            s = score_blk((z, w))
        s = jnp.where(cids[None] >= 0, s, -jnp.inf)        # padding columns
        s = jnp.where(cids[None] == qids[:, None], -jnp.inf, s)  # self
        ls, li = jax.lax.top_k(s, k)                       # (Q, k) local
        lids = cids[li]
        # gather the small candidate sets from every shard and re-rank
        all_s = ls
        all_i = lids
        for ax in axes:
            all_s = jax.lax.all_gather(all_s, ax, axis=1, tiled=True)
            all_i = jax.lax.all_gather(all_i, ax, axis=1, tiled=True)
        gs, gi = jax.lax.top_k(all_s, k)
        return gs, jnp.take_along_axis(all_i, gi, axis=1)

    in_specs = (P(axes), P(axes), P(axes), P(), P(), P())
    out_specs = (P(), P())
    fn = shard_map(local_rank, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return jax.jit(fn)


def rank_sharded(index: DiscoveryIndex, query_ids: np.ndarray, mesh: Mesh,
                 k: int = 10, shard_axes=("data",)):
    """Multi-device ranking over ``mesh`` (profiles sharded over columns)."""
    n_shards = int(np.prod([mesh.shape[a] for a in shard_axes]))
    n = index.n_columns
    n_pad = -(-n // n_shards) * n_shards

    z = _pad_to(index.profiles.zscored.astype(np.float32), n_pad, 0.0)
    w = _pad_to(index.profiles.words, n_pad, FT.HASH_SENTINEL)
    cids = _pad_to(np.arange(n, dtype=np.int32), n_pad, -1)
    zq = index.profiles.zscored[query_ids].astype(np.float32)
    wq = index.profiles.words[query_ids]

    gb = tuple(map(jnp.asarray, index.model.gbdt.astuple()))
    fn = build_rank_sharded(mesh, k, gb, shard_axes=shard_axes)

    shard_spec = NamedSharding(mesh, P(shard_axes))
    rep = NamedSharding(mesh, P())
    z = jax.device_put(z, shard_spec)
    w = jax.device_put(w, shard_spec)
    cids = jax.device_put(cids, shard_spec)
    qarr = jax.device_put(np.asarray(query_ids, np.int32), rep)
    scores, ids = fn(z, w, jnp.asarray(cids), jax.device_put(zq, rep),
                     jax.device_put(wq, rep), qarr)
    return np.asarray(scores), np.asarray(ids)
