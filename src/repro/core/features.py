"""Profile feature layout shared by profiling, distance and predictor code.

The paper (Table II) selects ~20 meta-features out of 60. We keep exactly the
selected set, laid out as a fixed-width vector so profiles are dense device
tensors:

* ``numeric`` part: ``(C, F_NUM)`` float32 — z-score normalized lake-wide
  before comparison (paper: "Normalize = Yes" column; we z-score every
  numeric slot which subsumes the paper's subset).
* ``words`` part: ``(C, F_WORDS)`` uint32 — the 10 most frequent value
  hashes + the "first word" proxy (minimum value hash; the paper orders
  alphabetically, we order by stable hash — see DESIGN.md §5.1).
"""
from __future__ import annotations

# ---- numeric slots ---------------------------------------------------------
CARDINALITY = 0        # number of distinct values
UNIQUENESS = 1         # cardinality / n_valid_rows
ENTROPY = 2            # Shannon entropy of the value frequency distribution
MIN_FREQ = 3           # min frequency-distribution count
MAX_FREQ = 4           # max frequency-distribution count
MAX_PERC_FREQ = 5      # max frequency as a fraction of rows
SD_PERC_FREQ = 6       # stddev of frequency fractions
OCTILE_0 = 7           # 7 interior octiles (12.5% .. 87.5%) of the
OCTILE_LAST = 13       # frequency distribution, in fractions of rows
LONGEST_STR = 14       # characters in the longest value
SHORTEST_STR = 15      # characters in the shortest value
AVG_STR = 16           # mean characters per value
AVG_WORDS = 17         # mean words per value
MIN_WORDS = 18         # min words per value
MAX_WORDS = 19         # max words per value
SD_WORDS = 20          # stddev of words per value

F_NUM = 21

NUMERIC_NAMES = [
    "cardinality", "uniqueness", "entropy", "min_freq", "max_freq",
    "max_perc_freq", "sd_perc_freq",
    "octile_1", "octile_2", "octile_3", "octile_4", "octile_5", "octile_6",
    "octile_7",
    "longest_str", "shortest_str", "avg_str",
    "avg_words", "min_words", "max_words", "sd_words",
]
assert len(NUMERIC_NAMES) == F_NUM

# ---- word-hash slots -------------------------------------------------------
N_FREQ_WORDS = 10      # top-10 most frequent value hashes
FIRST_WORD = 10        # index of the first-word proxy inside ``words``
F_WORDS = N_FREQ_WORDS + 1

# ---- distance-vector layout (predictor input) ------------------------------
# 0..F_NUM-1   : |z(a_i) - z(b_i)| per numeric slot
# F_NUM        : frequent-word overlap   |top10(A) ∩ top10(B)| / 10
# F_NUM + 1    : first-word proxy equality (0/1)
D_WORD_OVERLAP = F_NUM
D_FIRST_WORD_EQ = F_NUM + 1
F_DIST = F_NUM + 2

DIST_NAMES = [f"d_{n}" for n in NUMERIC_NAMES] + ["word_overlap", "first_word_eq"]
assert len(DIST_NAMES) == F_DIST

# Sentinel used for invalid / padded cells inside the uint32 hash space.
# ``ingest`` remaps genuine hashes equal to the sentinel, so it is exact.
HASH_SENTINEL = 0xFFFFFFFF
