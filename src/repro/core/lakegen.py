"""Synthetic data-lake generator with join ground truth *by construction*.

The paper hand-labels 4,318 candidate joins from 160 open datasets (plus the
SANTOS/TUS/D3L benchmark lakes). Offline we cannot fetch those, so this
module synthesizes lakes that reproduce the *generating process* the paper
describes for real lakes:

* **domains** — independent semantic concepts, each with its own vocabulary
  of values, value-frequency skew (Zipf), and string format;
* **granularity chains** — a domain can exist at several granularity levels
  (cities-of-a-country ⊂ cities-of-a-continent): coarser levels are subsets
  of finer ones, so cross-level pairs overlap heavily yet are *not* semantic
  joins (the paper's central observation about cardinality proportion);
* **surface-form collisions** — collision groups of domains share a fraction
  of raw values ("pol, jap, chn" = countries *or* languages): high overlap,
  different semantics → syntactic joins;
* **heterogeneity** — per-column row counts, vocabulary coverage, skew and
  null rates vary widely (data-lake syntactic variability).

Labels: a pair is **semantic** iff same domain and same granularity level;
**syntactic** iff it intersects but is not semantic (cross-granularity or
collision-group or chance overlap). Pairs with empty intersection are not
join candidates (the paper filters those out too).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.ingest import ColumnBatch, ColumnSketch, fold32, pack_columns
from repro.core.sketches import PackedSketches, pack_sketches


def splitmix64(x: np.ndarray) -> np.ndarray:
    z = (x.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15))
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class LakeSpec:
    n_domains: int = 24
    n_tables: int = 60
    cols_per_table: tuple[int, int] = (3, 10)
    # granularity: probability a domain has 2 / 3 levels; size ratio per level
    p_multi_gran: float = 0.5
    gran_ratio: tuple[int, int] = (3, 6)
    # vocabulary sizes (lognormal over base level)
    vocab_log_mean: float = 6.0       # ~400 values
    vocab_log_sigma: float = 1.0
    # per-column sampling
    rows_log_mean: float = 7.0        # ~1100 rows
    rows_log_sigma: float = 0.9
    # within-(domain, granularity) row-count spread. The paper's central
    # assumption is that columns describing the same concept at the same
    # granularity have comparable scales; rows_within_sigma ≪ rows_log_sigma
    # encodes that (per-concept base size × small per-column jitter).
    rows_within_sigma: float = 0.35
    row_budget: int = 4096
    zipf_range: tuple[float, float] = (0.01, 1.4)
    coverage_range: tuple[float, float] = (0.35, 1.0)
    null_range: tuple[float, float] = (0.0, 0.1)
    # surface-form collisions
    n_collision_groups: int = 4
    collision_frac: float = 0.5
    seed: int = 0


@dataclasses.dataclass
class Lake:
    spec: LakeSpec
    batch: ColumnBatch
    sketches: list[ColumnSketch]
    packed: PackedSketches
    domain: np.ndarray      # (C,) int32 domain id per column
    gran: np.ndarray        # (C,) int32 granularity level per column
    table: np.ndarray       # (C,) int32
    raw_bytes: int          # nominal "CSV" size: sum of char_len + separators

    @property
    def n_columns(self) -> int:
        return self.batch.n_columns

    def is_semantic(self, i: int | np.ndarray, j: int | np.ndarray) -> np.ndarray:
        return (self.domain[i] == self.domain[j]) & (self.gran[i] == self.gran[j])


def _build_domain_vocabs(spec: LakeSpec, rng: np.random.Generator):
    """Global value-id vocabularies per (domain, granularity level)."""
    vocabs: list[list[np.ndarray]] = []
    next_id = 1
    for d in range(spec.n_domains):
        base = int(np.clip(rng.lognormal(spec.vocab_log_mean, spec.vocab_log_sigma), 24, 200_000))
        levels = [np.arange(next_id, next_id + base, dtype=np.uint64)]
        next_id += base
        n_levels = 1
        if rng.random() < spec.p_multi_gran:
            n_levels = int(rng.integers(2, 4))
        for _ in range(1, n_levels):
            ratio = int(rng.integers(spec.gran_ratio[0], spec.gran_ratio[1] + 1))
            extra = levels[-1].shape[0] * (ratio - 1)
            finer = np.concatenate([levels[-1], np.arange(next_id, next_id + extra, dtype=np.uint64)])
            next_id += extra
            levels.append(finer)
        vocabs.append(levels)

    # collision groups: domains in a group alias a fraction of their *base*
    # values to shared ids (same surface form, different semantics)
    dom_ids = rng.permutation(spec.n_domains)
    gsize = max(2, spec.n_domains // max(spec.n_collision_groups, 1)) if spec.n_collision_groups else 0
    for g in range(spec.n_collision_groups):
        members = dom_ids[g * gsize:(g + 1) * gsize]
        if len(members) < 2:
            continue
        share = int(min(min(vocabs[m][0].shape[0] for m in members) * spec.collision_frac, 4096))
        if share < 1:
            continue
        shared = np.arange(next_id, next_id + share, dtype=np.uint64)
        next_id += share
        for m in members:
            for lv in range(len(vocabs[m])):
                v = vocabs[m][lv].copy()
                pos = rng.choice(v.shape[0], size=share, replace=False)
                v[pos] = shared
                vocabs[m][lv] = v
    return vocabs


def _string_format(domain: int):
    """Deterministic per-domain string format (drives syntactic features)."""
    r = np.random.default_rng(0xD0 + domain)
    base_len = int(r.integers(3, 24))
    spread = int(r.integers(1, 12))
    max_words = int(r.integers(1, 5))
    return base_len, spread, max_words


def _value_strings(vids: np.ndarray, domain: int):
    base_len, spread, max_words = _string_format(domain)
    h = splitmix64(vids)
    char_len = (base_len + (h % np.uint64(spread)).astype(np.int64)).astype(np.float32)
    word_cnt = (1 + (h >> np.uint64(17)) % np.uint64(max_words)).astype(np.float32)
    return char_len, word_cnt


def generate_lake(spec: LakeSpec) -> Lake:
    rng = np.random.default_rng(spec.seed)
    vocabs = _build_domain_vocabs(spec, rng)

    # per-(domain, granularity) base row scale — concepts have a size
    base_rows = {
        (d, lv): float(np.clip(rng.lognormal(spec.rows_log_mean + 0.5 * lv,
                                             spec.rows_log_sigma),
                               16, spec.row_budget))
        for d in range(spec.n_domains) for lv in range(len(vocabs[d]))
    }

    names, h64s, cls, wcs = [], [], [], []
    dom_l, gran_l, tab_l = [], [], []
    raw_bytes = 0

    col_id = 0
    for t in range(spec.n_tables):
        n_cols = int(rng.integers(spec.cols_per_table[0], spec.cols_per_table[1] + 1))
        for _ in range(n_cols):
            d = int(rng.integers(0, spec.n_domains))
            lv = int(rng.integers(0, len(vocabs[d])))
            vocab = vocabs[d][lv]
            n_rows = int(np.clip(
                base_rows[(d, lv)] * rng.lognormal(0.0, spec.rows_within_sigma),
                16, spec.row_budget))
            cov = rng.uniform(*spec.coverage_range)
            support_n = max(2, min(int(vocab.shape[0] * cov), vocab.shape[0], n_rows * 4))
            support = rng.choice(vocab, size=support_n, replace=False)
            a = rng.uniform(*spec.zipf_range)
            p = (np.arange(1, support_n + 1, dtype=np.float64)) ** (-a)
            p /= p.sum()
            vids = rng.choice(support, size=n_rows, p=p)
            null_frac = rng.uniform(*spec.null_range)
            keep = rng.random(n_rows) >= null_frac
            vids = vids[keep]
            if vids.shape[0] < 4:
                vids = support[:4].astype(np.uint64)
            h64 = splitmix64(vids)
            cl, wc = _value_strings(vids, d)
            raw_bytes += int(cl.sum()) + vids.shape[0]

            names.append(f"t{t}_c{col_id}_d{d}g{lv}")
            h64s.append(h64)
            cls.append(cl)
            wcs.append(wc)
            dom_l.append(d)
            gran_l.append(lv)
            tab_l.append(t)
            col_id += 1

    batch, sketches = pack_columns(names, h64s, cls, wcs, row_budget=spec.row_budget,
                                   table_ids=tab_l)
    packed = pack_sketches(sketches)
    return Lake(spec=spec, batch=batch, sketches=sketches, packed=packed,
                domain=np.asarray(dom_l, np.int32), gran=np.asarray(gran_l, np.int32),
                table=np.asarray(tab_l, np.int32), raw_bytes=raw_bytes)


# ---------------------------------------------------------------------------
# scaled lakes (10^5+ columns)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScaledLakeSpec:
    """Generator spec for very large lakes with *planted* joinability.

    The per-row sampling of :func:`generate_lake` is a faithful model but
    tops out around 10^3-10^4 columns (a Python loop per column).  Scale
    benchmarks need 10^5-10^6, so this spec drives a fully vectorized
    generator that builds the :class:`~repro.core.ingest.ColumnBatch`
    arrays directly: a ``joinable_frac`` of the columns is organized into
    join groups of ``group_size`` members whose pairwise Jaccard is
    controlled per group by cycling through ``jaccard_tiers`` (high =
    easy candidates, low = the tail a coarse pass must not lose); the
    rest are pairwise-disjoint noise.  Group members are striped across
    tables so same-table exclusion never hides a planted partner.
    """

    n_columns: int = 100_000
    row_budget: int = 256          # rows per column (small: profiles+sigs
    group_size: int = 16           # only ever see the value *set*)
    cols_per_table: int = 8
    joinable_frac: float = 0.12
    jaccard_tiers: tuple[float, ...] = (0.8, 0.4, 0.2)
    vocab_size: int = 160          # shared value pool per join group
    seed: int = 0


@dataclasses.dataclass
class ScaledLake:
    """A generated scale lake: the packed batch plus planted ground truth
    (``group``/``tier`` are -1 for noise columns)."""

    spec: ScaledLakeSpec
    batch: ColumnBatch
    group: np.ndarray       # (C,) int32 join-group id, -1 = noise
    tier: np.ndarray        # (C,) int32 index into spec.jaccard_tiers
    table: np.ndarray       # (C,) int32

    @property
    def n_columns(self) -> int:
        return self.batch.n_columns

    def partners(self, q: int) -> np.ndarray:
        """Planted join partners of column ``q`` (empty for noise)."""
        g = int(self.group[q])
        if g < 0:
            return np.zeros((0,), np.int64)
        out = np.flatnonzero(self.group == g)
        return out[out != q]


def generate_scaled_lake(spec: ScaledLakeSpec) -> ScaledLake:
    """Vectorized 10^5+-column lake with controlled joinability tiers.

    Each join group owns a ``vocab_size`` value pool; a member's support
    is a uniform ``s``-subset with ``s/V = 2J/(1+J)``, which makes the
    expected pairwise Jaccard of two members exactly ``J`` (the group's
    tier).  Every support value appears in at least one row, so the
    realized value *set* is the support itself and the tier holds for
    the MinHash signatures, not just in expectation over sampling.
    """
    rng = np.random.default_rng(spec.seed)
    c, r, v = spec.n_columns, spec.row_budget, spec.vocab_size
    if r < v:
        raise ValueError(f"row_budget ({r}) must be >= vocab_size ({v}) "
                         f"so a support always fits its rows")
    tiers = tuple(float(j) for j in spec.jaccard_tiers)
    n_groups = (int(c * spec.joinable_frac) // max(spec.group_size, 2)
                if tiers else 0)
    n_planted = n_groups * spec.group_size

    # planted columns occupy indices [0, n_planted) in a strided layout:
    # column p belongs to group p % n_groups (member p // n_groups), so
    # members sit n_groups columns apart — different tables whenever
    # n_groups >= cols_per_table
    group = np.full((c,), -1, np.int32)
    tier = np.full((c,), -1, np.int32)
    if n_groups:
        p = np.arange(n_planted)
        group[:n_planted] = (p % n_groups).astype(np.int32)
        tier[:n_planted] = (group[:n_planted] % len(tiers)).astype(np.int32)

    vids = np.empty((c, r), np.uint64)
    for t, j in enumerate(tiers):
        idx = np.flatnonzero(tier == t)
        if idx.size == 0:
            continue
        q = 2.0 * j / (1.0 + j)            # support fraction for Jaccard j
        s = int(np.clip(round(q * v), 2, v))
        perms = rng.permuted(
            np.broadcast_to(np.arange(v, dtype=np.uint64),
                            (idx.size, v)).copy(), axis=1)
        sup = perms[:, :s] + group[idx, None].astype(np.uint64) * v + 1
        extra = np.take_along_axis(
            sup, rng.integers(0, s, size=(idx.size, r - s)), axis=1)
        vids[idx] = np.concatenate([sup, extra], axis=1)

    # noise columns: private disjoint id ranges — no cross-column overlap
    noise = np.flatnonzero(group < 0)
    base = np.uint64(n_groups) * np.uint64(v) + np.uint64(1)
    for i in range(0, noise.size, 8192):
        blk = noise[i:i + 8192]
        vids[blk] = (base + blk[:, None].astype(np.uint64) * np.uint64(r)
                     + np.arange(r, dtype=np.uint64)[None, :])

    h = splitmix64(vids)
    values32 = fold32(h)
    # per-OWNER string style (owner = join group for planted columns, the
    # column itself for noise): every value belongs to exactly one owner,
    # so the style is consistent wherever a value appears — group members
    # share syntactic profiles while unrelated columns differ, which is
    # what lets a profile-distance model separate them
    owner = np.where(group >= 0, group.astype(np.int64),
                     np.int64(n_groups) + np.arange(c))
    st = splitmix64(owner.astype(np.uint64) + np.uint64(0x51AB))
    base_len = (4 + st % np.uint64(13))[:, None]
    spread = (2 + (st >> np.uint64(8)) % np.uint64(9))[:, None]
    wmax = (1 + (st >> np.uint64(16)) % np.uint64(4))[:, None]
    char_len = (base_len + h % spread).astype(np.float32)
    word_cnt = (1 + h % wmax).astype(np.float32)
    table = (np.arange(c) // spec.cols_per_table).astype(np.int32)
    batch = ColumnBatch(values32=values32, char_len=char_len,
                        word_cnt=word_cnt,
                        n_rows=np.full((c,), r, np.int32),
                        names=[f"c{i}" for i in range(c)],
                        table_ids=table)
    return ScaledLake(spec=spec, batch=batch, group=group, tier=tier,
                      table=table)


def select_scaled_queries(lake: ScaledLake, n_queries: int,
                          seed: int = 1) -> np.ndarray:
    """Planted columns to query, balanced across joinability tiers (every
    query has ``group_size - 1`` genuine partners in the lake)."""
    rng = np.random.default_rng(seed)
    out: list[np.ndarray] = []
    tiers = np.unique(lake.tier[lake.tier >= 0])
    if tiers.size == 0:
        raise ValueError("lake has no planted join groups to query")
    per = -(-n_queries // tiers.size)
    for t in tiers:
        idx = np.flatnonzero(lake.tier == t)
        out.append(rng.choice(idx, size=min(per, idx.size), replace=False))
    sel = np.concatenate(out)
    rng.shuffle(sel)
    return np.sort(sel[:n_queries]).astype(np.int32)


def select_queries(lake: Lake, n_queries: int, min_semantic: int = 3,
                   seed: int = 1) -> np.ndarray:
    """Query columns having at least ``min_semantic`` semantic partners
    outside their own table (mirrors the paper's query selection)."""
    rng = np.random.default_rng(seed)
    c = lake.n_columns
    counts = np.zeros((c,), np.int32)
    for d in np.unique(lake.domain):
        for g in np.unique(lake.gran):
            m = np.flatnonzero((lake.domain == d) & (lake.gran == g))
            if m.size < 2:
                continue
            # partners outside own table
            for i in m:
                counts[i] = np.sum(lake.table[m] != lake.table[i])
    cand = np.flatnonzero(counts >= min_semantic)
    if cand.size == 0:
        cand = np.argsort(-counts)[:n_queries]
    rng.shuffle(cand)
    return np.sort(cand[:n_queries]).astype(np.int32)
