"""Synthetic data-lake generator with join ground truth *by construction*.

The paper hand-labels 4,318 candidate joins from 160 open datasets (plus the
SANTOS/TUS/D3L benchmark lakes). Offline we cannot fetch those, so this
module synthesizes lakes that reproduce the *generating process* the paper
describes for real lakes:

* **domains** — independent semantic concepts, each with its own vocabulary
  of values, value-frequency skew (Zipf), and string format;
* **granularity chains** — a domain can exist at several granularity levels
  (cities-of-a-country ⊂ cities-of-a-continent): coarser levels are subsets
  of finer ones, so cross-level pairs overlap heavily yet are *not* semantic
  joins (the paper's central observation about cardinality proportion);
* **surface-form collisions** — collision groups of domains share a fraction
  of raw values ("pol, jap, chn" = countries *or* languages): high overlap,
  different semantics → syntactic joins;
* **heterogeneity** — per-column row counts, vocabulary coverage, skew and
  null rates vary widely (data-lake syntactic variability).

Labels: a pair is **semantic** iff same domain and same granularity level;
**syntactic** iff it intersects but is not semantic (cross-granularity or
collision-group or chance overlap). Pairs with empty intersection are not
join candidates (the paper filters those out too).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.ingest import ColumnBatch, ColumnSketch, pack_columns
from repro.core.sketches import PackedSketches, pack_sketches


def splitmix64(x: np.ndarray) -> np.ndarray:
    z = (x.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15))
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class LakeSpec:
    n_domains: int = 24
    n_tables: int = 60
    cols_per_table: tuple[int, int] = (3, 10)
    # granularity: probability a domain has 2 / 3 levels; size ratio per level
    p_multi_gran: float = 0.5
    gran_ratio: tuple[int, int] = (3, 6)
    # vocabulary sizes (lognormal over base level)
    vocab_log_mean: float = 6.0       # ~400 values
    vocab_log_sigma: float = 1.0
    # per-column sampling
    rows_log_mean: float = 7.0        # ~1100 rows
    rows_log_sigma: float = 0.9
    # within-(domain, granularity) row-count spread. The paper's central
    # assumption is that columns describing the same concept at the same
    # granularity have comparable scales; rows_within_sigma ≪ rows_log_sigma
    # encodes that (per-concept base size × small per-column jitter).
    rows_within_sigma: float = 0.35
    row_budget: int = 4096
    zipf_range: tuple[float, float] = (0.01, 1.4)
    coverage_range: tuple[float, float] = (0.35, 1.0)
    null_range: tuple[float, float] = (0.0, 0.1)
    # surface-form collisions
    n_collision_groups: int = 4
    collision_frac: float = 0.5
    seed: int = 0


@dataclasses.dataclass
class Lake:
    spec: LakeSpec
    batch: ColumnBatch
    sketches: list[ColumnSketch]
    packed: PackedSketches
    domain: np.ndarray      # (C,) int32 domain id per column
    gran: np.ndarray        # (C,) int32 granularity level per column
    table: np.ndarray       # (C,) int32
    raw_bytes: int          # nominal "CSV" size: sum of char_len + separators

    @property
    def n_columns(self) -> int:
        return self.batch.n_columns

    def is_semantic(self, i: int | np.ndarray, j: int | np.ndarray) -> np.ndarray:
        return (self.domain[i] == self.domain[j]) & (self.gran[i] == self.gran[j])


def _build_domain_vocabs(spec: LakeSpec, rng: np.random.Generator):
    """Global value-id vocabularies per (domain, granularity level)."""
    vocabs: list[list[np.ndarray]] = []
    next_id = 1
    for d in range(spec.n_domains):
        base = int(np.clip(rng.lognormal(spec.vocab_log_mean, spec.vocab_log_sigma), 24, 200_000))
        levels = [np.arange(next_id, next_id + base, dtype=np.uint64)]
        next_id += base
        n_levels = 1
        if rng.random() < spec.p_multi_gran:
            n_levels = int(rng.integers(2, 4))
        for _ in range(1, n_levels):
            ratio = int(rng.integers(spec.gran_ratio[0], spec.gran_ratio[1] + 1))
            extra = levels[-1].shape[0] * (ratio - 1)
            finer = np.concatenate([levels[-1], np.arange(next_id, next_id + extra, dtype=np.uint64)])
            next_id += extra
            levels.append(finer)
        vocabs.append(levels)

    # collision groups: domains in a group alias a fraction of their *base*
    # values to shared ids (same surface form, different semantics)
    dom_ids = rng.permutation(spec.n_domains)
    gsize = max(2, spec.n_domains // max(spec.n_collision_groups, 1)) if spec.n_collision_groups else 0
    for g in range(spec.n_collision_groups):
        members = dom_ids[g * gsize:(g + 1) * gsize]
        if len(members) < 2:
            continue
        share = int(min(min(vocabs[m][0].shape[0] for m in members) * spec.collision_frac, 4096))
        if share < 1:
            continue
        shared = np.arange(next_id, next_id + share, dtype=np.uint64)
        next_id += share
        for m in members:
            for lv in range(len(vocabs[m])):
                v = vocabs[m][lv].copy()
                pos = rng.choice(v.shape[0], size=share, replace=False)
                v[pos] = shared
                vocabs[m][lv] = v
    return vocabs


def _string_format(domain: int):
    """Deterministic per-domain string format (drives syntactic features)."""
    r = np.random.default_rng(0xD0 + domain)
    base_len = int(r.integers(3, 24))
    spread = int(r.integers(1, 12))
    max_words = int(r.integers(1, 5))
    return base_len, spread, max_words


def _value_strings(vids: np.ndarray, domain: int):
    base_len, spread, max_words = _string_format(domain)
    h = splitmix64(vids)
    char_len = (base_len + (h % np.uint64(spread)).astype(np.int64)).astype(np.float32)
    word_cnt = (1 + (h >> np.uint64(17)) % np.uint64(max_words)).astype(np.float32)
    return char_len, word_cnt


def generate_lake(spec: LakeSpec) -> Lake:
    rng = np.random.default_rng(spec.seed)
    vocabs = _build_domain_vocabs(spec, rng)

    # per-(domain, granularity) base row scale — concepts have a size
    base_rows = {
        (d, lv): float(np.clip(rng.lognormal(spec.rows_log_mean + 0.5 * lv,
                                             spec.rows_log_sigma),
                               16, spec.row_budget))
        for d in range(spec.n_domains) for lv in range(len(vocabs[d]))
    }

    names, h64s, cls, wcs = [], [], [], []
    dom_l, gran_l, tab_l = [], [], []
    raw_bytes = 0

    col_id = 0
    for t in range(spec.n_tables):
        n_cols = int(rng.integers(spec.cols_per_table[0], spec.cols_per_table[1] + 1))
        for _ in range(n_cols):
            d = int(rng.integers(0, spec.n_domains))
            lv = int(rng.integers(0, len(vocabs[d])))
            vocab = vocabs[d][lv]
            n_rows = int(np.clip(
                base_rows[(d, lv)] * rng.lognormal(0.0, spec.rows_within_sigma),
                16, spec.row_budget))
            cov = rng.uniform(*spec.coverage_range)
            support_n = max(2, min(int(vocab.shape[0] * cov), vocab.shape[0], n_rows * 4))
            support = rng.choice(vocab, size=support_n, replace=False)
            a = rng.uniform(*spec.zipf_range)
            p = (np.arange(1, support_n + 1, dtype=np.float64)) ** (-a)
            p /= p.sum()
            vids = rng.choice(support, size=n_rows, p=p)
            null_frac = rng.uniform(*spec.null_range)
            keep = rng.random(n_rows) >= null_frac
            vids = vids[keep]
            if vids.shape[0] < 4:
                vids = support[:4].astype(np.uint64)
            h64 = splitmix64(vids)
            cl, wc = _value_strings(vids, d)
            raw_bytes += int(cl.sum()) + vids.shape[0]

            names.append(f"t{t}_c{col_id}_d{d}g{lv}")
            h64s.append(h64)
            cls.append(cl)
            wcs.append(wc)
            dom_l.append(d)
            gran_l.append(lv)
            tab_l.append(t)
            col_id += 1

    batch, sketches = pack_columns(names, h64s, cls, wcs, row_budget=spec.row_budget,
                                   table_ids=tab_l)
    packed = pack_sketches(sketches)
    return Lake(spec=spec, batch=batch, sketches=sketches, packed=packed,
                domain=np.asarray(dom_l, np.int32), gran=np.asarray(gran_l, np.int32),
                table=np.asarray(tab_l, np.int32), raw_bytes=raw_bytes)


def select_queries(lake: Lake, n_queries: int, min_semantic: int = 3,
                   seed: int = 1) -> np.ndarray:
    """Query columns having at least ``min_semantic`` semantic partners
    outside their own table (mirrors the paper's query selection)."""
    rng = np.random.default_rng(seed)
    c = lake.n_columns
    counts = np.zeros((c,), np.int32)
    for d in np.unique(lake.domain):
        for g in np.unique(lake.gran):
            m = np.flatnonzero((lake.domain == d) & (lake.gran == g))
            if m.size < 2:
                continue
            # partners outside own table
            for i in m:
                counts[i] = np.sum(lake.table[m] != lake.table[i])
    cand = np.flatnonzero(counts >= min_semantic)
    if cand.size == 0:
        cand = np.argsort(-counts)[:n_queries]
    rng.shuffle(cand)
    return np.sort(cand[:n_queries]).astype(np.int32)
