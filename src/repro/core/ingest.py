"""Offline ingest: raw columns -> fixed-width tensors + exact sketches.

This is the analogue of the paper's "offline processing phase" (DuckDB in the
Java implementation). TPUs cannot process variable-length strings, so ingest
converts every cell into

* a **64-bit stable hash** of its string form (equality-preserving — an
  equi-join only needs value identity),
* its **character length** and **word count** (the syntactic profile
  features of Table II),
* a validity bit (nulls / missing cells).

Per column we additionally build an exact **sketch**: the sorted distinct
64-bit hashes and their counts. Sketches power the exact multiset-Jaccard
path (ground-truth labels + the "exact metric" baseline the paper says is
infeasible at lake scale — we implement it anyway as the comparison point).

Inside JAX we use the folded 32-bit hash (hi ^ lo); the exact/label path
keeps the full 64 bits in numpy. See DESIGN.md §5.1.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core import features as FT

_FNV64_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV64_PRIME = np.uint64(0x100000001B3)
_MIX = np.uint64(0xBF58476D1CE4E5B9)


def hash64(s: str) -> np.uint64:
    """Stable FNV-1a 64-bit hash with a splitmix finalizer."""
    h = _FNV64_OFFSET
    for b in s.encode("utf-8"):
        h = np.uint64((int(h) ^ b) * int(_FNV64_PRIME) & 0xFFFFFFFFFFFFFFFF)
    # splitmix-style avalanche
    z = int(h)
    z = (z ^ (z >> 30)) * int(_MIX) & 0xFFFFFFFFFFFFFFFF
    z = (z ^ (z >> 27)) * 0x94D049BB133111EB & 0xFFFFFFFFFFFFFFFF
    return np.uint64(z ^ (z >> 31))


def fold32(h64: np.ndarray) -> np.ndarray:
    """Fold uint64 hashes to the uint32 space used on-device."""
    h = (h64 >> np.uint64(32)) ^ (h64 & np.uint64(0xFFFFFFFF))
    h = h.astype(np.uint32)
    # keep the sentinel exact: remap real 0xFFFFFFFF
    return np.where(h == np.uint32(FT.HASH_SENTINEL), np.uint32(FT.HASH_SENTINEL - 1), h)


@dataclasses.dataclass
class ColumnSketch:
    """Exact distinct-value sketch (numpy, offline only)."""

    values: np.ndarray   # (k,) uint64, sorted ascending
    counts: np.ndarray   # (k,) int64
    n_rows: int          # multiset size |A| (valid rows)

    @property
    def cardinality(self) -> int:
        return int(self.values.shape[0])


@dataclasses.dataclass
class ColumnBatch:
    """A batch of columns as fixed-width device-ready tensors.

    All arrays are padded to the same row budget ``R``; ``n_rows`` holds the
    true number of valid rows per column. Hash padding uses
    ``features.HASH_SENTINEL``.
    """

    values32: np.ndarray   # (C, R) uint32
    char_len: np.ndarray   # (C, R) float32
    word_cnt: np.ndarray   # (C, R) float32
    n_rows: np.ndarray     # (C,)  int32
    names: list[str]
    table_ids: np.ndarray  # (C,) int32 — owning dataset

    @property
    def n_columns(self) -> int:
        return int(self.values32.shape[0])

    @property
    def row_budget(self) -> int:
        return int(self.values32.shape[1])


def sketch_from_hashes(h64: np.ndarray) -> ColumnSketch:
    vals, counts = np.unique(h64, return_counts=True)
    return ColumnSketch(values=vals, counts=counts.astype(np.int64), n_rows=int(h64.shape[0]))


def ingest_string_columns(
    columns: Sequence[tuple[str, Iterable[str | None]]],
    *,
    row_budget: int | None = None,
    table_ids: Sequence[int] | None = None,
) -> tuple[ColumnBatch, list[ColumnSketch]]:
    """Ingest raw string columns (the quickstart / CSV path)."""
    names, all_h64, all_cl, all_wc = [], [], [], []
    for name, cells in columns:
        h64, cl, wc = [], [], []
        for cell in cells:
            if cell is None or (isinstance(cell, float) and np.isnan(cell)):
                continue
            s = str(cell).strip()
            if not s:
                continue
            h64.append(hash64(s))
            cl.append(len(s))
            wc.append(max(1, len(s.split())))
        names.append(name)
        all_h64.append(np.asarray(h64, dtype=np.uint64))
        all_cl.append(np.asarray(cl, dtype=np.float32))
        all_wc.append(np.asarray(wc, dtype=np.float32))
    return pack_columns(names, all_h64, all_cl, all_wc, row_budget=row_budget, table_ids=table_ids)


def pack_columns(
    names: list[str],
    h64_list: list[np.ndarray],
    char_len_list: list[np.ndarray],
    word_cnt_list: list[np.ndarray],
    *,
    row_budget: int | None = None,
    table_ids: Sequence[int] | None = None,
) -> tuple[ColumnBatch, list[ColumnSketch]]:
    """Pack per-column ragged arrays into a padded ColumnBatch + sketches."""
    c = len(names)
    max_rows = max((int(h.shape[0]) for h in h64_list), default=1)
    budget = int(row_budget or max_rows)
    budget = max(budget, 1)

    values32 = np.full((c, budget), FT.HASH_SENTINEL, dtype=np.uint32)
    char_len = np.zeros((c, budget), dtype=np.float32)
    word_cnt = np.zeros((c, budget), dtype=np.float32)
    n_rows = np.zeros((c,), dtype=np.int32)
    sketches: list[ColumnSketch] = []

    for i, h64 in enumerate(h64_list):
        n = min(int(h64.shape[0]), budget)
        if int(h64.shape[0]) > budget:
            # deterministic row subsample when a column exceeds the budget
            rng = np.random.default_rng(0xF0E1 + i)
            idx = np.sort(rng.choice(h64.shape[0], size=budget, replace=False))
            h64 = h64[idx]
            char_len_list[i] = char_len_list[i][idx]
            word_cnt_list[i] = word_cnt_list[i][idx]
        values32[i, :n] = fold32(h64[:n])
        char_len[i, :n] = char_len_list[i][:n]
        word_cnt[i, :n] = word_cnt_list[i][:n]
        n_rows[i] = n
        sketches.append(sketch_from_hashes(h64[:n]))

    tids = np.asarray(table_ids if table_ids is not None else np.zeros((c,)), dtype=np.int32)
    batch = ColumnBatch(values32=values32, char_len=char_len, word_cnt=word_cnt,
                        n_rows=n_rows, names=names, table_ids=tids)
    return batch, sketches


def concat_batches(batches: Sequence[ColumnBatch]) -> ColumnBatch:
    budget = max(b.row_budget for b in batches)

    def pad(a, fill):
        return np.pad(a, ((0, 0), (0, budget - a.shape[1])), constant_values=fill)

    return ColumnBatch(
        values32=np.concatenate([pad(b.values32, FT.HASH_SENTINEL) for b in batches]),
        char_len=np.concatenate([pad(b.char_len, 0) for b in batches]),
        word_cnt=np.concatenate([pad(b.word_cnt, 0) for b in batches]),
        n_rows=np.concatenate([b.n_rows for b in batches]),
        names=sum((b.names for b in batches), []),
        table_ids=np.concatenate([b.table_ids for b in batches]),
    )
