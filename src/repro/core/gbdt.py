"""Oblivious-tree gradient boosting — the paper's GBM, reshaped for the MXU.

The paper trains a Gradient Boosting regressor (50 estimators, default
hyperparameters otherwise) on profile-difference vectors. A classical GBDT
traverses per-node branches — scalar, pointer-chasing work with no TPU
analogue. We adapt the insight, not the implementation (DESIGN.md §2):
**oblivious (symmetric) trees** use one (feature, threshold) pair per *level*,
so inference is

    leaf_index = Σ_level  (x[feat_l] ≥ thr_l) << l          (VPU compares)
    prediction += one_hot(leaf_index, 2^depth) @ leaves      (MXU matmul)

which is branch-free and batchable — the same trick CatBoost uses on CPU
SIMD. Training (histogram-based greedy, second-order boosting) runs offline
in numpy: the paper's model is trained once, off-line, and shipped; only
inference must scale to lake size.

Parameters are exported as dense arrays consumed by ``kernels/gbdt_infer``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GBDTParams:
    """Dense parameterization of an oblivious-tree ensemble."""

    feats: np.ndarray    # (T, D) int32   — feature index per (tree, level)
    thrs: np.ndarray     # (T, D) float32 — threshold per (tree, level)
    leaves: np.ndarray   # (T, 2^D) float32
    base: float          # initial prediction (mean of targets)

    @property
    def n_trees(self) -> int:
        return int(self.feats.shape[0])

    @property
    def depth(self) -> int:
        return int(self.feats.shape[1])

    def astuple(self):
        return self.feats, self.thrs, self.leaves, np.float32(self.base)

    def save(self, path: str) -> None:
        np.savez(path, feats=self.feats, thrs=self.thrs, leaves=self.leaves,
                 base=np.float32(self.base))

    @staticmethod
    def load(path: str) -> "GBDTParams":
        z = np.load(path)
        return GBDTParams(feats=z["feats"], thrs=z["thrs"], leaves=z["leaves"],
                          base=float(z["base"]))


@dataclasses.dataclass
class GBDTConfig:
    n_trees: int = 50          # paper: estimators reduced 100 -> 50
    depth: int = 5
    learning_rate: float = 0.1
    n_bins: int = 32
    l2: float = 1.0
    min_child_weight: float = 4.0
    seed: int = 0


def _quantile_bins(x: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature candidate thresholds from quantiles (unique-ified)."""
    qs = np.quantile(x, np.linspace(0.02, 0.98, n_bins), axis=0)
    return qs  # (n_bins, F)


def fit_gbdt(x: np.ndarray, y: np.ndarray, cfg: GBDTConfig = GBDTConfig()) -> GBDTParams:
    """Second-order (hessian = 1 for L2 loss) oblivious-tree boosting.

    Histogram-based: features are digitized into ``n_bins`` quantile bins
    once; per (tree, level) a single scatter-add builds the (node, bin)
    gradient/hessian histograms and suffix sums score every threshold of
    every feature at once.
    """
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float64)
    n, f = x.shape
    t, d = cfg.n_trees, cfg.depth
    b = cfg.n_bins
    thr_cand = _quantile_bins(x, b)                        # (B, F)
    # digitize: bin[i, fi] = #thresholds <= x[i, fi]  ∈ [0, B]
    binidx = np.empty((n, f), np.int32)
    for fi in range(f):
        thr_sorted = np.sort(thr_cand[:, fi])
        thr_cand[:, fi] = thr_sorted
        binidx[:, fi] = np.searchsorted(thr_sorted, x[:, fi], side="right")

    base = float(np.mean(y))
    pred = np.full((n,), base, dtype=np.float64)

    feats = np.zeros((t, d), np.int32)
    thrs = np.zeros((t, d), np.float32)
    leaves = np.zeros((t, 2 ** d), np.float32)

    for ti in range(t):
        grad = pred - y                                    # dL/dpred, L2 loss
        node = np.zeros((n,), np.int64)                    # current leaf index
        for lvl in range(d):
            n_nodes = 2 ** lvl
            g_tot = np.bincount(node, weights=grad, minlength=n_nodes)
            h_tot = np.bincount(node, minlength=n_nodes).astype(np.float64)
            parent_score = np.sum(g_tot ** 2 / (h_tot + cfg.l2))

            best = (1e-12, -1, 0.0)
            for fi in range(f):
                key = node * (b + 1) + binidx[:, fi]
                g_hist = np.bincount(key, weights=grad, minlength=n_nodes * (b + 1))
                h_hist = np.bincount(key, minlength=n_nodes * (b + 1)).astype(np.float64)
                g_hist = g_hist.reshape(n_nodes, b + 1)
                h_hist = h_hist.reshape(n_nodes, b + 1)
                # right side of threshold bi = bins >= bi + 1 (suffix sums)
                g_sfx = np.cumsum(g_hist[:, ::-1], axis=1)[:, ::-1]
                h_sfx = np.cumsum(h_hist[:, ::-1], axis=1)[:, ::-1]
                g_r = g_sfx[:, 1:b + 1].T                  # (B, n_nodes)
                h_r = h_sfx[:, 1:b + 1].T
                g_l, h_l = g_tot[None] - g_r, h_tot[None] - h_r
                score = (g_l ** 2 / (h_l + cfg.l2) + g_r ** 2 / (h_r + cfg.l2)).sum(axis=1)
                valid = ((h_l >= cfg.min_child_weight) & (h_r >= cfg.min_child_weight)).any(axis=1)
                score = np.where(valid, score - parent_score, -np.inf)
                bi = int(np.argmax(score))
                if score[bi] > best[0]:
                    best = (float(score[bi]), fi, float(thr_cand[bi, fi]))
            _, fi, thr = best
            if fi < 0:        # no useful split at this level: constant level
                fi, thr = 0, np.float32(np.inf)
            feats[ti, lvl] = fi
            thrs[ti, lvl] = thr
            node = node | ((x[:, fi] >= thr).astype(np.int64) << lvl)

        g_leaf = np.bincount(node, weights=grad, minlength=2 ** d)
        h_leaf = np.bincount(node, minlength=2 ** d).astype(np.float64)
        w = -g_leaf / (h_leaf + cfg.l2) * cfg.learning_rate
        leaves[ti] = w.astype(np.float32)
        pred = pred + w[node]

    return GBDTParams(feats=feats, thrs=thrs, leaves=leaves, base=base)


def predict_np(params: GBDTParams, x: np.ndarray) -> np.ndarray:
    """Reference numpy inference (used in training-side validation)."""
    n = x.shape[0]
    out = np.full((n,), params.base, dtype=np.float64)
    for ti in range(params.n_trees):
        node = np.zeros((n,), np.int64)
        for lvl in range(params.depth):
            node |= (x[:, params.feats[ti, lvl]] >= params.thrs[ti, lvl]).astype(np.int64) << lvl
        out += params.leaves[ti][node]
    return out.astype(np.float32)
