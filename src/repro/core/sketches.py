"""Exact multiset / set intersections from column sketches.

The paper argues the all-pairs computation of J is infeasible at lake scale —
FREYJA's point is to *predict* it. We still implement the exact path because
(a) it labels the synthetic ground truth, (b) it is the "exact metric"
comparison baseline in the benchmarks, and (c) tests validate the predictor
against it.

Two implementations:
* numpy (uint64, exact) — offline label generation;
* JAX batched (uint32 folded hashes, padded distinct arrays) — the
  vectorized all-pairs baseline used in benchmarks; vmapped double
  ``searchsorted`` + count gather.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as FT
from repro.core.ingest import ColumnSketch, fold32


# ---------------------------------------------------------------------------
# numpy exact path (labels / ground truth)
# ---------------------------------------------------------------------------

def intersections_np(a: ColumnSketch, b: ColumnSketch) -> tuple[int, int]:
    """(multiset intersection, set intersection) of two sketches."""
    common, ia, ib = np.intersect1d(a.values, b.values, assume_unique=True,
                                    return_indices=True)
    multi = int(np.minimum(a.counts[ia], b.counts[ib]).sum())
    return multi, int(common.shape[0])


def pair_metrics_np(a: ColumnSketch, b: ColumnSketch) -> dict:
    multi, inter_set = intersections_np(a, b)
    ca, cb = a.cardinality, b.cardinality
    j = multi / max(a.n_rows + b.n_rows, 1)
    k = min(ca, cb) / max(max(ca, cb), 1)
    jac = inter_set / max(ca + cb - inter_set, 1)
    cont = inter_set / max(ca, 1)
    return {"j_multi": j, "k": k, "jaccard": jac, "containment": cont,
            "inter_multi": multi, "inter_set": inter_set}


# ---------------------------------------------------------------------------
# JAX batched path (padded distinct arrays)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedSketches:
    """Padded distinct-value arrays for device-side exact metrics.

    values: (C, K) uint32 sorted ascending with SENTINEL padding
    counts: (C, K) float32 (0 padding)
    card:   (C,) int32
    n_rows: (C,) int32
    """

    values: np.ndarray
    counts: np.ndarray
    card: np.ndarray
    n_rows: np.ndarray

    def nbytes(self) -> int:
        return self.values.nbytes + self.counts.nbytes + self.card.nbytes + self.n_rows.nbytes


def pack_sketches(sketches: list[ColumnSketch], k_max: int | None = None) -> PackedSketches:
    kcap = max((s.cardinality for s in sketches), default=1)
    # k must stay >= 1 even for empty lists / all-empty sketches / k_max=0:
    # zero-width value arrays crash the searchsorted probe downstream.
    k = int(kcap if k_max is None else k_max)
    k = max(k, 1)
    c = len(sketches)
    values = np.full((c, k), FT.HASH_SENTINEL, dtype=np.uint32)
    counts = np.zeros((c, k), dtype=np.float32)
    card = np.zeros((c,), dtype=np.int32)
    n_rows = np.zeros((c,), dtype=np.int32)
    for i, s in enumerate(sketches):
        v32 = fold32(s.values)
        order = np.argsort(v32, kind="stable")
        sv, sc = v32[order], s.counts[order].astype(np.float32)
        # fold32 can (rarely) merge two uint64 values; merge their counts
        uv, start = np.unique(sv, return_index=True)
        csum = np.add.reduceat(sc, start) if sv.size else np.zeros((0,), np.float32)
        kk = min(uv.shape[0], k)
        values[i, :kk] = uv[:kk]
        counts[i, :kk] = csum[:kk]
        card[i] = kk
        n_rows[i] = s.n_rows
    return PackedSketches(values=values, counts=counts, card=card, n_rows=n_rows)


def _pair_intersections(va, ca_counts, vb, cb_counts):
    """Intersections of two sorted padded sketches (uint32)."""
    pos = jnp.searchsorted(vb, va)
    pos = jnp.clip(pos, 0, vb.shape[0] - 1)
    match = (vb[pos] == va) & (va != jnp.uint32(FT.HASH_SENTINEL))
    inter_set = jnp.sum(match.astype(jnp.int32))
    inter_multi = jnp.sum(jnp.where(match, jnp.minimum(ca_counts, cb_counts[pos]), 0.0))
    return inter_multi, inter_set


@partial(jax.jit)
def batch_exact_metrics(q_values, q_counts, q_card, q_rows,
                        c_values, c_counts, c_card, c_rows):
    """All-pairs exact metrics: queries (Q, K) × corpus (N, K) -> (Q, N) each.

    Returns dict of (Q, N) arrays: j_multi, k, jaccard, containment.
    """
    def one_query(va, ca_counts, card_a, rows_a):
        def one_corpus(vb, cb_counts, card_b, rows_b):
            inter_multi, inter_set = _pair_intersections(va, ca_counts, vb, cb_counts)
            j = inter_multi / jnp.maximum((rows_a + rows_b).astype(jnp.float32), 1.0)
            cf_a = jnp.maximum(card_a.astype(jnp.float32), 1.0)
            cf_b = jnp.maximum(card_b.astype(jnp.float32), 1.0)
            k = jnp.minimum(cf_a, cf_b) / jnp.maximum(cf_a, cf_b)
            union = jnp.maximum(cf_a + cf_b - inter_set, 1.0)
            return (j, k, inter_set / union, inter_set / cf_a)
        return jax.vmap(one_corpus)(c_values, c_counts, c_card, c_rows)

    j, k, jac, cont = jax.vmap(one_query)(q_values, q_counts, q_card, q_rows)
    return {"j_multi": j, "k": k, "jaccard": jac, "containment": cont}
