"""JAX column profiling — the paper's "preparation phase", TPU-native.

The paper computes profiles with DuckDB SQL; here a single jitted function
profiles a whole batch of columns at once (vmapped sort + scan per column),
and the distributed path shards the column axis across the ``data`` mesh
axis — each device profiles its own shard of the lake, no communication.

Input:  ``ColumnBatch`` tensors   (C, R) — see ``ingest.py``
Output: ``numeric`` (C, F_NUM) float32 and ``words`` (C, F_WORDS) uint32
        laid out per ``features.py``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as FT

SENTINEL = jnp.uint32(FT.HASH_SENTINEL)


@dataclasses.dataclass
class LakeProfiles:
    """Profiles for a set of columns + lake-wide normalization stats."""

    numeric: np.ndarray      # (C, F_NUM) float32 (raw, un-normalized)
    words: np.ndarray        # (C, F_WORDS) uint32
    n_rows: np.ndarray       # (C,) int32
    mean: np.ndarray         # (F_NUM,) float32 — lake-wide z-score stats
    std: np.ndarray          # (F_NUM,) float32

    @property
    def n_columns(self) -> int:
        return int(self.numeric.shape[0])

    @property
    def zscored(self) -> np.ndarray:
        return (self.numeric - self.mean) / self.std

    def zscored_view(self) -> "ZscoreView":
        """Lazy row-gather view of :attr:`zscored` — z-scores only the
        rows actually indexed, so a memmapped lake never materializes a
        lake-sized fp32 matrix (the quantized-sidecar engine path)."""
        return ZscoreView(self.numeric, self.mean, self.std)

    def nbytes(self) -> int:
        return self.numeric.nbytes + self.words.nbytes + self.n_rows.nbytes


class ZscoreView:
    """``(numeric[idx] - mean) / std`` computed per access.

    Indexing accepts anything ``numeric`` does — an int row, a slice, or
    a (possibly 2-D) fancy-index array — and always returns fresh fp32;
    the backing ``numeric`` may be a read-only segment memmap, so reads
    page in only the touched rows.  Duck-compatible with the fp32 matrix
    the engine's eager path keeps (``shape`` / ``len`` / ``__getitem__``),
    which is all the resolve and exact-rescore paths use.
    """

    def __init__(self, numeric, mean, std):
        self.numeric = numeric
        self.mean = np.asarray(mean, np.float32)
        self.std = np.asarray(std, np.float32)

    @property
    def shape(self) -> tuple:
        return tuple(self.numeric.shape)

    @property
    def dtype(self):
        return np.dtype(np.float32)

    def __len__(self) -> int:
        return int(self.numeric.shape[0])

    def __getitem__(self, idx) -> np.ndarray:
        return (np.asarray(self.numeric[idx], np.float32)
                - self.mean) / self.std


def _masked_stats(x, valid, nf):
    """(min, max, mean, sd) of ``x`` over ``valid`` positions."""
    big = jnp.float32(3.4e38)
    mn = jnp.min(jnp.where(valid, x, big))
    mx = jnp.max(jnp.where(valid, x, -big))
    s = jnp.sum(jnp.where(valid, x, 0.0))
    s2 = jnp.sum(jnp.where(valid, x * x, 0.0))
    mean = s / nf
    var = jnp.maximum(s2 / nf - mean * mean, 0.0)
    return mn, mx, mean, jnp.sqrt(var)


def _profile_one(vals: jnp.ndarray, char_len: jnp.ndarray, word_cnt: jnp.ndarray,
                 n: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Profile a single column. vals: (R,) uint32 with sentinel padding."""
    r = vals.shape[0]
    idx = jnp.arange(r, dtype=jnp.int32)
    nf = jnp.maximum(n.astype(jnp.float32), 1.0)
    has_rows = n > 0

    # ---- frequency distribution via sort + run-length encoding ----
    sv = jnp.sort(vals)                        # sentinel sorts to the end
    is_valid = sv != SENTINEL
    is_start = is_valid & ((idx == 0) | (sv != jnp.roll(sv, 1)))
    run_id = jnp.cumsum(is_start.astype(jnp.int32)) - 1          # (R,)
    card = jnp.sum(is_start.astype(jnp.int32))
    counts = jax.ops.segment_sum(
        jnp.where(is_valid, 1, 0), jnp.clip(run_id, 0, r - 1), num_segments=r
    ).astype(jnp.float32)                       # counts[k] for run k; 0 beyond

    # value of each run (aligned with ``counts``)
    start_pos = jnp.sort(jnp.where(is_start, idx, r))
    run_vals = jnp.where(jnp.arange(r) < card,
                         sv[jnp.minimum(start_pos, r - 1)], SENTINEL)

    cardf = jnp.maximum(card.astype(jnp.float32), 1.0)
    kmask = jnp.arange(r) < card
    big = jnp.float32(3.4e38)

    min_freq = jnp.min(jnp.where(kmask, counts, big))
    max_freq = jnp.max(counts)
    perc = counts / nf
    max_perc = max_freq / nf
    mean_perc = jnp.sum(jnp.where(kmask, perc, 0.0)) / cardf
    sd_perc = jnp.sqrt(jnp.maximum(
        jnp.sum(jnp.where(kmask, (perc - mean_perc) ** 2, 0.0)) / cardf, 0.0))
    entropy = -jnp.sum(jnp.where(kmask & (counts > 0), perc * jnp.log(perc), 0.0))

    # octiles of the frequency distribution (in fractions of rows):
    # counts sorted ascending has (r - card) padding zeros first.
    scounts = jnp.sort(counts)
    base = (r - card).astype(jnp.float32)

    def octile(q):
        pos = base + q * (cardf - 1.0)
        lo = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, r - 1)
        hi = jnp.clip(lo + 1, 0, r - 1)
        w = pos - lo.astype(jnp.float32)
        return ((1.0 - w) * scounts[lo] + w * scounts[hi]) / nf

    octs = jnp.stack([octile(jnp.float32(q / 8.0)) for q in range(1, 8)])

    # ---- top-10 frequent values + first-word proxy ----
    kk = min(FT.N_FREQ_WORDS, r)
    topc, topi = jax.lax.top_k(counts, kk)
    freq_words = jnp.where(topc > 0, run_vals[topi], SENTINEL)
    if kk < FT.N_FREQ_WORDS:
        freq_words = jnp.concatenate(
            [freq_words, jnp.full((FT.N_FREQ_WORDS - kk,), SENTINEL, jnp.uint32)])
    first_word = jnp.where(has_rows, sv[0], SENTINEL)

    # ---- syntactic string stats ----
    valid_row = idx < n
    mn_c, mx_c, mean_c, _ = _masked_stats(char_len, valid_row, nf)
    mn_w, mx_w, mean_w, sd_w = _masked_stats(word_cnt, valid_row, nf)

    # Heavy-tailed count features are stored log1p-transformed: the z-scored
    # |Δ| of a log count is proportional to |log ratio| — exactly the
    # cardinality-proportion signal the paper's metric needs the model to
    # see (min/max ratio ≡ exp(-|log a - log b|)).
    z = jnp.float32(0.0)
    numeric = jnp.stack([
        jnp.where(has_rows, jnp.log1p(card.astype(jnp.float32)), z),  # CARDINALITY (log)
        jnp.where(has_rows, card.astype(jnp.float32) / nf, z),  # UNIQUENESS
        jnp.where(has_rows, entropy, z),                        # ENTROPY
        jnp.where(has_rows, jnp.log1p(min_freq), z),            # MIN_FREQ (log)
        jnp.where(has_rows, jnp.log1p(max_freq), z),            # MAX_FREQ (log)
        jnp.where(has_rows, max_perc, z),                       # MAX_PERC_FREQ
        jnp.where(has_rows, sd_perc, z),                        # SD_PERC_FREQ
        *[jnp.where(has_rows, octs[i], z) for i in range(7)],   # OCTILES
        jnp.where(has_rows, mx_c, z),                           # LONGEST_STR
        jnp.where(has_rows, mn_c, z),                           # SHORTEST_STR
        jnp.where(has_rows, mean_c, z),                         # AVG_STR
        jnp.where(has_rows, mean_w, z),                         # AVG_WORDS
        jnp.where(has_rows, mn_w, z),                           # MIN_WORDS
        jnp.where(has_rows, mx_w, z),                           # MAX_WORDS
        jnp.where(has_rows, sd_w, z),                           # SD_WORDS
    ])
    words = jnp.concatenate([freq_words, first_word[None]])
    return numeric, words


@partial(jax.jit, static_argnames=())
def compute_profiles_batch(values32, char_len, word_cnt, n_rows):
    """(C, R) tensors -> ((C, F_NUM) float32, (C, F_WORDS) uint32)."""
    return jax.vmap(_profile_one)(values32, char_len, word_cnt, n_rows)


def profile_lake(batch, *, chunk: int = 4096) -> LakeProfiles:
    """Profile a ColumnBatch (chunked to bound device memory)."""
    nums, words = [], []
    c = batch.n_columns
    for i in range(0, c, chunk):
        nb, wb = compute_profiles_batch(
            jnp.asarray(batch.values32[i:i + chunk]),
            jnp.asarray(batch.char_len[i:i + chunk]),
            jnp.asarray(batch.word_cnt[i:i + chunk]),
            jnp.asarray(batch.n_rows[i:i + chunk]),
        )
        nums.append(np.asarray(nb))
        words.append(np.asarray(wb))
    numeric = np.concatenate(nums) if nums else np.zeros((0, FT.F_NUM), np.float32)
    wordsa = np.concatenate(words) if words else np.zeros((0, FT.F_WORDS), np.uint32)
    mean = numeric.mean(axis=0) if c else np.zeros((FT.F_NUM,), np.float32)
    std = numeric.std(axis=0) if c else np.ones((FT.F_NUM,), np.float32)
    std = np.where(std < 1e-6, 1.0, std).astype(np.float32)
    return LakeProfiles(numeric=numeric.astype(np.float32), words=wordsa,
                        n_rows=batch.n_rows.copy(), mean=mean.astype(np.float32), std=std)
