"""FREYJA core: the paper's contribution as composable JAX modules."""
from repro.core import features
from repro.core.discovery import DiscoveryIndex, rank, rank_sharded
from repro.core.gbdt import GBDTConfig, GBDTParams, fit_gbdt
from repro.core.ingest import ColumnBatch, ColumnSketch, ingest_string_columns
from repro.core.lakegen import (Lake, LakeSpec, ScaledLake, ScaledLakeSpec,
                                generate_lake, generate_scaled_lake,
                                select_queries, select_scaled_queries)
from repro.core.predictor import (JoinQualityModel, build_training_set,
                                  train_quality_model)
from repro.core.profiles import LakeProfiles, profile_lake
from repro.core.quality import (cardinality_proportion, containment,
                                continuous_quality, discrete_quality,
                                multiset_jaccard, set_jaccard)

__all__ = [
    "features", "DiscoveryIndex", "rank", "rank_sharded", "GBDTConfig",
    "GBDTParams", "fit_gbdt", "ColumnBatch", "ColumnSketch",
    "ingest_string_columns", "Lake", "LakeSpec", "ScaledLake",
    "ScaledLakeSpec", "generate_lake", "generate_scaled_lake",
    "select_queries", "select_scaled_queries",
    "JoinQualityModel", "build_training_set",
    "train_quality_model", "LakeProfiles", "profile_lake",
    "cardinality_proportion", "containment", "continuous_quality",
    "discrete_quality", "multiset_jaccard", "set_jaccard",
]
