"""The paper's join-quality metric (Section III-B / IV-A).

* multiset Jaccard      J(A,B) = |A ∩ B|_multiset / (|A| + |B|)   ∈ [0, 0.5]
* cardinality proportion K(A,B) = min(|A|,|B|) / max(|A|,|B|)     over
  distinct cardinalities ∈ (0, 1]
* discrete buckets      Q(A,B,L)
* continuous quality    Q(A,B,s) = product of truncated-Gaussian CDFs with
  the paper's fitted parameters (μ_J = 0 + strictness, μ_K = 0.44,
  σ_J = 0.19, σ_K = 0.28, truncation [0, 1]).

Notes vs. the paper text (documented in DESIGN.md §5):
* The paper's Φ writes ``erf(x/2)``; the standard normal CDF is
  ``erf(x/√2)`` — we implement the standard CDF (the paper's fitted σ values
  only make sense with a proper CDF).
* The paper's discrete formula as printed is non-monotone (``max i`` over
  jointly loosening thresholds is always L). We implement the evident intent,
  verified against the paper's own Example 3 (scenario 1 → High, scenario 2
  → Medium for L = 4):

      Q(A,B,L) = max{ i ∈ [1..L] : J ≥ 2^{-(L-i+1)}  ∧  K ≥ (i-1)/L },
                 else 0.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Paper-fitted parameters (Section IV-A).
MU_J = 0.0
MU_K = 0.44
SIGMA_J = 0.19
SIGMA_K = 0.28
STRICTNESS = {"relaxed": 0.0, "balanced": 0.25, "strict": 0.5}
DEFAULT_STRICTNESS = 0.25   # the released model is trained at s = 0.25


@dataclasses.dataclass(frozen=True)
class QualityParams:
    mu_j: float = MU_J
    mu_k: float = MU_K
    sigma_j: float = SIGMA_J
    sigma_k: float = SIGMA_K
    lo: float = 0.0
    hi: float = 1.0


def multiset_jaccard(inter: jnp.ndarray, n_a: jnp.ndarray, n_b: jnp.ndarray) -> jnp.ndarray:
    """J from a precomputed multiset intersection size and multiset sizes."""
    denom = jnp.maximum(n_a + n_b, 1).astype(jnp.float32)
    return inter.astype(jnp.float32) / denom


def cardinality_proportion(card_a: jnp.ndarray, card_b: jnp.ndarray) -> jnp.ndarray:
    a = jnp.maximum(card_a.astype(jnp.float32), 1.0)
    b = jnp.maximum(card_b.astype(jnp.float32), 1.0)
    return jnp.minimum(a, b) / jnp.maximum(a, b)


def containment(inter_set: jnp.ndarray, card_a: jnp.ndarray) -> jnp.ndarray:
    """Set containment of A in B (baseline metric, Fig. 2)."""
    return inter_set.astype(jnp.float32) / jnp.maximum(card_a.astype(jnp.float32), 1.0)


def set_jaccard(inter_set: jnp.ndarray, card_a: jnp.ndarray, card_b: jnp.ndarray) -> jnp.ndarray:
    """Classical set Jaccard (baseline metric, Fig. 2)."""
    union = card_a + card_b - inter_set
    return inter_set.astype(jnp.float32) / jnp.maximum(union.astype(jnp.float32), 1.0)


def discrete_quality(j: jnp.ndarray, k: jnp.ndarray, levels: int = 4) -> jnp.ndarray:
    """Q(A,B,L) — see module docstring for the monotone reformulation."""
    q = jnp.zeros_like(j, dtype=jnp.int32)
    for i in range(1, levels + 1):
        ok = (j >= 2.0 ** -(levels - i + 1)) & (k >= (i - 1) / levels)
        q = jnp.where(ok, i, q)
    return q


def _phi(x: jnp.ndarray) -> jnp.ndarray:
    """Standard normal CDF."""
    return 0.5 * (1.0 + jax.lax.erf(x / jnp.sqrt(jnp.float32(2.0))))


def truncated_cdf(x: jnp.ndarray, mu: float, sigma: float,
                  lo: float = 0.0, hi: float = 1.0) -> jnp.ndarray:
    """CDF of N(mu, sigma²) truncated to [lo, hi], evaluated at x."""
    num = _phi((x - mu) / sigma) - _phi((lo - mu) / sigma)
    den = _phi((hi - mu) / sigma) - _phi((lo - mu) / sigma)
    return jnp.clip(num / den, 0.0, 1.0)


def continuous_quality(j: jnp.ndarray, k: jnp.ndarray,
                       strictness: float = DEFAULT_STRICTNESS,
                       params: QualityParams = QualityParams()) -> jnp.ndarray:
    """Q(A,B,s): the paper's continuous join-quality metric."""
    cj = truncated_cdf(j, params.mu_j + strictness, params.sigma_j, params.lo, params.hi)
    ck = truncated_cdf(k, params.mu_k, params.sigma_k, params.lo, params.hi)
    return cj * ck


# ---------------------------------------------------------------------------
# Wasserstein re-fit (the paper's Fig. 6 procedure): grid-search (μ, σ) per
# dimension to minimize the W1 distance between the truncated-Gaussian CDF and
# the empirical distribution of the discrete metric's marginals.
# ---------------------------------------------------------------------------

def _w1_to_edf(samples, mu, sigma, grid):
    import numpy as np
    edf = np.searchsorted(np.sort(samples), grid, side="right") / max(len(samples), 1)
    cdf = np.asarray(truncated_cdf(jnp.asarray(grid, jnp.float32), float(mu), float(sigma)))
    return float(np.trapezoid(np.abs(edf - cdf), grid))


def fit_truncated_gaussian(samples, mus, sigmas, n_grid: int = 256):
    """Exhaustive (μ, σ) grid search minimizing W1 to the empirical dist."""
    import numpy as np
    grid = np.linspace(0.0, 1.0, n_grid)
    best = (float("inf"), None, None)
    for mu in mus:
        for sg in sigmas:
            d = _w1_to_edf(samples, mu, sg, grid)
            if d < best[0]:
                best = (d, float(mu), float(sg))
    return {"w1": best[0], "mu": best[1], "sigma": best[2]}
