"""whisper-base: enc-dec audio backbone; conv frontend STUBBED — frame
embeddings arrive precomputed [arXiv:2212.04356]. Vocab padded 51865 ->
51872 for 16-way TP divisibility."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec", n_layers=6, enc_layers=6,
    dec_layers=6, d_model=512, n_heads=8, n_kv=8, d_head=64, d_ff=2048,
    vocab=51872, norm="layernorm", act="gelu", tie_embeddings=True,
    frontend="audio_stub")
