"""FREYJA's own distributed discovery step as a dry-runnable config: the
paper's query path (profile distances -> GBDT -> top-k) over a sharded
profile corpus. Not an LM; used by launch/dryrun.py as an extra cell."""
N_COLUMNS = 16 * 1024 * 1024       # 16M columns (a very large lake)
N_QUERIES = 64
TOP_K = 100
