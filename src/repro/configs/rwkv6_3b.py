"""rwkv6-3b ("Finch"): attention-free, data-dependent decay linear RNN
[arXiv:2404.05892]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560,
    n_heads=40, n_kv=40, d_head=64, d_ff=8960, vocab=65536,
    rwkv_headdim=64, norm="layernorm", act="silu")
