"""zamba2-2.7b: Mamba2 backbone + shared attention block every 6 layers
[arXiv:2411.15242] (simplified shared block — see DESIGN.md)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv=32, d_head=80, d_ff=10240, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, attn_every=6,
    norm="rmsnorm", act="gelu", rope_theta=10_000.0)
