"""stablelm-3b: dense MHA, LayerNorm [hf:stabilityai/stablelm-2 family]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv=32, d_head=80, d_ff=6912, vocab=50304,
    norm="layernorm", act="silu", rope_theta=10_000.0)
