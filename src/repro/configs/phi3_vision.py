"""phi-3-vision-4.2b: phi3-mini backbone + CLIP frontend STUB — patch
embeddings replace the first n_patches token positions
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv=32, d_head=96, d_ff=8192, vocab=32064,
    frontend="vision_stub", n_patches=576,
    norm="rmsnorm", act="silu", rope_theta=10_000.0)
