"""mixtral-8x22b: 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]. Experts use TP sharding (8 experts do not divide the
16-wide model axis); SWA window 4096 makes long_500k sub-quadratic."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv=8, d_head=128, d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, moe_sharding="tp", sliding_window=4096,
    norm="rmsnorm", act="silu", rope_theta=1_000_000.0)
