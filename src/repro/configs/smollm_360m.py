"""smollm-360m: llama-arch small dense GQA [hf:HuggingFaceTB/SmolLM-360M]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense", n_layers=32, d_model=960,
    n_heads=15, n_kv=5, d_head=64, d_ff=2560, vocab=49152,
    norm="rmsnorm", act="silu", rope_theta=10_000.0)
