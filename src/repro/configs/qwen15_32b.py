"""qwen1.5-32b: dense MHA with QKV bias [hf:Qwen/Qwen1.5 family]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv=40, d_head=128, d_ff=27392, vocab=152064,
    qkv_bias=True, norm="rmsnorm", act="silu", rope_theta=1_000_000.0)
