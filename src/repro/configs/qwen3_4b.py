"""qwen3-4b: dense GQA with qk-norm, head_dim 128 [hf:Qwen/Qwen3 family]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense", n_layers=36, d_model=2560,
    n_heads=32, n_kv=8, d_head=128, d_ff=9728, vocab=151936,
    qk_norm=True, norm="rmsnorm", act="silu", rope_theta=1_000_000.0)
