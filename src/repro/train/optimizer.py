"""Pure-JAX AdamW with ZeRO-1 moment sharding and global-norm clipping.

No optax offline — the optimizer is ~80 lines of pytree arithmetic. The
ZeRO-1 behaviour comes entirely from *sharding*: moments live with
``zero1_spec`` (an extra 'data' shard on the stacked ``layers`` axis);
gradients are sharding-constrained into that spec before the moment update,
so XLA lowers the gradient reduction as reduce-scatter + the param update as
all-gather — the ZeRO-1 collective schedule — instead of a full all-reduce
per gradient.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_opt_state(params, moment_shardings=None):
    def zeros_like_f32(p, sh=None):
        z = jnp.zeros(p.shape, jnp.float32)
        return jax.device_put(z, sh) if sh is not None else z

    if moment_shardings is None:
        m = jax.tree.map(zeros_like_f32, params)
        v = jax.tree.map(zeros_like_f32, params)
    else:
        m = jax.tree.map(zeros_like_f32, params, moment_shardings)
        v = jax.tree.map(zeros_like_f32, params, moment_shardings)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


def lr_schedule(cfg: AdamWConfig, step):
    if cfg.warmup_steps <= 0:
        warm = 1.0
    else:
        warm = jnp.minimum((step.astype(jnp.float32) + 1.0) / cfg.warmup_steps, 1.0)
    t = jnp.clip((step.astype(jnp.float32) - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state, *,
                 moment_specs=None, mesh=None):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def constrain(g, spec):
        if mesh is None or spec is None:
            return g
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(g, NamedSharding(mesh, spec))

    if moment_specs is None:
        moment_specs = jax.tree.map(lambda _: None, params)

    lr = lr_schedule(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, spec):
        g = constrain(g.astype(jnp.float32) * scale, spec)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_s = tdef.flatten_up_to(moment_specs)
    out = [upd(p, g, m, v, s) for p, g, m, v, s in
           zip(flat_p, flat_g, flat_m, flat_v, flat_s)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
