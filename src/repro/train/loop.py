"""Fault-tolerant training loop: checkpoint/restart, straggler monitor.

The loop is deliberately boring: deterministic data (batch = f(seed, step)),
checkpoint every N steps via the atomic CheckpointManager, resume from the
latest checkpoint on (re)start, and re-raise only after writing an emergency
checkpoint — a preempted/crashed worker restarts byte-identically.

``StragglerMonitor`` keeps an EMA of step wall-time and flags steps slower
than ``threshold ×`` the EMA; on a real fleet this signal feeds the
controller that re-shards around slow hosts (here it logs — single host).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2
    threshold: float = 2.0
    ema: float | None = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.threshold * self.ema
        self.ema = dt if self.ema is None else (1 - self.alpha) * self.ema + self.alpha * dt
        self.flagged += int(slow)
        return slow


def train_loop(train_step, params, opt_state, pipeline, *, steps: int,
               ckpt_dir: str, ckpt_every: int = 50, log_every: int = 10,
               to_device=None, log=print):
    """Runs ``steps`` optimizer steps with checkpoint/resume. Returns
    (params, opt_state, history)."""
    mgr = CheckpointManager(ckpt_dir)
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        (params, opt_state), _ = mgr.restore(latest, (params, opt_state))
        start = latest
        log(f"[resume] restored checkpoint @ step {latest}")

    monitor = StragglerMonitor()
    history = []
    step = start
    try:
        for step in range(start, steps):
            batch = pipeline.batch(step)
            if to_device is not None:
                batch = to_device(batch)
            t0 = time.perf_counter()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = monitor.observe(dt)
            if step % log_every == 0 or slow:
                loss = float(metrics["loss"])
                history.append((step, loss, dt))
                log(f"step {step:5d} loss {loss:.4f} {dt*1e3:7.1f} ms"
                    + (" [straggler]" if slow else ""))
            if (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state))
    except KeyboardInterrupt:
        mgr.save(step, (params, opt_state))
        log(f"[interrupt] emergency checkpoint @ step {step}")
        raise
    mgr.save(steps, (params, opt_state))
    return params, opt_state, history
