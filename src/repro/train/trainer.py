"""Train-step builder: loss, grad accumulation, optional compressed grads.

``build_train_step`` returns a jittable ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` closure. Cross-entropy is computed in fp32
against vocab-sharded logits (the logsumexp reduction over the sharded vocab
axis lowers to a small all-reduce under pjit).

Gradient accumulation scans over ``accum`` microbatches (bit-exact mean of
micro-grads). Optional int8 error-feedback compression (dist/compression.py)
plugs in between grad computation and the optimizer.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.train.optimizer import AdamWConfig, adamw_update


def cross_entropy(logits, labels):
    """logits (B, S, V) f32, labels (B, S) int32 (-1 = masked)."""
    mask = labels >= 0
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def loss_fn(params, cfg, batch, *, mesh=None):
    logits = registry.forward(params, cfg, batch, mesh=mesh)
    return cross_entropy(logits, batch["labels"])


def build_train_step(cfg, opt_cfg: AdamWConfig, *, mesh=None, accum: int = 1,
                     moment_specs=None, compressor=None):
    def micro_grads(params, batch):
        return jax.value_and_grad(loss_fn)(params, cfg, batch, mesh=mesh)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = micro_grads(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape((accum, b // accum) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                loss_a, g_a = carry
                loss, g = micro_grads(params, mb)
                return (loss_a + loss, jax.tree.map(jnp.add, g_a, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.zeros(()), zeros), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        if compressor is not None:
            grads, opt_state = compressor(grads, opt_state)

        params, opt_state, metrics = adamw_update(
            opt_cfg, params, grads, opt_state,
            moment_specs=moment_specs, mesh=mesh)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
