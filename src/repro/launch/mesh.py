"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16×16 = 256 chips per pod (TPU v5e), and 2 pods = 512
chips with a leading ``pod`` axis for the multi-pod dry-run.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
