"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16×16 = 256 chips per pod (TPU v5e), and 2 pods = 512
chips with a leading ``pod`` axis for the multi-pod dry-run.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_grid_mesh(q_shards: int, d_shards: int, *, devices=None):
    """2-D (query × data) grid mesh for discovery serving.

    ``q_shards`` shards the concurrent query batch, ``d_shards`` the
    lake's column axis — each device owns one (Q-shard, C-shard) tile of
    the scoring problem (``repro.exec.sharded``). Degenerate geometries
    are both useful: ``(1, d)`` is the classic replicated-query data
    sharding, ``(q, 1)`` replicates the corpus to scale concurrent
    batches. ``devices`` defaults to all local devices and must be
    divisible by ``q_shards × d_shards``; the remainder becomes a trailing
    ``model`` axis (replicated by discovery placements).
    """
    import numpy as np

    devs = np.asarray(devices if devices is not None else jax.devices())
    devs = devs.reshape(-1)
    n = q_shards * d_shards
    if n <= 0 or devs.size % n:
        raise ValueError(f"grid ({q_shards}, {d_shards}) does not tile "
                         f"{devs.size} devices")
    return jax.sharding.Mesh(devs.reshape(q_shards, d_shards, devs.size // n),
                             ("query", "data", "model"))
