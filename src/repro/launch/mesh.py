"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16×16 = 256 chips per pod (TPU v5e), and 2 pods = 512
chips with a leading ``pod`` axis for the multi-pod dry-run.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_replica_meshes(n_replicas: int, *, devices=None,
                        model: int = 1) -> list:
    """Partition the device set into ``n_replicas`` contiguous slices and
    build one ``(data, model)`` sub-mesh per replica.

    The fleet (``repro.service.fleet``) pins each engine replica to its
    own slice so replicas never contend for device memory or compute.
    Slices are contiguous — on real TPU topologies neighbouring device
    ids share ICI links, so a contiguous slice keeps each replica's
    collectives on-chip instead of crossing the fleet boundary.

    When the pool is too small to give every replica ``model`` devices
    (e.g. 4 host devices, 8 replicas) every replica gets ``None`` —
    single-device local execution, the degenerate slice.  A non-dividing
    replica count leaves the trailing remainder devices unused rather
    than building lopsided slices (uneven replicas would defeat the
    router's cost symmetry).
    """
    import numpy as np

    devs = np.asarray(devices if devices is not None else jax.devices())
    devs = devs.reshape(-1)
    n_replicas = int(n_replicas)
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1; got {n_replicas}")
    per = devs.size // n_replicas
    if per < max(model, 1) or per < 2:
        # not enough devices to mesh every replica: single-device local
        return [None] * n_replicas
    per -= per % model                   # keep the model axis dividing
    meshes = []
    for i in range(n_replicas):
        chunk = devs[i * per:(i + 1) * per]
        meshes.append(jax.sharding.Mesh(
            chunk.reshape(per // model, model), ("data", "model")))
    return meshes


def make_grid_mesh(q_shards: int, d_shards: int, *, devices=None):
    """2-D (query × data) grid mesh for discovery serving.

    ``q_shards`` shards the concurrent query batch, ``d_shards`` the
    lake's column axis — each device owns one (Q-shard, C-shard) tile of
    the scoring problem (``repro.exec.sharded``). Degenerate geometries
    are both useful: ``(1, d)`` is the classic replicated-query data
    sharding, ``(q, 1)`` replicates the corpus to scale concurrent
    batches. ``devices`` defaults to all local devices and must be
    divisible by ``q_shards × d_shards``; the remainder becomes a trailing
    ``model`` axis (replicated by discovery placements).
    """
    import numpy as np

    devs = np.asarray(devices if devices is not None else jax.devices())
    devs = devs.reshape(-1)
    n = q_shards * d_shards
    if n <= 0 or devs.size % n:
        raise ValueError(f"grid ({q_shards}, {d_shards}) does not tile "
                         f"{devs.size} devices")
    return jax.sharding.Mesh(devs.reshape(q_shards, d_shards, devs.size // n),
                             ("query", "data", "model"))
