"""FREYJA discovery driver: build a lake, profile it, train/load the quality
model, and answer discovery-by-attribute queries.

  PYTHONPATH=src python -m repro.launch.discover --tables 40 --queries 10
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (DiscoveryIndex, GBDTConfig, LakeSpec, generate_lake,
                        profile_lake, rank, select_queries,
                        train_quality_model)
from repro.core.predictor import JoinQualityModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", type=int, default=40)
    ap.add_argument("--domains", type=int, default=16)
    ap.add_argument("--queries", type=int, default=10)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--model", default=None, help="path to a trained model .npz")
    ap.add_argument("--save-model", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    t0 = time.perf_counter()
    lake = generate_lake(LakeSpec(n_domains=args.domains, n_tables=args.tables,
                                  seed=args.seed))
    print(f"lake: {lake.n_columns} columns, {lake.raw_bytes/1e6:.1f} MB raw "
          f"({time.perf_counter()-t0:.1f}s)")

    t0 = time.perf_counter()
    prof = profile_lake(lake.batch)
    print(f"profiles: {prof.numeric.shape} in {time.perf_counter()-t0:.2f}s "
          f"({prof.nbytes()/1e3:.1f} KB = "
          f"{100*prof.nbytes()/max(lake.raw_bytes,1):.2f}% of raw)")

    if args.model:
        model = JoinQualityModel.load(args.model)
        print(f"loaded model (train R² {model.train_r2:.3f})")
    else:
        t0 = time.perf_counter()
        model = train_quality_model([lake], GBDTConfig())
        print(f"trained model R² {model.train_r2:.3f} "
              f"({time.perf_counter()-t0:.1f}s)")
        if args.save_model:
            model.save(args.save_model)

    index = DiscoveryIndex(profiles=prof, model=model, names=lake.batch.names,
                           table_ids=lake.table)
    qids = select_queries(lake, args.queries)
    t0 = time.perf_counter()
    scores, ids = rank(index, qids, k=args.k)
    dt = time.perf_counter() - t0
    sem = lake.is_semantic(np.repeat(qids, args.k), ids.reshape(-1))
    print(f"query: {len(qids)} queries in {dt:.3f}s "
          f"({dt/max(len(qids),1)*1e3:.1f} ms/query), "
          f"P@{args.k} = {sem.mean():.3f}")
    for qi, (s_row, i_row) in list(zip(qids, zip(scores, ids)))[:3]:
        names = [lake.batch.names[j] for j in i_row[:5]]
        print(f"  q={lake.batch.names[qi]} -> {names}")


if __name__ == "__main__":
    main()
