"""FREYJA discovery driver: build a lake, profile it, train/load the quality
model, and answer discovery-by-attribute queries.

  PYTHONPATH=src python -m repro.launch.discover --tables 40 --queries 10

Service mode (the online subsystem): persist the lake into an on-disk
catalog, restart an engine from it, and serve the queries through the
planned candidate→score→merge pipeline (``repro.exec``), reporting the
chosen plan, serving stats, and recall against the exact scan:

  PYTHONPATH=src python -m repro.launch.discover --tables 40 --queries 10 \
      --catalog /tmp/freyja_catalog --serve

Add ``--mesh`` (with XLA_FLAGS=--xla_force_host_platform_device_count=8)
to shard the lake over local devices — ``--mode lsh`` then runs the
distributed LSH plan: per-device bucket probe + one small all_gather.
``--grid QxD`` pins the 2-D (query × data) device grid (e.g. ``--grid
2x4`` on 8 devices shards the query batch 2-way alongside a 4-way column
shard); without it the planner factorizes the mesh per batch.

``--follow`` turns the engine into a read replica: it tails the catalog's
manifest chain and refreshes onto each new version before serving (the
demo publishes a table mid-run to show the pickup).  ``--calibrate
BENCH_service.json`` fits per-stage cost constants from measured bench
timings and plugs them into the planner, so ``--mode auto`` crossovers
are measured, not analytic.

``--open-loop`` follows the closed-loop serve with an **open-loop**
measurement through the continuous-batching scheduler: requests arrive
as a Poisson process at ``--offered-qps`` (default: 2× the closed-loop
rate just measured), each carrying ``--deadline-ms``; reported are the
achieved QPS, goodput under the deadline, p50/p99 latency *including
queue wait*, shed rate, and the formed-batch size histogram.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (DiscoveryIndex, GBDTConfig, LakeSpec, generate_lake,
                        profile_lake, rank, select_queries,
                        train_quality_model)
from repro.core.predictor import JoinQualityModel


def serve_mode(args, lake, model):
    """Persist → restart → serve through the online engine."""
    from repro.service import (CatalogReader, ColumnCatalog, DiscoveryEngine,
                               DiscoveryRequest, EngineConfig, LSHConfig,
                               add_lake, measure_recall, serve_discovery)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh()
        print(f"mesh: {dict(mesh.shape)} ({len(mesh.devices.flat)} devices)")

    grid = None
    if args.grid:
        if mesh is None:
            raise SystemExit("--grid needs --mesh")
        try:
            grid = tuple(int(x) for x in args.grid.lower().split("x"))
            assert len(grid) == 2
        except (ValueError, AssertionError):
            raise SystemExit(f"--grid wants QxD (e.g. 2x4), got {args.grid!r}")
        print(f"grid: {grid[0]} query shards x {grid[1]} data shards")

    t0 = time.perf_counter()
    catalog = ColumnCatalog(args.catalog)
    if not catalog.tables():
        add_lake(catalog, lake)
        print(f"catalog: ingested {len(catalog.tables())} tables in "
              f"{time.perf_counter()-t0:.1f}s -> {args.catalog}")
    else:
        # query ids below index into the generated lake; a stale catalog
        # built from different --tables/--seed would silently misalign.
        # Column names encode (table, domain, granularity, seed ordering),
        # so comparing them is a content check, not just a count check.
        if catalog.snapshot().names != lake.batch.names:
            raise SystemExit(
                f"catalog at {args.catalog} does not match the generated "
                f"lake — it was built with different --tables/--domains/"
                f"--seed; point --catalog at a fresh directory (or delete "
                f"this one)")
        print(f"catalog: reusing {len(catalog.tables())} tables from "
              f"{args.catalog}")

    cost_fn = None
    if args.calibrate:
        from repro.launch.costmodel import calibrate_stage_costs
        constants, cost_fn = calibrate_stage_costs(args.calibrate)
        print(f"calibrated cost model from {args.calibrate}: "
              f"r2={constants['r2']:.3f} over {constants['n_obs']} obs, "
              f"score={constants['score_s_per_flop']:.3e} s/flop, "
              f"fixed={1e3*constants['fixed_s_per_query']:.3f} ms/query")

    if args.replicas > 1:
        fleet_mode(args, lake, model, cost_fn, grid)
        return

    # restart path: a fresh process would do exactly this
    engine = DiscoveryEngine.from_catalog(
        ColumnCatalog(args.catalog), model,
        EngineConfig(k=args.k, mode=args.mode,
                     lsh=LSHConfig(n_bands=args.lsh_bands),
                     cost_fn=cost_fn, grid=grid,
                     metrics=args.metrics_port is not None,
                     warmup=(False if args.warmup == "off" else args.warmup),
                     executable_cache_dir=args.executable_cache), mesh=mesh)
    if engine.warmup_report is not None:
        rep = engine.warmup_report
        print(f"warmup[{rep['scope']}]: {rep['n_executables']} executables "
              f"over buckets {rep['buckets']} in {rep['wall_ms']:.0f}ms "
              f"({rep['cache_hits']} from cache, "
              f"{rep['cache_misses']} compiled)")
    metrics_server = None
    if args.metrics_port is not None:
        from repro.service import MetricsServer
        metrics_server = MetricsServer(engine.metrics,
                                       port=args.metrics_port)
        print(f"metrics: serving Prometheus exposition at "
              f"{metrics_server.url}")
    if args.follow:
        # follower mode: the engine tails the manifest chain, picking up
        # versions published by any concurrent writer before each batch
        engine.follow(CatalogReader(args.catalog))
        print(f"follower: tailing {args.catalog} from version "
              f"{engine.version}")
    qids = select_queries(lake, args.queries)
    reqs = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q))
            for q in qids]
    t0 = time.perf_counter()
    responses = list(serve_discovery(engine, reqs, max_batch=args.batch))
    dt = time.perf_counter() - t0
    print(f"served {len(responses)} queries in {dt:.3f}s "
          f"({len(responses)/max(dt,1e-9):.1f} QPS, mode={args.mode})")
    stats = engine.stats()
    plan = stats.get("last_plan", {})
    print(f"plan: {plan.get('kind')} budget={plan.get('budget')} "
          f"grid={'x'.join(map(str, plan.get('grid', [1, 1])))} "
          f"(~{plan.get('cost', {}).get('total_flops', 0)/1e6:.2f} MFLOP/batch); "
          f"cache {stats['cache']['hits']}h/{stats['cache']['misses']}m, "
          f"plans={stats['plans']}")
    if args.mode in ("lsh", "auto"):
        rec = measure_recall(engine, qids, k=args.k)
        print(f"recall@{args.k} vs {rec['baseline_plan']} scan: "
              f"{rec['recall']:.3f} scoring "
              f"{100*rec['scored_fraction']:.1f}% of columns")
    for r in responses[:3]:
        names = [m.column for m in r.matches[:5]]
        print(f"  {r.name} ({r.n_candidates} scored) -> {names}")

    if args.open_loop:
        closed_qps = len(responses) / max(dt, 1e-9)
        open_loop_mode(args, engine, qids, closed_qps)

    if metrics_server is not None:
        scrape = engine.metrics.collect()
        admitted = scrape["requests_admitted_total"]["values"].get("", 0)
        print(f"metrics: {int(admitted)} requests admitted; endpoint "
              f"{metrics_server.url} stays up until exit")

    if args.follow:
        # demonstrate replication: a writer publishes a delta segment and
        # the follower's next batch observes the new version
        writer = ColumnCatalog(args.catalog)
        if "follow_demo" not in writer.tables():
            writer.add_table("follow_demo",
                             [("demo_ids", [f"demo_{i}" for i in range(64)])])
        v0 = engine.version
        engine.query(DiscoveryRequest(name="demo", column_id=0))
        print(f"follower: observed version {engine.version} (was {v0}) "
              f"after a concurrent add_table; "
              f"{engine.n_columns} columns live")


def fleet_mode(args, lake, model, cost_fn, grid) -> None:
    """``--replicas N``: serve through an :class:`EngineFleet` of catalog
    followers, each on its own device slice, behind the load-aware
    router."""
    import jax

    from repro.service import (DiscoveryRequest, EngineConfig, EngineFleet,
                               LSHConfig, serve_discovery)

    fleet = EngineFleet.from_catalog(
        args.catalog, model,
        EngineConfig(k=args.k, mode=args.mode,
                     lsh=LSHConfig(n_bands=args.lsh_bands),
                     cost_fn=cost_fn, grid=grid,
                     metrics=args.metrics_port is not None,
                     warmup=(False if args.warmup == "off" else args.warmup),
                     executable_cache_dir=args.executable_cache),
        n_replicas=args.replicas,
        devices=jax.devices() if args.mesh else None)
    try:
        fleet.warm_event.wait(timeout=300)
        st = fleet.stats()
        slices = {rid: v["state"] for rid, v in st["replicas"].items()}
        print(f"fleet: {args.replicas} replicas over "
              f"{len(jax.devices())} devices, states {slices}")
        metrics_server = None
        if args.metrics_port is not None:
            from repro.service import MetricsServer
            metrics_server = MetricsServer(fleet.metrics,
                                           port=args.metrics_port)
            print(f"metrics: serving Prometheus exposition at "
                  f"{metrics_server.url}")
        qids = select_queries(lake, args.queries)
        reqs = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q))
                for q in qids]
        t0 = time.perf_counter()
        responses = list(serve_discovery(fleet, reqs, max_batch=args.batch))
        dt = time.perf_counter() - t0
        st = fleet.stats()
        print(f"served {len(responses)} queries in {dt:.3f}s "
              f"({len(responses)/max(dt,1e-9):.1f} QPS, mode={args.mode}, "
              f"{st['dispatched']} batches routed, "
              f"{st['redispatches']} re-dispatched)")
        for rid, v in st["replicas"].items():
            print(f"  replica {rid}: {v['state']} "
                  f"served {v['requests_served']} requests in "
                  f"{v['batches_served']} batches "
                  f"(catalog v{v['engine_version']})")
        for r in responses[:3]:
            names = [m.column for m in r.matches[:5]]
            print(f"  {r.name} ({r.n_candidates} scored) -> {names}")
    finally:
        fleet.close()


def open_loop_mode(args, engine, qids, closed_qps: float) -> None:
    """Poisson-arrival serving through the continuous-batching scheduler."""
    from repro.launch.costmodel import derive_batch_buckets
    from repro.service import DiscoveryRequest
    from repro.service.loadgen import run_open_loop
    from repro.service.scheduler import SchedulerConfig

    offered = args.offered_qps or 2.0 * closed_qps
    buckets = derive_batch_buckets(args.calibrate or "BENCH_service.json")
    pool = [DiscoveryRequest(name=f"ol{i}", column_id=int(q))
            for i, q in enumerate(qids)]
    # warm every bucket's compiled shape BEFORE offering load, or the
    # first formed batch at each new size pays its jit compile against
    # the deadline and the printed numbers measure XLA, not serving.
    # engine.warmup() AOT-compiles the ladder (through the persistent
    # executable cache when one is configured) without serving traffic
    engine.config.batch_buckets = buckets
    engine.planner.config.batch_buckets = buckets
    rep = engine.warmup("serve")
    print(f"open-loop warmup: {rep['n_executables']} executables in "
          f"{rep['wall_ms']:.0f}ms ({rep['cache_hits']} from cache)")
    r = run_open_loop(engine, pool, offered, args.open_loop_duration,
                      args.deadline_ms,
                      scheduler_config=SchedulerConfig(batch_buckets=buckets))
    print(f"open-loop: offered {r['offered_qps']:.0f} QPS for "
          f"{r['duration_s']:.2f}s (Poisson, deadline "
          f"{args.deadline_ms:.0f}ms, buckets {r['buckets']})")
    if r["p50_ms"] is not None:
        print(f"  achieved {r['qps']:.0f} QPS, goodput "
              f"{r['goodput_qps']:.0f} QPS; latency incl queue "
              f"p50={r['p50_ms']:.1f}ms p99={r['p99_ms']:.1f}ms")
    print(f"  shed {r['shed']}/{r['n_offered']} "
          f"({100*r['shed_rate']:.1f}%), expired {r['expired']} "
          f"({100*r['expired_rate']:.1f}%); formed {r['batches']} batches, "
          f"size hist {r['batch_size_hist']}, "
          f"bucket hits {r['bucket_hits']}/{r['batches']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", type=int, default=40)
    ap.add_argument("--domains", type=int, default=16)
    ap.add_argument("--queries", type=int, default=10)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--model", default=None, help="path to a trained model .npz")
    ap.add_argument("--save-model", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--catalog", default=None,
                    help="catalog directory (enables service mode)")
    ap.add_argument("--serve", action="store_true",
                    help="serve queries through the online engine")
    ap.add_argument("--mode", default="lsh",
                    choices=["lsh", "full", "sharded", "auto"])
    ap.add_argument("--mesh", action="store_true",
                    help="serve over a mesh of all local devices (sharded "
                         "plans; run with XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N to fake N devices)")
    ap.add_argument("--grid", default=None, metavar="QxD",
                    help="pin the (query x data) device grid for sharded "
                         "plans, e.g. 2x4 (needs --mesh; Q*D must equal the "
                         "device count). Default: the planner factorizes "
                         "the mesh per batch from batch size, lake size, "
                         "and the cost model")
    ap.add_argument("--lsh-bands", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="serve through an EngineFleet of N catalog-"
                         "follower replicas behind the load-aware router "
                         "(each pinned to its own device slice with "
                         "--mesh; warm->serve->drain->evict lifecycle, "
                         "health-check eviction, batch re-dispatch)")
    ap.add_argument("--follow", action="store_true",
                    help="follower mode: tail the catalog manifest chain "
                         "and refresh onto new versions between batches")
    ap.add_argument("--calibrate", default=None, metavar="BENCH_JSON",
                    help="fit per-stage cost constants from a "
                         "BENCH_service.json and use them as the planner's "
                         "cost model (mode=auto crossovers become measured)")
    ap.add_argument("--open-loop", action="store_true",
                    help="follow the closed-loop serve with a Poisson "
                         "open-loop run through the continuous-batching "
                         "scheduler (QPS, goodput, p50/p99 incl queue "
                         "wait, shed rate)")
    ap.add_argument("--offered-qps", type=float, default=0.0,
                    help="open-loop offered load (0 = 2x the measured "
                         "closed-loop QPS)")
    ap.add_argument("--deadline-ms", type=float, default=100.0,
                    help="per-request deadline for the open-loop run")
    ap.add_argument("--open-loop-duration", type=float, default=2.0,
                    help="seconds of Poisson arrivals to offer")
    ap.add_argument("--warmup", default="off",
                    choices=["off", "serve", "full"],
                    help="AOT-compile the padded-batch bucket ladder before "
                         "serving: 'serve' warms the configured mode's "
                         "plans (+ recall baseline), 'full' every "
                         "admissible plan kind x grid factorization")
    ap.add_argument("--executable-cache", default=None, metavar="DIR",
                    help="persistent executable cache directory: warmup "
                         "stores serialized XLA executables there and a "
                         "restarted engine loads them instead of "
                         "recompiling (keyed by jax version, backend, "
                         "device kind/count, mesh geometry, and plan "
                         "signature — any drift falls back to a fresh "
                         "compile)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="enable the observability plane (event bus + "
                         "metrics registry) and serve the Prometheus text "
                         "exposition on http://127.0.0.1:PORT/metrics "
                         "(0 = ephemeral port, printed at startup)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    lake = generate_lake(LakeSpec(n_domains=args.domains, n_tables=args.tables,
                                  seed=args.seed))
    print(f"lake: {lake.n_columns} columns, {lake.raw_bytes/1e6:.1f} MB raw "
          f"({time.perf_counter()-t0:.1f}s)")

    t0 = time.perf_counter()
    prof = profile_lake(lake.batch)
    print(f"profiles: {prof.numeric.shape} in {time.perf_counter()-t0:.2f}s "
          f"({prof.nbytes()/1e3:.1f} KB = "
          f"{100*prof.nbytes()/max(lake.raw_bytes,1):.2f}% of raw)")

    if args.model:
        model = JoinQualityModel.load(args.model)
        print(f"loaded model (train R² {model.train_r2:.3f})")
    else:
        t0 = time.perf_counter()
        model = train_quality_model([lake], GBDTConfig())
        print(f"trained model R² {model.train_r2:.3f} "
              f"({time.perf_counter()-t0:.1f}s)")
        if args.save_model:
            model.save(args.save_model)

    if args.serve or args.catalog:
        if not args.catalog:
            ap.error("--serve needs --catalog DIR")
        serve_mode(args, lake, model)
        return

    index = DiscoveryIndex(profiles=prof, model=model, names=lake.batch.names,
                           table_ids=lake.table)
    qids = select_queries(lake, args.queries)
    t0 = time.perf_counter()
    scores, ids = rank(index, qids, k=args.k)
    dt = time.perf_counter() - t0
    valid = (ids >= 0).reshape(-1)          # k > lake size pads with -1
    sem = lake.is_semantic(np.repeat(qids, args.k),
                           np.maximum(ids.reshape(-1), 0)) & valid
    print(f"query: {len(qids)} queries in {dt:.3f}s "
          f"({dt/max(len(qids),1)*1e3:.1f} ms/query), "
          f"P@{args.k} = {sem.sum()/max(valid.sum(), 1):.3f}")
    for qi, (s_row, i_row) in list(zip(qids, zip(scores, ids)))[:3]:
        names = [lake.batch.names[j] for j in i_row[:5] if j >= 0]
        print(f"  q={lake.batch.names[qi]} -> {names}")


if __name__ == "__main__":
    main()
