import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

512 placeholder host devices stand in for 2 TPU v5e pods; ``.lower()`` /
``.compile()`` prove the sharding config is coherent (no mismatched specs,
no unsupported collectives, no shape errors) and yield per-device
FLOPs/bytes (cost_analysis), memory (memory_analysis) and the collective
schedule (HLO parse) that EXPERIMENTS.md §Dry-run/§Roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.models.config import ArchConfig
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.trainer import build_train_step
from repro.serve.engine import build_serve_step

ARTIFACT_DIR = "artifacts/dryrun"


# ---------------------------------------------------------------------------
# sharding of inputs
# ---------------------------------------------------------------------------

def _batch_spec(mesh, b: int, extra=()):
    ba = shd.batch_axes(mesh)
    nba = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    if b % nba == 0 and b >= nba:
        return P(ba, *extra)
    return P(None, *extra)


def cache_shardings(cfg: ArchConfig, caches_sds, b: int, mesh: Mesh):
    """Sharding for decode caches: batch-shard when divisible, else shard
    the sequence axis (flash-decoding style); kv-heads over 'model' when
    divisible (else replicated)."""
    ba = shd.batch_axes(mesh)
    nba = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    mp = mesh.shape.get("model", 1)
    batch_ok = b % nba == 0 and b >= nba

    def spec_for(path, leaf):
        shape = leaf.shape
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if leaf.ndim == 0:
            return P()
        axes = [None] * leaf.ndim
        if "kv/k" in name or "kv/v" in name or "cross/" in name:
            # (L, B, S, nk, dh[,or scales (L,B,S,nk)])
            if batch_ok:
                axes[1] = ba
            elif shape[2] % nba == 0:
                axes[2] = ba                      # sequence-sharded cache
            if shape[3] % mp == 0 and shape[3] >= mp:
                axes[3] = "model"                 # kv heads over model
            elif axes[2] is None and shape[2] % mp == 0 and shape[2] >= mp:
                axes[2] = "model"                 # else: sequence over model
                # (flash-decoding combine via SPMD all-reduce)
        elif "mamba/ssm" in name:                 # (L, B, H, N, P)
            if batch_ok:
                axes[1] = ba
            if shape[2] % mp == 0:
                axes[2] = "model"
        elif "mamba/conv" in name:                # (L, B, K-1, C)
            if batch_ok:
                axes[1] = ba
            if shape[3] % mp == 0:
                axes[3] = "model"
        elif "rwkv" in name:
            if batch_ok:
                axes[1] = ba
        return P(*axes)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_sds)
    specs = [spec_for(path, leaf) for path, leaf in flat]
    return jax.tree.unflatten(treedef, [NamedSharding(mesh, s) for s in specs])


def batch_shardings(cfg: ArchConfig, specs: dict, b: int, mesh: Mesh):
    out = {}
    for k, v in specs.items():
        if k == "caches":
            out[k] = cache_shardings(cfg, v, b, mesh)
        else:
            extra = (None,) * (v.ndim - 1)
            out[k] = NamedSharding(mesh, _batch_spec(mesh, b, extra))
    return out


# ---------------------------------------------------------------------------
# cell runners
# ---------------------------------------------------------------------------

def _params_sds(cfg):
    holder = {}

    def f():
        p, s = registry.init_params(cfg, jax.random.PRNGKey(0))
        holder["specs"] = s          # static side-channel (specs are strings)
        return p

    params_sds = jax.eval_shape(f)
    return params_sds, holder["specs"]


def lower_cell(arch: str, shape_name: str, mesh: Mesh, *,
               zero1: bool = True, accum: int = 1, kv_quant: bool = False,
               mode: str = "tp", moe_sharding: str | None = None,
               remat: str | None = None):
    """Returns (lowered, aux) for the cell. Raises on unsupported cells."""
    shd.set_mode(mode)
    cfg = registry.get_config(arch)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if moe_sharding is not None and cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_sharding=moe_sharding)
    ok, reason = registry.cell_supported(cfg, shape_name)
    if not ok:
        raise SkipCell(reason)
    sh = registry.SHAPES[shape_name]
    b, kind = sh["batch"], sh["kind"]

    params_sds, logical_specs = _params_sds(cfg)
    pshard = shd.param_shardings(logical_specs, mesh, params=params_sds)
    in_specs = registry.input_specs(cfg, shape_name)
    bshard = batch_shardings(cfg, in_specs, b, mesh)

    if kind == "train":
        opt_sds = jax.eval_shape(lambda: init_opt_state(params_sds))
        if zero1:
            mspec_named = shd.zero1_shardings(logical_specs, params_sds, mesh)
            mspecs = jax.tree.map(lambda ns: ns.spec, mspec_named)
        else:
            mspec_named = shd.param_shardings(logical_specs, mesh)
            mspecs = jax.tree.map(lambda ns: ns.spec, mspec_named)
        oshard = {"m": mspec_named, "v": mspec_named,
                  "step": NamedSharding(mesh, P())}
        step = build_train_step(cfg, AdamWConfig(), mesh=mesh, accum=accum,
                                moment_specs=mspecs)
        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_sds, opt_sds, in_specs)
    elif kind == "prefill":
        def fwd(params, batch):
            return registry.forward(params, cfg, batch, mesh=mesh)
        jitted = jax.jit(fwd, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params_sds, in_specs)
    else:  # decode
        step = build_serve_step(cfg, mesh=mesh)
        jitted = jax.jit(step, in_shardings=(pshard, bshard["tokens"],
                                             bshard["caches"]),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_sds, in_specs["tokens"],
                               in_specs["caches"])
    return lowered, {"cfg": cfg, "kind": kind, "batch": b, "seq": sh["seq"],
                     "kv_bytes": 1 if kv_quant else 2, "mode": mode}


class SkipCell(Exception):
    pass


def lower_freyja_cell(mesh: Mesh, *, bf16_profiles: bool = False):
    """The paper's own distributed discovery query as a dry-run cell."""
    from repro.configs import freyja_discovery as FD
    from repro.core import features as FT
    from repro.exec import build_sharded_pipeline
    n, q, k = FD.N_COLUMNS, FD.N_QUERIES, FD.TOP_K
    zdt = jnp.bfloat16 if bf16_profiles else jnp.float32
    ba = shd.batch_axes(mesh)
    gb = (jnp.zeros((50, 5), jnp.int32), jnp.zeros((50, 5), jnp.float32),
          jnp.zeros((50, 32), jnp.float32), jnp.float32(0.5))
    fn = build_sharded_pipeline(mesh, gb, candidates="all", k=k,
                                shard_axes=ba)
    shard = NamedSharding(mesh, P(ba))
    shard2 = NamedSharding(mesh, P(ba, None))
    rep = NamedSharding(mesh, P())
    args = (jax.ShapeDtypeStruct((n, FT.F_NUM), zdt),
            jax.ShapeDtypeStruct((n, FT.F_WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.int32),      # cids
            jax.ShapeDtypeStruct((n,), jnp.int32),      # tids
            jax.ShapeDtypeStruct((q, FT.F_NUM), zdt),
            jax.ShapeDtypeStruct((q, FT.F_WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((q,), jnp.int32),      # tq
            jax.ShapeDtypeStruct((q,), jnp.int32))      # qid
    jitted = jax.jit(fn, in_shardings=(shard2, shard2, shard, shard,
                                       rep, rep, rep, rep))
    return jitted.lower(*args), {"kind": "discover", "batch": q, "seq": n,
                                 "cfg": None}


# ---------------------------------------------------------------------------
# analysis + driver
# ---------------------------------------------------------------------------

def analyze(lowered, aux, mesh: Mesh, *, zero1: bool = True) -> dict:
    from repro.launch.costmodel import cell_cost

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):       # older jax returns [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byt = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_stats = {}
    coll = hlo.parse_collectives(compiled.as_text())

    cfg = aux.get("cfg")
    n_dev = int(np.prod(list(mesh.shape.values())))
    result = {
        "kind": aux["kind"], "batch": aux["batch"], "seq": aux["seq"],
        "n_devices": n_dev,
        "mesh": dict(mesh.shape),
        "compile_s": compile_s,
        # raw XLA tool numbers — While bodies counted ONCE (lower bounds for
        # loops, unfused upper bound for bytes); see DESIGN.md §7
        "xla_flops_per_device": flops,
        "xla_bytes_per_device": byt,
        "xla_collective_bytes_per_device": coll.total_bytes,
        "collectives": coll.bytes_by_op,
        "collective_counts": coll.count_by_op,
        "memory": mem_stats,
    }
    if cfg is not None:
        ac = cell_cost(cfg, aux["kind"], aux["batch"], aux["seq"],
                       dict(mesh.shape), zero1=zero1,
                       kv_cache_dtype_bytes=aux.get("kv_bytes", 2),
                       mode=aux.get("mode", "tp"))
        terms = hlo.roofline_terms(ac.flops, ac.hbm_bytes, ac.coll_bytes)
        n_params = cfg.param_count()
        n_active = cfg.active_param_count()
        tokens = aux["batch"] * (aux["seq"] if aux["kind"] != "decode" else 1)
        mult = 6 if aux["kind"] == "train" else 2
        model_flops = mult * n_active * tokens
        t_model = model_flops / n_dev / hlo.PEAK_FLOPS
        result.update(
            flops_per_device=ac.flops,
            bytes_per_device=ac.hbm_bytes,
            collective_bytes_per_device=ac.coll_bytes,
            cost_detail=ac.detail,
            n_params=n_params, n_active_params=n_active,
            model_flops=model_flops,
            useful_flops_ratio=model_flops / (ac.flops * n_dev) if ac.flops else 0.0,
            roofline_fraction=t_model / terms["bound_s"] if terms["bound_s"] else 0.0,
            **terms,
        )
    else:
        terms = hlo.roofline_terms(flops, byt, coll.total_bytes)
        result.update(flops_per_device=flops, bytes_per_device=byt,
                      collective_bytes_per_device=coll.total_bytes, **terms)
    return result


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, zero1=True,
             accum=1, out_dir: str = ARTIFACT_DIR, tag: str = "",
             kv_quant: bool = False, mode: str = "tp",
             moe_sharding: str | None = None, mesh_override: str | None = None,
             freyja_bf16: bool = False, remat: str | None = None) -> dict:
    if mesh_override:
        dims = tuple(int(x) for x in mesh_override.split("x"))
        if mesh_kind == "multi":
            mesh = jax.make_mesh((2,) + dims, ("pod", "data", "model"))
        else:
            mesh = jax.make_mesh(dims, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        if arch == "freyja-discovery":
            lowered, aux = lower_freyja_cell(mesh, bf16_profiles=freyja_bf16)
        else:
            lowered, aux = lower_cell(arch, shape_name, mesh, zero1=zero1,
                                      accum=accum, kv_quant=kv_quant,
                                      mode=mode, moe_sharding=moe_sharding,
                                      remat=remat)
        lower_s = time.time() - t0
        result = analyze(lowered, aux, mesh, zero1=zero1)
        result.update(arch=arch, shape=shape_name, mesh_kind=mesh_kind,
                      lower_s=lower_s, status="ok",
                      variant={"kv_quant": kv_quant, "mode": mode,
                               "zero1": zero1, "accum": accum,
                               "moe_sharding": moe_sharding,
                               "mesh_override": mesh_override,
                               "freyja_bf16": freyja_bf16})
    except SkipCell as e:
        result = {"arch": arch, "shape": shape_name, "mesh_kind": mesh_kind,
                  "status": "skip", "reason": str(e)}
    except Exception as e:
        result = {"arch": arch, "shape": shape_name, "mesh_kind": mesh_kind,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--sharding-mode", default="tp", choices=["tp", "fsdp", "dp"])
    ap.add_argument("--moe-sharding", default=None, choices=[None, "tp", "ep"])
    ap.add_argument("--mesh-override", default=None,
                    help="e.g. 64x4 — same chip count, different data×model split")
    ap.add_argument("--freyja-bf16", action="store_true")
    ap.add_argument("--remat", default=None, choices=[None, "block", "none"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=ARTIFACT_DIR)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in registry.list_archs() for s in registry.SHAPES]
        cells.append(("freyja-discovery", "query"))
    else:
        shape = args.shape
        if shape is None and args.arch == "freyja-discovery":
            shape = "query"              # the discovery cell's only shape
        cells = [(args.arch, shape)]

    for mk in meshes:
        for arch, shape in cells:
            t0 = time.time()
            r = run_cell(arch, shape, mk, zero1=not args.no_zero1,
                         accum=args.accum, out_dir=args.out_dir, tag=args.tag,
                         kv_quant=args.kv_quant, mode=args.sharding_mode,
                         moe_sharding=args.moe_sharding,
                         mesh_override=args.mesh_override,
                         freyja_bf16=args.freyja_bf16, remat=args.remat)
            status = r["status"]
            extra = ""
            if status == "ok":
                extra = (f"bottleneck={r['bottleneck']} "
                         f"t=({r['t_compute_s']:.3f},{r['t_memory_s']:.3f},"
                         f"{r['t_collective_s']:.3f})s")
            elif status == "skip":
                extra = r["reason"]
            else:
                extra = r["error"][:160]
            print(f"[{mk:6s}] {arch:22s} {str(shape):11s} {status:5s} "
                  f"{time.time()-t0:6.1f}s {extra}", flush=True)


if __name__ == "__main__":
    main()
