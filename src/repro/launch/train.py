"""End-to-end training driver.

Examples:
  # ~100M-class model, real training on this host:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduce --steps 200 --batch 8 --seq 256

  # full config under the production mesh (requires the pod):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 100
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import TokenPipeline
from repro.dist import sharding as shd
from repro.models import registry
from repro.train.loop import train_loop
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.trainer import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduce", action="store_true",
                    help="use the reduced (smoke) config")
    ap.add_argument("--width", type=int, default=0,
                    help="override d_model of the reduced config (e.g. 768 "
                         "for a ~100M-class model)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none", choices=["none", "local", "single", "multi"])
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduce:
        cfg = registry.reduced_config(cfg)
        over = {}
        if args.width:
            over.update(d_model=args.width, d_ff=4 * args.width,
                        n_heads=max(4, args.width // 64), d_head=64,
                        n_kv=max(2, args.width // 128))
        if args.layers:
            over["n_layers"] = args.layers
        if over:
            cfg = dataclasses.replace(cfg, **over)
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"active≈{cfg.active_param_count()/1e6:.1f}M")

    mesh = None
    if args.mesh == "local":
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh()
    elif args.mesh in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    key = jax.random.PRNGKey(0)
    params, specs = registry.init_params(cfg, key)

    moment_specs = None
    to_device = None
    if mesh is not None:
        pshard = shd.param_shardings(specs, mesh)
        params = jax.tree.map(jax.device_put, params, pshard)
        mspec_named = shd.zero1_shardings(specs, params, mesh)
        moment_specs = jax.tree.map(lambda ns: ns.spec, mspec_named)
        from jax.sharding import NamedSharding, PartitionSpec as P
        bshard = NamedSharding(mesh, P(shd.batch_axes(mesh)))

        def to_device(batch):
            return {k: jax.device_put(v, bshard) for k, v in batch.items()}

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=min(50, args.steps // 10 + 1))
    opt_state = init_opt_state(params)
    step = build_train_step(cfg, opt_cfg, mesh=mesh, accum=args.accum,
                            moment_specs=moment_specs)
    step = jax.jit(step, donate_argnums=(0, 1))

    pipe = TokenPipeline(vocab=cfg.vocab, seq=args.seq, global_batch=args.batch)
    params, opt_state, hist = train_loop(
        step, params, opt_state, pipe, steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, to_device=to_device)
    if hist:
        print(f"first loss {hist[0][1]:.4f} -> last loss {hist[-1][1]:.4f}")


if __name__ == "__main__":
    main()
