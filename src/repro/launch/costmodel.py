"""Analytic per-cell cost model (flops / HBM bytes / collective bytes).

Why this exists: XLA's ``cost_analysis()`` on the CPU backend counts each
``While`` body ONCE — our models scan over layers, so tool-reported flops
under-count by ~L× and per-op "bytes accessed" both under-counts loops and
over-counts fusion. The dry-run therefore reports BOTH the raw tool numbers
and this closed-form model; the roofline table (EXPERIMENTS.md §Roofline)
uses the analytic terms. Formulas follow the standard MaxText/PaLM
accounting (6·N·D training matmuls, 12·B·S·W·h·dh attention, ring-collective
(n-1)/n factors), specialized per family. All numbers are per device,
per step.

Conventions:
  T      tokens per step (B·S train/prefill; B decode)
  dp     data-parallel shards (pod × data), mp model shards
  BF, F4 bf16 / f32 byte sizes
  remat  'block' adds one forward recompute (matmul factor 8/6 over 6·N·D)
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

BF, F4 = 2, 4


def _dense_layer_matmul_params(cfg) -> float:
    d, f, nh, nk, dh = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv,
                        cfg.d_head)
    attn = d * nh * dh + 2 * d * nk * dh + nh * dh * d
    mlp = (3 if cfg.act == "silu" else 2) * d * f
    return attn + mlp


def _layer_active_params(cfg) -> float:
    """Matmul params touched per token per layer (MoE: top_k experts)."""
    d, f = cfg.d_model, cfg.d_ff
    if cfg.family == "moe":
        attn = d * cfg.n_heads * cfg.d_head + 2 * d * cfg.n_kv * cfg.d_head \
            + cfg.n_heads * cfg.d_head * d
        mlp = cfg.top_k * (3 if cfg.act == "silu" else 2) * d * f \
            + d * cfg.n_experts
        return attn + mlp
    if cfg.family == "ssm":        # rwkv6
        lora = 2 * d * 64
        return 5 * d * d + lora + 2 * d * f + d * d
    if cfg.family == "hybrid":     # mamba2 blocks (shared attn added apart)
        din = cfg.ssm_expand * d
        h = din // cfg.ssm_headdim
        return d * (2 * din + 2 * cfg.ssm_state + h) + din * d
    if cfg.family == "encdec":
        # averaged enc/dec layer (cross attn on decoder layers)
        base = _dense_layer_matmul_params(cfg)
        cross = (cfg.d_model * cfg.n_heads * cfg.d_head * 2
                 + 2 * cfg.d_model * cfg.n_kv * cfg.d_head)
        return base + cross * cfg.dec_layers / max(cfg.n_layers, 1)
    return _dense_layer_matmul_params(cfg)


def _layer_stored_params(cfg) -> float:
    """Matmul params stored per layer (MoE: all experts)."""
    if cfg.family == "moe":
        d, f = cfg.d_model, cfg.d_ff
        attn = d * cfg.n_heads * cfg.d_head + 2 * d * cfg.n_kv * cfg.d_head \
            + cfg.n_heads * cfg.d_head * d
        return attn + cfg.n_experts * (3 if cfg.act == "silu" else 2) * d * f
    return _layer_active_params(cfg)


def _n_layers(cfg) -> int:
    if cfg.family == "encdec":
        return cfg.enc_layers + cfg.dec_layers
    return cfg.n_layers


def _attn_window(cfg, s: int) -> float:
    """Average keys attended per query token."""
    if cfg.family == "ssm":
        return 0.0                                  # attention-free
    w = cfg.sliding_window
    full = (s + 1) / 2                              # causal average
    per_layer = min(w, s) if w else full
    if cfg.family == "hybrid":
        # one shared attn block per attn_every mamba layers
        return per_layer / cfg.attn_every
    if cfg.family == "encdec":
        # enc: bidирect S keys; dec: causal + cross S keys
        return (s + (full + s)) / 2
    return per_layer


def _ssm_flops_per_token(cfg) -> float:
    """Recurrence flops per token per layer beyond projections."""
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.rwkv_headdim
        return 4 * h * cfg.rwkv_headdim ** 2        # rank-1 state update
    if cfg.family == "hybrid":
        din = cfg.ssm_expand * cfg.d_model
        cs, n = cfg.ssm_chunk, cfg.ssm_state
        # SSD: intra-chunk (≈ windowed attention of width Cs) + state update
        return 4 * cs * din + 6 * n * din / cfg.ssm_headdim * cfg.ssm_headdim
    return 0.0


@dataclasses.dataclass
class CellCost:
    flops: float            # per device
    hbm_bytes: float        # per device
    coll_bytes: float       # per device
    detail: dict

    def as_dict(self):
        return {"flops_per_device": self.flops,
                "hbm_bytes_per_device": self.hbm_bytes,
                "collective_bytes_per_device": self.coll_bytes,
                "detail": self.detail}


def cell_cost(cfg, kind: str, batch: int, seq: int, mesh_shape: dict,
              *, zero1: bool = True, kv_cache_dtype_bytes: int = BF,
              mode: str = "tp") -> CellCost:
    mp = mesh_shape.get("model", 1)
    dp = int(np.prod([v for k, v in mesh_shape.items() if k != "model"]))
    n_dev = mp * dp
    if mode == "dp":          # batch over every axis, weights replicated
        dp, mp = n_dev, 1
    l = _n_layers(cfg)
    d, v = cfg.d_model, cfg.vocab
    nh, nk, dh = cfg.n_heads, cfg.n_kv, cfg.d_head

    p_layer_active = _layer_active_params(cfg)
    p_layer_stored = _layer_stored_params(cfg)
    p_matmul_active = l * p_layer_active + 2 * d * v  # embed + head
    p_stored = l * p_layer_stored + (1 if cfg.tie_embeddings else 2) * d * v

    t_global = batch * (seq if kind != "decode" else 1)
    t_dev = t_global / dp if kind != "decode" else max(batch / dp, 1)
    b_loc = max(batch / dp, 1)

    mult = {"train": 6, "prefill": 2, "decode": 2}[kind]
    remat_f = (8 / 6) if (kind == "train" and cfg.remat == "block") else 1.0

    # ---- flops ----
    matmul = mult * remat_f * t_dev * p_matmul_active / mp
    w_avg = _attn_window(cfg, seq)
    attn_mult = {"train": 3, "prefill": 1, "decode": 1}[kind] * remat_f
    if kind == "decode":
        attn = attn_mult * 4 * b_loc * w_avg_decode(cfg, seq) * nh * dh * l / mp
    else:
        attn = attn_mult * 4 * t_dev * w_avg * nh * dh * l / mp
    ssm = mult / 2 * remat_f * t_dev * _ssm_flops_per_token(cfg) * l / mp
    flops = matmul + attn + ssm

    # ---- HBM bytes ----
    p_shard = p_stored * BF / (mp if mp else 1)
    if mode == "fsdp":
        p_shard = p_shard / dp    # resident shard; AG'd slices stream through
    if kind == "train":
        # weights: fwd read + bwd read (+ remat extra read) + update write
        w_reads = (3 if cfg.remat == "block" else 2) + 1
        weight_traffic = p_shard * w_reads
        # optimizer: grads f32 r+w, m/v read+write, param f32 math
        opt_traffic = (p_stored / mp) * (F4 * 2 + F4 * 4 + F4 * 2) / \
            (dp if zero1 else 1) + p_shard  # AG'd params write
        # activations: residual stream + block internals (≈6 tensors/layer),
        # written fwd + read bwd; remat halves what is stored
        act_tensors = 2 if cfg.remat == "block" else 6
        acts = 2 * act_tensors * l * t_dev * d * BF
        logits = 2 * t_dev * (v / mp) * F4
        hbm = weight_traffic + opt_traffic + acts + logits
    elif kind == "prefill":
        acts = 2 * 2 * l * t_dev * d * BF
        hbm = p_shard + acts + t_dev * (v / mp) * F4
    else:  # decode
        cache = _cache_bytes_per_dev(cfg, batch, seq, mesh_shape,
                                     kv_cache_dtype_bytes)
        hbm = p_shard + 2 * cache + t_dev * (v / mp) * F4
    hbm = float(hbm)

    # ---- collective bytes (ring factors) ----
    ring_mp = 2 * (mp - 1) / mp if mp > 1 else 0.0
    ring_dp = (dp - 1) / dp if dp > 1 else 0.0
    tok_bytes = t_dev * d * BF
    if cfg.family == "moe" and cfg.moe_sharding == "ep" and mp > 1:
        a2a = 2 * t_dev * cfg.top_k * max(cfg.capacity_factor, 2.0) * d * BF \
            * (mp - 1) / mp
        tp_per_layer = 1 * tok_bytes * ring_mp + a2a   # attn psum + A2A pair
    else:
        psums = 2 if cfg.family in ("moe", "hybrid") else 2
        tp_per_layer = psums * tok_bytes * ring_mp
    fwd_bwd = {"train": 2, "prefill": 1, "decode": 1}[kind]
    coll = tp_per_layer * l * fwd_bwd
    if kind == "train":
        # ZeRO-1: reduce-scatter grads (f32) + all-gather params (bf16)
        coll += (p_stored / mp) * (F4 + BF) * ring_dp
    if mode == "fsdp":
        # per-layer param all-gathers: fwd + bwd (+ remat refetch)
        refetch = 3 if (kind == "train" and cfg.remat == "block") else \
            (2 if kind == "train" else 1)
        coll += refetch * (p_stored * BF / mp) * ring_dp
    if kind == "decode" and batch < dp and seq >= 2 ** 18:
        # sequence-sharded cache: per layer combine of partial attention
        coll += l * b_loc * nh * dh * F4 * ring_dp
    return CellCost(flops=float(flops), hbm_bytes=hbm, coll_bytes=float(coll),
                    detail={"matmul_flops": float(matmul),
                            "attn_flops": float(attn),
                            "ssm_flops": float(ssm),
                            "param_bytes_per_dev": float(p_shard),
                            "tokens_per_dev": float(t_dev),
                            "n_devices": n_dev})


# ---------------------------------------------------------------------------
# discovery pipeline (candidate -> score -> merge) per-stage costs
# ---------------------------------------------------------------------------

def discovery_stage_costs(n_queries: int, n_columns: int, *, budget: int,
                          candidates: str = "hybrid", k: int = 10,
                          n_bands: int = 64, n_trees: int = 30,
                          tree_depth: int = 4, n_shards: int = 1,
                          q_shards: int = 1, survivor_budget: int = 0,
                          n_coarse_bands: int = 16) -> dict:
    """Analytic per-device cost of one discovery micro-batch, per stage.

    The planner's default cost hook (``repro.exec.Planner``): flops / HBM
    bytes / collective bytes for the candidate→score→merge pipeline over a
    (``q_shards`` × ``n_shards``) query×data device grid — each device
    sees ``ceil(Q / q_shards)`` queries against ``ceil(C / n_shards)``
    columns. A pruned plan pays the bucket probe (Ql·Cl·B uint32 compares)
    and, for ``hybrid``, one (Ql, F_NUM)×(F_NUM, Cl) proxy matmul over
    *all* local columns to score only ``budget/n_shards`` of them — so it
    beats the brute scan exactly when the budget is small relative to the
    lake, which is the decision "auto" mode makes.

    Per-device flops are factorization-symmetric at fixed q·d (Ql·Cl is
    constant), so the grid choice hangs on the asymmetric terms: the HBM
    bytes grow with Cl (corpus replication across query shards re-reads
    the keys/profiles on every replica), while the merge collective
    shrinks with d (phase 1 gathers Ql·k·d pairs over the data axis) and
    pays a small query-axis reassembly (phase 2) instead. Replace via the
    ``cost_fn`` hook once measured numbers exist (ROADMAP: native-TPU
    tuning).
    """
    from repro.core import features as FT

    qg = max(int(n_queries), 1)
    q_sh = max(int(q_shards), 1)
    q = -(-qg // q_sh)                                 # local queries/device
    shards = max(int(n_shards), 1)
    cl = -(-max(int(n_columns), 1) // shards)          # local columns/device
    # distance-feature work per scored pair: F_NUM |Δz| subs, the 10×10
    # frequent-word overlap compare, first-word equality + GBDT traversal
    feat_ops = FT.F_NUM + FT.N_FREQ_WORDS ** 2 + 2
    pair_ops = feat_ops + n_trees * tree_depth
    profile_bytes = (FT.F_NUM + FT.F_WORDS) * F4

    stg = {}
    if candidates == "all":
        m = cl
        stg["candidates"] = {"flops": 0.0, "hbm_bytes": 0.0}
    elif candidates == "tiered":
        # coarse digest over ALL local columns (S << B uint32 lanes, no
        # proxy matmul), then the fine probe + proxy + gather only over the
        # C' gathered survivors — the full-lake terms shrink from
        # (B + 2·F_NUM) per column to S per column
        m = min(-(-max(int(budget), 1) // shards), cl)
        surv = min(max(int(survivor_budget), 1), cl)
        s_bands = max(int(n_coarse_bands), 1)
        coarse = q * cl * s_bands + q * cl              # probe + selection
        fine = q * surv * (n_bands + 2.0 * FT.F_NUM + 1)
        gather = q * surv * (FT.F_NUM + n_bands)        # per-query gathers
        stg["candidates"] = {
            "flops": coarse + fine + gather,
            "hbm_bytes": (q + cl) * s_bands * 4 + q * cl * F4
            + q * surv * (n_bands * 4 + FT.F_NUM * F4),
        }
    else:
        m = min(-(-max(int(budget), 1) // shards), cl)
        probe = q * cl * n_bands                        # uint32 equality
        proxy = 2.0 * q * cl * FT.F_NUM if candidates == "hybrid" else 0.0
        stg["candidates"] = {
            "flops": probe + proxy + q * cl,            # + budget selection
            "hbm_bytes": (q + cl) * n_bands * 4 + q * cl * F4
            + (q + cl) * FT.F_NUM * F4,
        }
    stg["score"] = {
        "flops": float(q * m * pair_ops),
        "hbm_bytes": float((q + m) * profile_bytes + q * m * F4),
    }
    kl = min(k, m)
    # phase 1: tiled all_gather of every data shard's (score, id) top-k
    # pairs within the query shard; phase 2: all_gather over the query
    # axis reassembles the (Q, k) batch from its (Ql, k) shards
    data_coll = float(q * kl * shards * (F4 + 4)) if shards > 1 else 0.0
    query_coll = float(q * kl * q_sh * (F4 + 4)) if q_sh > 1 else 0.0
    stg["merge"] = {
        "flops": float(q * m),
        "hbm_bytes": float(q * m * F4),
        "collective_bytes": data_coll + query_coll,
    }
    return {
        "stages": stg,
        "total_flops": float(sum(s["flops"] for s in stg.values())),
        "total_hbm_bytes": float(sum(s["hbm_bytes"] for s in stg.values())),
        "total_collective_bytes": float(stg["merge"]["collective_bytes"]),
        "n_queries": qg,
        "queries_per_device": int(q),
        "n_shards": shards,
        "q_shards": q_sh,
        "grid": [q_sh, shards],
        "scored_per_device": int(m),
        "survivor_budget": int(min(max(int(survivor_budget), 1), cl))
        if candidates == "tiered" else 0,
    }


def calibrate_stage_costs(bench="BENCH_service.json", *, k: int = 10,
                          n_bands: int = 64):
    """Fit per-stage time constants from measured service-bench timings.

    Closes the ROADMAP "measured cost model" item: the analytic
    :func:`discovery_stage_costs` predicts *flops*, but the "auto" planner
    needs *time* crossovers that match the machine.  Each
    ``BENCH_service.json`` lake entry records the measured per-query
    latency of the plan each mode executed; regressing those against the
    analytic per-stage flop counts (candidates / score / merge, plus a
    fixed dispatch overhead) yields seconds-per-flop constants for this
    host.  The full-scan rows pin the score/merge constants (their
    candidate flops are zero); the pruned rows then identify the candidate
    constant.

    ``bench`` is a path or an already-loaded record.  Returns
    ``(constants, cost_fn)`` where ``cost_fn`` is a drop-in for the
    planner/engine hook (``Planner(cost_fn=...)`` /
    ``EngineConfig(cost_fn=...)``): it returns the analytic stage dict
    augmented with ``total_cost`` (predicted seconds for the batch), which
    "auto" mode prefers over raw flops when present.
    """
    import json
    if isinstance(bench, (str, os.PathLike)):
        with open(bench) as f:
            record = json.load(f)
    else:
        record = bench

    rows_x, rows_y = [], []
    for lake in record.get("lakes", []):
        c = int(lake["n_columns"])
        for stats in lake.get("modes", {}).values():
            kind = stats.get("plan") or ""
            cand = ("tiered" if kind.endswith("tiered") else
                    "hybrid" if kind.endswith("hybrid") else
                    "lsh" if kind.endswith("lsh") else "all")
            budget = int(stats.get("plan_budget") or c)
            surv = int(stats.get("plan_survivor_budget") or 4 * budget)
            stg = discovery_stage_costs(1, c, budget=budget, candidates=cand,
                                        k=k, n_bands=n_bands,
                                        survivor_budget=surv)["stages"]
            rows_x.append([stg["candidates"]["flops"], stg["score"]["flops"],
                           stg["merge"]["flops"], 1.0])
            rows_y.append(float(stats["batch_ms_per_query"]) * 1e-3)
    if len(rows_y) < 4:
        raise ValueError(
            f"need >= 4 timed (lake, mode) observations to fit 4 constants; "
            f"{bench!r} has {len(rows_y)} — run benchmarks/bench_service.py "
            f"first")

    x = np.asarray(rows_x, np.float64)
    y = np.asarray(rows_y, np.float64)
    coef, *_ = np.linalg.lstsq(x, y, rcond=None)
    coef = np.clip(coef, 0.0, None)     # a stage can't have negative cost
    pred = x @ coef
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    constants = {
        "candidates_s_per_flop": float(coef[0]),
        "score_s_per_flop": float(coef[1]),
        "merge_s_per_flop": float(coef[2]),
        "fixed_s_per_query": float(coef[3]),
        "n_obs": len(rows_y),
        "r2": 1.0 - ss_res / max(ss_tot, 1e-30),
    }
    return constants, make_calibrated_cost_fn(constants)


def derive_batch_buckets(bench="BENCH_service.json"):
    """Batch-bucket ladder for the continuous-batching scheduler, derived
    from a measured ``BENCH_service.json``.

    When the record carries a ``--batch-sweep`` section, its measured
    batch sizes ARE the ladder: they are exactly the padded shapes whose
    grid choice (1-D vs each 2-D factorization, and the sustained
    crossover between them) was timed on this host, so snapping formed
    batches to them reuses both the compiled executables and the
    measured placement decisions.  Without a sweep (or without a
    readable file) the analytic default
    ``repro.exec.DEFAULT_BATCH_BUCKETS`` is returned.

    ``bench`` is a path or an already-loaded record.  Returns a sorted
    tuple of bucket sizes.
    """
    import json

    from repro.exec.plan import DEFAULT_BATCH_BUCKETS
    record = bench
    if isinstance(bench, (str, os.PathLike)):
        try:
            with open(bench) as f:
                record = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return DEFAULT_BATCH_BUCKETS
    sweep = (record or {}).get("batch_sweep", {})
    sizes = sorted({int(e["batch"]) for e in sweep.get("batches", [])
                    if int(e["batch"]) >= 1})
    return tuple(sizes) if sizes else DEFAULT_BATCH_BUCKETS


def derive_column_buckets(bench="BENCH_service.json"):
    """Corpus-column bucket ladder for delta-proportional refresh, derived
    from a measured ``BENCH_service.json``.

    The scale sweep records which lake sizes this deployment actually
    serves; snapping the PLACED corpus dimension to those rungs (padded
    with inert sentinel rows) keeps every traced shape stable across
    ingest deltas, so an in-bucket refresh re-dispatches the compiled
    executables verbatim — zero steady-state recompiles.  The ladder is
    the measured lake sizes rounded UP to the analytic default rungs
    (a rung per measured point would make crossings too frequent to
    amortize).  Without a sweep (or without a readable file) the
    analytic default ``repro.exec.DEFAULT_COLUMN_BUCKETS`` is returned.

    ``bench`` is a path or an already-loaded record.  Returns a sorted
    tuple of bucket sizes.
    """
    import json

    from repro.exec.plan import DEFAULT_COLUMN_BUCKETS
    record = bench
    if isinstance(bench, (str, os.PathLike)):
        try:
            with open(bench) as f:
                record = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return DEFAULT_COLUMN_BUCKETS
    sweep = (record or {}).get("scale_sweep", {})
    lakes = sorted({int(e["n_columns"]) for e in sweep.get("lakes", [])
                    if int(e.get("n_columns", 0)) >= 1})
    if not lakes:
        return DEFAULT_COLUMN_BUCKETS
    rungs = set()
    for n in lakes:
        snapped = next((b for b in DEFAULT_COLUMN_BUCKETS if n <= b),
                       -(-n // DEFAULT_COLUMN_BUCKETS[-1])
                       * DEFAULT_COLUMN_BUCKETS[-1])
        rungs.add(int(snapped))
        # one headroom rung above the largest measured lake, so steady
        # ingest has a pre-warmable bucket to grow into
    top = max(rungs)
    nxt = next((b for b in DEFAULT_COLUMN_BUCKETS if b > top),
               top + DEFAULT_COLUMN_BUCKETS[-1])
    rungs.add(int(nxt))
    return tuple(sorted(rungs))


def make_calibrated_cost_fn(constants: dict):
    """Wrap fitted per-stage constants into a planner ``cost_fn`` hook."""

    def cost_fn(n_queries: int, n_columns: int, *, budget: int,
                candidates: str = "hybrid", k: int = 10, n_bands: int = 64,
                n_trees: int = 30, tree_depth: int = 4,
                n_shards: int = 1, q_shards: int = 1,
                survivor_budget: int = 0, n_coarse_bands: int = 16) -> dict:
        c = discovery_stage_costs(n_queries, n_columns, budget=budget,
                                  candidates=candidates, k=k,
                                  n_bands=n_bands, n_trees=n_trees,
                                  tree_depth=tree_depth, n_shards=n_shards,
                                  q_shards=q_shards,
                                  survivor_budget=survivor_budget,
                                  n_coarse_bands=n_coarse_bands)
        stg = c["stages"]
        # per-device stage flops × fitted s/flop: the critical-path device
        # (dispatch overhead is per-batch, so the fixed term stays global)
        seconds = (constants["fixed_s_per_query"] * c["n_queries"]
                   + constants["candidates_s_per_flop"]
                   * stg["candidates"]["flops"]
                   + constants["score_s_per_flop"] * stg["score"]["flops"]
                   + constants["merge_s_per_flop"] * stg["merge"]["flops"])
        c["total_cost"] = float(seconds)
        c["calibrated"] = True
        return c

    return cost_fn


def plan_cost_per_query(cost: dict | None) -> float | None:
    """Per-request cost of an executed plan, for the fleet router.

    Prefers the calibrated ``total_cost`` (predicted seconds — what
    ``make_calibrated_cost_fn`` attaches); falls back to ``total_flops``
    scaled to pseudo-seconds so calibrated and analytic replicas stay
    on comparable magnitudes.  Returns ``None`` when ``cost`` carries
    neither (the router then uses its unit-cost default) — the router
    only ever *compares* these values across replicas, so any shared
    monotone scale works.
    """
    if not cost:
        return None
    n = max(float(cost.get("n_queries", 1) or 1), 1.0)
    total = cost.get("total_cost")
    if total is None:
        flops = cost.get("total_flops")
        if flops is None:
            return None
        total = float(flops) * 1e-9
    return max(float(total) / n, 1e-9)


def w_avg_decode(cfg, seq: int) -> float:
    if cfg.family == "ssm":
        return 0.0
    w = cfg.sliding_window
    per = min(w, seq) if w else seq
    if cfg.family == "hybrid":
        return per / cfg.attn_every
    if cfg.family == "encdec":
        return 2 * seq            # self cache + cross memory
    return per


def _cache_bytes_per_dev(cfg, batch, seq, mesh_shape, cache_b) -> float:
    mp = mesh_shape.get("model", 1)
    dp = int(np.prod([v for k, v in mesh_shape.items() if k != "model"]))
    l = _n_layers(cfg)
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.rwkv_headdim
        st = batch * h * cfg.rwkv_headdim ** 2 * F4 * l
        return st / dp if batch >= dp else st
    size = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    # cache sharded over 'model' via kv heads when divisible, else via the
    # sequence axis (flash-decoding SPMD combine) — see dryrun.cache_shardings
    kv_shard = mp if (cfg.n_kv % mp == 0 or size % mp == 0) else 1
    per_layer = 2 * batch * size * cfg.n_kv * cfg.d_head * cache_b / kv_shard
    n_attn = l if cfg.family != "hybrid" else l // cfg.attn_every
    total = per_layer * n_attn
    if cfg.family == "hybrid":
        din = cfg.ssm_expand * cfg.d_model
        h = din // cfg.ssm_headdim
        total += batch * h * cfg.ssm_state * cfg.ssm_headdim * F4 * l
    shard = dp if batch >= dp else (dp if seq >= 2 ** 18 else 1)
    return total / shard
