"""Batched serving driver: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduce \
      --batch 4 --prompt-len 64 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import registry
from repro.serve.engine import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduce:
        cfg = registry.reduced_config(cfg)
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)

    t0 = time.perf_counter()
    out = generate(params, cfg, prompts, max_new=args.max_new,
                   cache_size=args.prompt_len + args.max_new)
    dt = time.perf_counter() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
