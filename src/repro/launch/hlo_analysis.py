"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` exposes per-device FLOPs and bytes-accessed but not
collective traffic, so we parse the compiled HLO text and sum bytes moved
per device for every collective op, with the standard ring-algorithm
factors:

    all-gather          result_bytes  × (n-1)/n
    reduce-scatter      operand_bytes × (n-1)/n
    all-reduce          2 × operand_bytes × (n-1)/n   (RS + AG phases)
    all-to-all          operand_bytes × (n-1)/n
    collective-permute  operand_bytes

Group size ``n`` is parsed from ``replica_groups`` (iota or explicit form).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<result>.*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    count_by_op: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by_op: dict[str, float] = {}
    count_by_op: dict[str, int] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        if "-done(" in line:        # async pair: count only the start
            continue
        result_bytes = _shape_bytes(m.group("result"))
        # operand bytes: shapes appearing in the argument list
        args = line[m.end():]
        operand_bytes = _shape_bytes(args.split(")")[0])
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gm2 = _GROUPS_LIST_RE.search(line)
            n = len(gm2.group(1).split(",")) if gm2 else 2
        n = max(n, 2)
        ring = (n - 1) / n
        if op == "all-gather":
            b = result_bytes * ring
        elif op == "reduce-scatter":
            b = operand_bytes * ring
        elif op == "all-reduce":
            b = 2 * operand_bytes * ring
        elif op == "all-to-all":
            b = operand_bytes * ring
        else:  # collective-permute
            b = operand_bytes
        bytes_by_op[op] = bytes_by_op.get(op, 0.0) + b
        count_by_op[op] = count_by_op.get(op, 0) + 1
    return CollectiveStats(bytes_by_op=bytes_by_op, count_by_op=count_by_op)


# ---- TPU v5e hardware constants (roofline denominators) -------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip effective)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    t_c = flops_per_dev / PEAK_FLOPS
    t_m = bytes_per_dev / HBM_BW
    t_x = coll_bytes_per_dev / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "bottleneck": dom,
            "bound_s": max(t_c, t_m, t_x)}
