"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Two sharding modes (cfg.moe_sharding):

* ``tp`` — every expert's d_ff is sharded over the ``model`` axis (Mixtral's
  8 experts don't divide a 16-wide model axis). Dispatch is *local* to each
  data shard: tokens are sorted by expert id, gathered into (E, C, d) blocks
  with capacity C = ceil(T·k/E · capacity_factor) and dropped beyond C
  (GShard-style token dropping), run through an E-batched gated FFN, and
  combined with router weights. The down-projection produces partial sums
  over the f-shards → one psum over ``model`` per layer (same collective
  pattern as dense TP).

* ``ep`` — experts are fully sharded over ``model`` (phi3.5-moe: 16 experts
  / 16-way axis = 1 expert per rank). Tokens travel to their expert's rank
  via ``all_to_all`` over ``model`` and return the same way: two A2As per
  layer instead of a psum; collective bytes per token drop from O(d) (ring
  all-reduce) to O(d · k / mp) sent point-to-point — the classic EP trade.
  EP assumes n_experts % model_axis_size == 0 and is most efficient at one
  expert per rank (the phi3.5 cell); with several local experts the local
  FFN masks per expert (documented compute overhead).

Both run inside ``shard_map`` (manual collectives), composing with the
pjit-propagated sharding of the surrounding dense layers, where activations
are replicated across ``model`` and sharded across the batch axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(cfg, key, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {"router": dense_init(ks[0], (d, e), jnp.float32),
         "w_gate": dense_init(ks[1], (e, d, f), dtype),
         "w_up": dense_init(ks[2], (e, d, f), dtype),
         "w_down": dense_init(ks[3], (e, f, d), dtype)}
    if cfg.moe_sharding == "ep":
        s = {"router": ("none", "none"),
             "w_gate": ("expert", "none", "none"),
             "w_up": ("expert", "none", "none"),
             "w_down": ("expert", "none", "none")}
    else:
        s = {"router": ("none", "none"),
             "w_gate": ("none", "none", "mlp"),
             "w_up": ("none", "none", "mlp"),
             "w_down": ("none", "mlp", "none")}
    return p, s


def _dispatch(eids, weights, tokens, n_buckets: int, capacity: int):
    """Sort-based capacity dispatch (static shapes, GShard-style dropping).

    Returns per (bucket, slot): token row (-1 pad), router weight, copy id.
    """
    tk = eids.shape[0]
    order = jnp.argsort(eids)                               # stable
    es = eids[order]
    counts = jax.nn.one_hot(es, n_buckets, dtype=jnp.int32).sum(0)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(tk, dtype=jnp.int32) - start[es]
    keep = pos < capacity
    slot = jnp.where(keep, es * capacity + pos, n_buckets * capacity)

    def scatter(vals, fill, dt):
        out = jnp.full((n_buckets * capacity + 1,), fill, dt)
        return out.at[slot].set(vals.astype(dt))[:-1]

    return (scatter(tokens[order], -1, jnp.int32),
            scatter(weights[order], 0.0, jnp.float32),
            scatter(order, -1, jnp.int32))


def _expert_ffn(xe, w_gate, w_up, w_down, act: str):
    """(E, C, d) × (E, d, f) -> (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xe, w_up)
    h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_ffn_local(p, cfg, x, *, model_axis: str | None):
    """MoE FFN over this shard's local tokens. x (Tl, d) -> (Tl, d)."""
    tl, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                  # (Tl, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    copies_e = top_e.reshape(-1).astype(jnp.int32)          # (Tl·k,)
    copies_w = top_w.reshape(-1)
    copies_t = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)

    if cfg.moe_sharding == "ep" and model_axis is not None:
        # jax.lax.axis_size only exists on newer jax; psum(1) is equivalent
        mp = jax.lax.psum(1, model_axis)
        e_local = e // mp
        send_cf = max(cfg.capacity_factor, 2.0)             # A2A send buffer
        cap_send = int(max(8, round(tl * k / mp * send_cf)))
        dest = copies_e // e_local
        slot_token, slot_weight, slot_copy = _dispatch(
            dest, copies_w, copies_t, mp, cap_send)
        local_e = jnp.where(slot_copy >= 0,
                            copies_e[jnp.maximum(slot_copy, 0)] % e_local, -1)
        xe = jnp.where(slot_token[:, None] >= 0,
                       x[jnp.maximum(slot_token, 0)], 0.0).reshape(mp, cap_send, d)
        meta = local_e.astype(jnp.float32).reshape(mp, cap_send, 1)
        xr = jax.lax.all_to_all(xe, model_axis, split_axis=0, concat_axis=0,
                                tiled=True).reshape(mp * cap_send, d)
        mr = jax.lax.all_to_all(meta, model_axis, split_axis=0, concat_axis=0,
                                tiled=True).reshape(-1).astype(jnp.int32)
        yr = jnp.zeros_like(xr)
        for le_i in range(e_local):
            h = _expert_ffn(xr[None], p["w_gate"][le_i][None],
                            p["w_up"][le_i][None], p["w_down"][le_i][None],
                            cfg.act)[0]
            yr = yr + h * (mr == le_i)[:, None].astype(xr.dtype)
        yr = yr.reshape(mp, cap_send, d)
        yb = jax.lax.all_to_all(yr, model_axis, split_axis=0, concat_axis=0,
                                tiled=True).reshape(mp * cap_send, d)
        out = jnp.zeros((tl, d), x.dtype)
        return out.at[jnp.maximum(slot_token, 0)].add(
            jnp.where(slot_token[:, None] >= 0,
                      yb * slot_weight[:, None], 0.0).astype(x.dtype))

    # ---- tp (or single-device) path: local dispatch ----
    cap = int(max(8, -(-round(tl * k / e * cfg.capacity_factor) // 8) * 8))
    slot_token, slot_weight, _ = _dispatch(copies_e, copies_w, copies_t, e, cap)
    xe = jnp.where(slot_token[:, None] >= 0,
                   x[jnp.maximum(slot_token, 0)], 0.0).reshape(e, cap, d)
    ye = _expert_ffn(xe, p["w_gate"], p["w_up"], p["w_down"], cfg.act)
    if model_axis is not None and cfg.moe_sharding == "tp":
        ye = jax.lax.psum(ye, model_axis)                   # combine f-shards
    ye = ye.reshape(e * cap, d) * slot_weight[:, None].astype(x.dtype)
    out = jnp.zeros((tl, d), x.dtype)
    return out.at[jnp.maximum(slot_token, 0)].add(
        jnp.where(slot_token[:, None] >= 0, ye, 0.0).astype(x.dtype))
