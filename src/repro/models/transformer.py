"""Decoder-only LM assembly for all families (dense / moe / vlm / ssm /
hybrid). Layers are stacked and scanned (``jax.lax.scan``) so HLO size is
depth-independent; per-block remat (``cfg.remat``) bounds activation memory.

Families:
* dense / vlm  — [norm → GQA attn → +res, norm → (Sw)iGLU MLP → +res] × L
* moe          — MLP replaced by ``moe_ffn_local`` (shard_map, TP or EP)
* ssm (rwkv6)  — RWKV6 time-mix + channel-mix blocks
* hybrid       — zamba2: groups of ``attn_every`` mamba2 blocks followed by
                 a **shared** (single-parameter) attention+MLP block
                 (simplified from the paper's concat+LoRA variant; see
                 DESIGN.md §Arch-applicability)

VLM: ``img`` stub embeddings replace the first ``n_patches`` token
embeddings (the CLIP frontend is out of scope per the assignment).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, dtype_of, embed_tokens,
                                 init_embed, init_mlp, init_norm, lm_logits,
                                 stack_layers)


def _batch_axes(mesh):
    from repro.dist import sharding as shd
    return shd.batch_axes(mesh)


def constrain(x, mesh, *axes):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_dense_block(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    pa, sa = attn.init_attention(cfg, k1, dtype)
    pm, sm = (init_mlp(cfg, k2, dtype) if cfg.family != "moe"
              else moe_mod.init_moe(cfg, k2, dtype))
    pn1, sn1 = init_norm(cfg, dtype)
    pn2, sn2 = init_norm(cfg, dtype)
    return ({"attn": pa, "mlp": pm, "ln1": pn1, "ln2": pn2},
            {"attn": sa, "mlp": sm, "ln1": sn1, "ln2": sn2})


def init_lm(cfg, key):
    dtype = dtype_of(cfg.param_dtype)
    ke, kb, kf = jax.random.split(key, 3)
    pe, se = init_embed(cfg, ke, dtype)
    pn, sn = init_norm(cfg, dtype)
    params: dict[str, Any] = {"embed": pe, "final_norm": pn}
    specs: dict[str, Any] = {"embed": se, "final_norm": sn}

    keys = jax.random.split(kb, max(cfg.n_layers, 1))
    if cfg.family in ("dense", "moe", "vlm"):
        inits = [_init_dense_block(cfg, keys[i], dtype) for i in range(cfg.n_layers)]
        params["blocks"] = stack_layers([p for p, _ in inits])
        specs["blocks"] = jax.tree.map(lambda a: ("layers",) + a, inits[0][1],
                                       is_leaf=lambda x: isinstance(x, tuple))
    elif cfg.family == "ssm":
        inits = [rwkv_mod.init_rwkv_block(cfg, keys[i], dtype)
                 for i in range(cfg.n_layers)]
        params["blocks"] = stack_layers([p for p, _ in inits])
        specs["blocks"] = jax.tree.map(lambda a: ("layers",) + a, inits[0][1],
                                       is_leaf=lambda x: isinstance(x, tuple))
    elif cfg.family == "hybrid":
        inits = [ssm_mod.init_mamba2(cfg, keys[i], dtype)
                 for i in range(cfg.n_layers)]
        params["blocks"] = stack_layers([p for p, _ in inits])
        specs["blocks"] = jax.tree.map(lambda a: ("layers",) + a, inits[0][1],
                                       is_leaf=lambda x: isinstance(x, tuple))
        pshared, sshared = _init_dense_block(
            dataclasses_replace_family(cfg), kf, dtype)
        params["shared"] = pshared
        specs["shared"] = sshared
        pn3, sn3 = init_norm(cfg, dtype)
        params["blocks_norm"] = _stack_norms(cfg, dtype, cfg.n_layers)
        specs["blocks_norm"] = {"scale": ("layers", "embed")} if cfg.norm == "rmsnorm" \
            else {"scale": ("layers", "embed"), "bias": ("layers", "embed")}
        params["shared_norm"] = pn3
        specs["shared_norm"] = sn3
    else:
        raise ValueError(cfg.family)
    return params, specs


def dataclasses_replace_family(cfg):
    import dataclasses
    return dataclasses.replace(cfg, family="dense")


def _stack_norms(cfg, dtype, n):
    p = {"scale": jnp.ones((n, cfg.d_model), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((n, cfg.d_model), dtype)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _moe_apply(pm, cfg, x, mesh):
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    if mesh is None:
        y = moe_mod.moe_ffn_local(pm, cfg, x2, model_axis=None)
    else:
        import numpy as np
        from jax.experimental.shard_map import shard_map
        from repro.dist.sharding import spec_of
        ba = _batch_axes(mesh)
        nba = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
        # tiny decode batches (long_500k B=1) replicate tokens instead
        divisible = (b * s) % nba == 0 and b * s >= nba
        xspec = P(ba, None) if divisible else P(None, None)
        pspecs = jax.tree.map(
            lambda a: spec_of(a, mesh), _moe_specs(cfg),
            is_leaf=lambda v: isinstance(v, tuple))

        from repro.dist.sharding import get_mode
        # dp mode replicates expert weights — no model-axis collective
        maxis = "model" if (get_mode() != "dp" and "model" in mesh.axis_names) \
            else None

        def local_fn(pm_, x_):
            return moe_mod.moe_ffn_local(pm_, cfg, x_, model_axis=maxis)

        fn = shard_map(local_fn, mesh=mesh, in_specs=(pspecs, xspec),
                       out_specs=xspec, check_rep=False)
        y = fn(pm, x2)
    return y.reshape(b, s, d)


def _moe_specs(cfg):
    if cfg.moe_sharding == "ep":
        return {"router": ("none", "none"), "w_gate": ("expert", "none", "none"),
                "w_up": ("expert", "none", "none"),
                "w_down": ("expert", "none", "none")}
    return {"router": ("none", "none"), "w_gate": ("none", "none", "mlp"),
            "w_up": ("none", "none", "mlp"), "w_down": ("none", "mlp", "none")}


def _dense_block_fwd(pl, cfg, x, positions, mesh):
    h = apply_norm(pl["ln1"], x, cfg.norm)
    h = attn.attention_train(pl["attn"], cfg, h, positions)
    x = x + h
    x = constrain(x, mesh, _batch_axes(mesh) if mesh else None)
    h = apply_norm(pl["ln2"], x, cfg.norm)
    if cfg.family == "moe":
        h = _moe_apply(pl["mlp"], cfg, h, mesh)
    else:
        h = apply_mlp(pl["mlp"], h, cfg.act)
    return x + h


def forward(params, cfg, tokens, *, img=None, mesh=None):
    """Training/prefill forward. tokens (B, S) -> logits (B, S, V) f32."""
    cdt = dtype_of(cfg.compute_dtype)
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens, cdt)
    if cfg.family == "vlm" and img is not None:
        x = jnp.concatenate([img.astype(cdt), x[:, cfg.n_patches:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = constrain(x, mesh, _batch_axes(mesh) if mesh else None)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(xc, pl):
            return _dense_block_fwd(pl, cfg, xc, positions, mesh), None
        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    elif cfg.family == "ssm":
        def body(xc, pl):
            out, _ = rwkv_mod.rwkv_block_forward(pl, cfg, xc)
            return out, None
        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    elif cfg.family == "hybrid":
        x = _hybrid_forward(params, cfg, x, positions, mesh)
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embed"], x)


def _hybrid_forward(params, cfg, x, positions, mesh):
    ae = cfg.attn_every
    n_groups = cfg.n_layers // ae
    blocks = jax.tree.map(
        lambda a: a.reshape((n_groups, ae) + a.shape[1:]), params["blocks"])
    norms = jax.tree.map(
        lambda a: a.reshape((n_groups, ae) + a.shape[1:]), params["blocks_norm"])
    shared = params["shared"]
    shared_norm = params["shared_norm"]

    def mamba_body(xc, pl_and_norm):
        pl, nl = pl_and_norm
        h = apply_norm(nl, xc, cfg.norm)
        return xc + ssm_mod.mamba2_forward(pl, cfg, h), None

    if cfg.remat == "block":
        mamba_body = jax.checkpoint(mamba_body, prevent_cse=False)

    def group(xc, grp):
        gp, gn = grp
        xc, _ = jax.lax.scan(mamba_body, xc, (gp, gn))
        # shared attention + MLP block (same params every group)
        h = apply_norm(shared_norm, xc, cfg.norm)
        h = attn.attention_train(shared["attn"], cfg, h, positions)
        xc = xc + h
        h = apply_norm(shared["ln2"], xc, cfg.norm)
        xc = xc + apply_mlp(shared["mlp"], h, cfg.act)
        return xc, None

    x, _ = jax.lax.scan(group, x, (blocks, norms))
    return x


# ---------------------------------------------------------------------------
# prefill: forward that also materializes decode caches
# ---------------------------------------------------------------------------

def _roll_pad(a, cache_size: int):
    """Pack a (B, S, ...) tensor into ``cache_size`` slots (ring layout)."""
    s = a.shape[1]
    if s >= cache_size:
        return jnp.roll(a[:, s - cache_size:], s % cache_size, axis=1)
    pad = [(0, 0), (0, cache_size - s)] + [(0, 0)] * (a.ndim - 2)
    return jnp.pad(a, pad)


def _kv_to_cache(cfg, k, v, cache_size: int):
    """Pack post-RoPE (B, S, nk, dh) K/V into a decode cache (ring layout
    for sliding-window archs; int8 + scales when cfg.kv_quant)."""
    if cfg.kv_quant:
        qk, sk = attn.quantize_kv(k)
        qv, sv = attn.quantize_kv(v)
        return {"k": _roll_pad(qk, cache_size), "v": _roll_pad(qv, cache_size),
                "k_scale": _roll_pad(sk, cache_size),
                "v_scale": _roll_pad(sv, cache_size)}
    return {"k": _roll_pad(k, cache_size), "v": _roll_pad(v, cache_size)}


def forward_with_caches(params, cfg, tokens, cache_size: int, *, img=None,
                        mesh=None):
    """Prefill: returns (logits (B, S, V), decode caches with len = S)."""
    cdt = dtype_of(cfg.compute_dtype)
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens, cdt)
    if cfg.family == "vlm" and img is not None:
        x = jnp.concatenate([img.astype(cdt), x[:, cfg.n_patches:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = constrain(x, mesh, _batch_axes(mesh) if mesh else None)
    slen = jnp.asarray(s, jnp.int32)

    if cfg.family in ("dense", "moe", "vlm"):
        size = cache_size if cfg.sliding_window is None \
            else min(cache_size, cfg.sliding_window)

        def body(xc, pl):
            h = apply_norm(pl["ln1"], xc, cfg.norm)
            h, (k, v) = attn.attention_train(pl["attn"], cfg, h, positions,
                                             return_kv=True)
            xc = xc + h
            h = apply_norm(pl["ln2"], xc, cfg.norm)
            if cfg.family == "moe":
                h = _moe_apply(pl["mlp"], cfg, h, mesh)
            else:
                h = apply_mlp(pl["mlp"], h, cfg.act)
            return xc + h, _kv_to_cache(cfg, k.astype(cdt), v.astype(cdt), size)

        x, kv = jax.lax.scan(body, x, params["blocks"])
        caches = {"kv": kv, "len": slen, "offset": jnp.zeros((), jnp.int32)}
    elif cfg.family == "ssm":
        def body(xc, pl):
            out, st = rwkv_mod.rwkv_block_forward(pl, cfg, xc)
            return out, st
        x, st = jax.lax.scan(body, x, params["blocks"])
        caches = {"rwkv": st, "len": slen}
    elif cfg.family == "hybrid":
        x, caches = _hybrid_prefill(params, cfg, x, positions, cache_size, mesh)
        caches["len"] = slen
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embed"], x), caches


def _hybrid_prefill(params, cfg, x, positions, cache_size, mesh):
    ae = cfg.attn_every
    n_groups = cfg.n_layers // ae
    blocks = jax.tree.map(
        lambda a: a.reshape((n_groups, ae) + a.shape[1:]), params["blocks"])
    norms = jax.tree.map(
        lambda a: a.reshape((n_groups, ae) + a.shape[1:]), params["blocks_norm"])
    shared = params["shared"]
    cdt = x.dtype

    def mamba_body(xc, pl_and_norm):
        pl, nl = pl_and_norm
        h = apply_norm(nl, xc, cfg.norm)
        out, st = ssm_mod.mamba2_forward(pl, cfg, h, return_state=True)
        return xc + out, st

    def group(xc, grp):
        gp, gn = grp
        xc, st = jax.lax.scan(mamba_body, xc, (gp, gn))
        h = apply_norm(params["shared_norm"], xc, cfg.norm)
        h, (k, v) = attn.attention_train(shared["attn"], cfg, h, positions,
                                         return_kv=True)
        xc = xc + h
        h = apply_norm(shared["ln2"], xc, cfg.norm)
        xc = xc + apply_mlp(shared["mlp"], h, cfg.act)
        kc, vc = _kv_to_cache(cfg, k.astype(cdt), v.astype(cdt), cache_size)
        return xc, (st, {"k": kc, "v": vc})

    x, (mamba, kv) = jax.lax.scan(group, x, (blocks, norms))
    mamba = jax.tree.map(lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), mamba)
    return x, {"mamba": mamba, "kv": kv, "offset": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# decode (one token, stacked caches)
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, seq: int):
    """Stacked per-layer decode caches for the given cache length."""
    cdt = dtype_of(cfg.compute_dtype)
    l = cfg.n_layers

    def stackd(make, n):
        one = make()
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one)

    if cfg.family in ("dense", "moe", "vlm"):
        kv = stackd(lambda: {k: v for k, v in attn.init_kv_cache(cfg, batch, seq, cdt).items()
                             if k not in ("len", "offset")}, l)
        return {"kv": kv, "len": jnp.zeros((), jnp.int32),
                "offset": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        st = stackd(lambda: rwkv_mod.init_rwkv_state(cfg, batch, cdt), l)
        return {"rwkv": st, "len": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        mamba = stackd(lambda: ssm_mod.init_mamba2_state(cfg, batch, cdt), l)
        kv = stackd(lambda: {k: v for k, v in attn.init_kv_cache(cfg, batch, seq, cdt).items()
                             if k in ("k", "v")}, n_groups)
        return {"mamba": mamba, "kv": kv, "len": jnp.zeros((), jnp.int32),
                "offset": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.family)


def decode_step(params, cfg, tokens, caches, *, mesh=None):
    """tokens (B, 1) -> (logits (B, 1, V), new caches)."""
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt)
    x = constrain(x, mesh, _batch_axes(mesh) if mesh else None)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(xc, inp):
            pl, kv = inp
            cache = {**kv, "len": caches["len"], "offset": caches["offset"]}
            h = apply_norm(pl["ln1"], xc, cfg.norm)
            h, nc = attn.attention_decode(pl["attn"], cfg, h, cache)
            xc = xc + h
            h = apply_norm(pl["ln2"], xc, cfg.norm)
            if cfg.family == "moe":
                h = _moe_apply(pl["mlp"], cfg, h, mesh)
            else:
                h = apply_mlp(pl["mlp"], h, cfg.act)
            return xc + h, {k2: nc[k2] for k2 in kv}

        x, new_kv = jax.lax.scan(body, x, (params["blocks"], caches["kv"]))
        new = {"kv": new_kv, "len": caches["len"] + 1, "offset": caches["offset"]}
    elif cfg.family == "ssm":
        def body(xc, inp):
            pl, st = inp
            out, ns = rwkv_mod.rwkv_block_forward(pl, cfg, xc, state=st)
            return out, ns
        x, new_st = jax.lax.scan(body, x, (params["blocks"], caches["rwkv"]))
        new = {"rwkv": new_st, "len": caches["len"] + 1}
    elif cfg.family == "hybrid":
        x, new = _hybrid_decode(params, cfg, x, caches)
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embed"], x), new


def _hybrid_decode(params, cfg, x, caches):
    ae = cfg.attn_every
    n_groups = cfg.n_layers // ae
    blocks = jax.tree.map(
        lambda a: a.reshape((n_groups, ae) + a.shape[1:]), params["blocks"])
    norms = jax.tree.map(
        lambda a: a.reshape((n_groups, ae) + a.shape[1:]), params["blocks_norm"])
    mamba = jax.tree.map(
        lambda a: a.reshape((n_groups, ae) + a.shape[1:]), caches["mamba"])
    shared = params["shared"]

    def mamba_body(xc, inp):
        pl, nl, st = inp
        h = apply_norm(nl, xc, cfg.norm)
        out, ns = ssm_mod.mamba2_decode(pl, cfg, h, st)
        return xc + out, ns

    def group(carry, inp):
        xc = carry
        gp, gn, gst, kv = inp
        xc, new_st = jax.lax.scan(mamba_body, xc, (gp, gn, gst))
        cache = {"k": kv["k"], "v": kv["v"], "len": caches["len"],
                 "offset": caches["offset"]}
        h = apply_norm(params["shared_norm"], xc, cfg.norm)
        h, nc = attn.attention_decode(shared["attn"], cfg, h, cache)
        xc = xc + h
        h = apply_norm(shared["ln2"], xc, cfg.norm)
        xc = xc + apply_mlp(shared["mlp"], h, cfg.act)
        return xc, (new_st, {"k": nc["k"], "v": nc["v"]})

    x, (new_mamba, new_kv) = jax.lax.scan(group, x, (blocks, norms, mamba, caches["kv"]))
    new_mamba = jax.tree.map(
        lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_mamba)
    return x, {"mamba": new_mamba, "kv": new_kv, "len": caches["len"] + 1,
               "offset": caches["offset"]}
