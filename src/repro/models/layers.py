"""Shared model primitives: norms, MLPs, embeddings, RoPE, init helpers.

Parameters are plain dict pytrees; every init function returns
``(params, specs)`` where ``specs`` is a matching pytree of logical-axis
tuples consumed by ``dist/sharding.py`` (MaxText-style logical sharding).
Logical axes used: ``embed`` (d_model), ``heads`` (fused head*dh), ``kv``,
``mlp`` (d_ff), ``vocab``, ``expert``, ``layers`` (scan axis), ``none``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None, axes=None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    w = jax.random.normal(key, shape, jnp.float32) * scale
    return w.astype(dtype)


def stack_layers(inits: list):
    """Stack per-layer param pytrees along a leading ``layers`` axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *inits)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    s = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
        s["bias"] = ("embed",)
    return p, s


def apply_norm(p, x, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        out = xf * inv * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_norm_vec(x, scale, eps: float = 1e-6):
    """RMS-norm over the last axis with a learned scale (qk-norm etc.)."""
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------

def init_mlp(cfg, key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "silu":  # gated
        p = {"w_gate": dense_init(k1, (d, f), dtype),
             "w_up": dense_init(k2, (d, f), dtype),
             "w_down": dense_init(k3, (f, d), dtype)}
        s = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
             "w_down": ("mlp", "embed")}
    else:
        p = {"w_up": dense_init(k1, (d, f), dtype),
             "w_down": dense_init(k2, (f, d), dtype)}
        s = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    return p, s


def apply_mlp(p, x, act: str):
    if "w_gate" in p:
        g = jax.nn.silu(x @ p["w_gate"])
        h = g * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# embeddings / LM head
# ---------------------------------------------------------------------------

def init_embed(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    p = {"embedding": dense_init(k1, (cfg.vocab, cfg.d_model), dtype, scale=0.02)}
    s = {"embedding": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, cfg.vocab), dtype)
        s["lm_head"] = ("embed", "vocab")
    return p, s


def embed_tokens(p, tokens, compute_dtype):
    return jnp.take(p["embedding"], tokens, axis=0).astype(compute_dtype)


def lm_logits(p, x):
    w = p.get("lm_head")
    if w is None:
        w = p["embedding"].T
    return (x @ w).astype(jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x (..., S, H, Dh), positions (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                                  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                               # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
