"""GQA attention: flash-style chunked training path + cached decode path.

* ``attention_train``: online-softmax over KV chunks (lax.scan), so the
  (S × S) score matrix never materializes — activation memory is
  O(S · chunk). With ``sliding_window`` set, each query chunk attends only a
  dynamic-sliced KV window of size (W + chunk): compute drops from O(S²) to
  O(S · W) (this is what makes mixtral's SWA genuinely sub-quadratic here).
* ``attention_decode``: one query token against a cache, scanned over cache
  chunks with online softmax; sliding-window caches are ring buffers of size
  W (keys stored post-RoPE at absolute positions).
* Cross-attention (whisper decoder) reuses the same chunked machinery
  without the causal mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm_vec

NEG_INF = -1e30


def init_attention(cfg, key, dtype, *, cross: bool = False):
    d, nh, nk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, nh * dh), dtype),
         "wk": dense_init(ks[1], (d, nk * dh), dtype),
         "wv": dense_init(ks[2], (d, nk * dh), dtype),
         "wo": dense_init(ks[3], (nh * dh, d), dtype)}
    s = {"wq": ("embed", "heads"), "wk": ("embed", "kv"),
         "wv": ("embed", "kv"), "wo": ("heads", "embed")}
    if cfg.qkv_bias and not cross:
        p.update(bq=jnp.zeros((nh * dh,), dtype), bk=jnp.zeros((nk * dh,), dtype),
                 bv=jnp.zeros((nk * dh,), dtype))
        s.update(bq=("heads",), bk=("kv",), bv=("kv",))
    if cfg.qk_norm and not cross:
        p.update(q_norm=jnp.ones((dh,), dtype), k_norm=jnp.ones((dh,), dtype))
        s.update(q_norm=("none",), k_norm=("none",))
    return p, s


def _qkv(p, cfg, xq, xkv, positions_q, positions_kv, *, rope: bool = True):
    b, sq, _ = xq.shape
    skv = xkv.shape[1]
    nh, nk, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, sq, nh, dh)
    k = k.reshape(b, skv, nk, dh)
    v = v.reshape(b, skv, nk, dh)
    if "q_norm" in p:
        q = rms_norm_vec(q, p["q_norm"])
        k = rms_norm_vec(k, p["k_norm"])
    if rope:
        q = apply_rope(q, positions_q, cfg.rope_theta)
        k = apply_rope(k, positions_kv, cfg.rope_theta)
    return q, k, v


def _sdp_chunk(qc, kc, vc, mask, scale):
    """One (q-chunk, kv-chunk) online-softmax step.

    qc (B, Cq, nk, g, dh), kc (B, Ck, nk, dh), vc (B, Ck, nk, dh),
    mask (Cq, Ck) bool (True = attend). Returns (scores_max, exp_sum,
    weighted_v) contributions.
    """
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qc.astype(jnp.float32),
                        kc.astype(jnp.float32)) * scale
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                                   # (B,k,g,Cq)
    e = jnp.exp(logits - m[..., None])
    e = jnp.where(mask[None, None, None], e, 0.0)
    l = jnp.sum(e, axis=-1)
    wv = jnp.einsum("bkgqs,bskd->bkgqd", e, vc.astype(jnp.float32))
    return m, l, wv


def _merge(carry, new):
    m0, l0, a0 = carry
    m1, l1, a1 = new
    m = jnp.maximum(m0, m1)
    c0 = jnp.exp(m0 - m)
    c1 = jnp.exp(m1 - m)
    return m, l0 * c0 + l1 * c1, a0 * c0[..., None] + a1 * c1[..., None]


def attention_train(p, cfg, x, positions, *, xkv=None, causal=True,
                    return_kv: bool = False):
    """Full training/prefill attention. x (B, S, d) -> (B, S, d).
    With ``return_kv``, also returns the post-RoPE (k, v) for cache prefill.
    """
    b, s, d = x.shape
    nh, nk, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    g = nh // nk
    cq = min(cfg.attn_chunk, s)
    assert s % cq == 0, (s, cq)
    nq = s // cq
    cross = xkv is not None
    kv_src = xkv if cross else x
    skv = kv_src.shape[1]
    pos_kv = positions if not cross else jnp.zeros(kv_src.shape[:2], jnp.int32)
    q, k, v = _qkv(p, cfg, x, kv_src, positions, pos_kv, rope=not cross)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q = q.reshape(b, nq, cq, nk, g, dh)

    window = cfg.sliding_window if (causal and not cross) else None
    if window is not None and s > window:
        # --- sub-quadratic sliding-window path: O(S · W) ---
        w = window
        cw = w + cq                                    # static KV slice size

        def q_chunk(qi, qc):
            start = jnp.maximum(qi * cq - w, 0)
            start = jnp.minimum(start, skv - cw) if skv >= cw else 0
            kc = jax.lax.dynamic_slice_in_dim(k, start, min(cw, skv), axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, min(cw, skv), axis=1)
            qpos = qi * cq + jnp.arange(cq)
            kpos = start + jnp.arange(min(cw, skv))
            mask = (kpos[None, :] <= qpos[:, None]) & \
                   (kpos[None, :] > qpos[:, None] - w)
            m, l, wv = _sdp_chunk(qc, kc, vc, mask, scale)
            return l, wv

        l, wv = jax.vmap(q_chunk, in_axes=(0, 1), out_axes=(0, 0))(
            jnp.arange(nq), q)
        # vmap puts nq first: (nq, B, k, g, Cq[, dh])
        out = wv / jnp.maximum(l[..., None], 1e-30)
        out = jnp.moveaxis(out, 0, 1)                  # (B, nq, k, g, Cq, dh)
        out = out.transpose(0, 1, 4, 2, 3, 5)          # (B, nq, Cq, k, g, dh)
    else:
        # --- chunked full/causal attention (online softmax over KV) ---
        ck = cq if not cross else min(cfg.attn_chunk, skv)
        nkc = skv // ck
        ks = k.reshape(b, nkc, ck, nk, dh)
        vs = v.reshape(b, nkc, ck, nk, dh)

        def q_chunk(qi, qc):
            def kv_step(carry, inp):
                kj, kc, vc = inp
                if causal and not cross:
                    qpos = qi * cq + jnp.arange(cq)
                    kpos = kj * ck + jnp.arange(ck)
                    mask = kpos[None, :] <= qpos[:, None]
                else:
                    mask = jnp.ones((cq, ck), bool)
                new = _sdp_chunk(qc, kc, vc, mask, scale)
                return _merge(carry, new), None

            init = (jnp.full((b, nk, g, cq), NEG_INF, jnp.float32),
                    jnp.zeros((b, nk, g, cq), jnp.float32),
                    jnp.zeros((b, nk, g, cq, dh), jnp.float32))
            (m, l, acc), _ = jax.lax.scan(
                kv_step, init,
                (jnp.arange(nkc), jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0)))
            return acc / jnp.maximum(l[..., None], 1e-30)

        out = jax.vmap(q_chunk, in_axes=(0, 1), out_axes=0)(jnp.arange(nq), q)
        out = jnp.moveaxis(out, 0, 1)                  # (B, nq, k, g, Cq, dh)
        out = out.transpose(0, 1, 4, 2, 3, 5)
    out = out.reshape(b, s, nh * dh).astype(x.dtype)
    if return_kv:
        return out @ p["wo"], (k, v)
    return out @ p["wo"]


def attention_decode(p, cfg, x, cache, *, xkv_cache_only: bool = False):
    """One-token decode. x (B, 1, d); cache dict with k/v (B, Sc, nk, dh),
    ``len`` scalar int32 (tokens already in cache), ``offset`` (absolute
    position of slot 0 — ring buffers advance it). Returns (out, new_cache).
    """
    b, _, d = x.shape
    nh, nk, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    g = nh // nk
    sc = cache["k"].shape[1]
    quant = "k_scale" in cache
    pos = cache["offset"] + cache["len"]                # absolute position
    pos_b = pos * jnp.ones((b, 1), jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, x, pos_b, pos_b, rope=not xkv_cache_only)
    kscale = vscale = None
    if xkv_cache_only:                                  # cross-attn: static memory
        k, v, valid_len = cache["k"], cache["v"], cache["len"]
    else:
        if cfg.sliding_window is not None:
            slot = cache["len"] % sc                   # ring buffer
        else:
            slot = cache["len"]

        def dus(buf, upd):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, upd.astype(buf.dtype), slot, axis=1)

        if quant:
            qk, sk = quantize_kv(k_new)                # (b,1,nk,dh)/(b,1,nk)
            qv, sv = quantize_kv(v_new)
            k, v = dus(cache["k"], qk), dus(cache["v"], qv)
            kscale, vscale = dus(cache["k_scale"], sk), dus(cache["v_scale"], sv)
        else:
            k, v = dus(cache["k"], k_new), dus(cache["v"], v_new)
        valid_len = jnp.minimum(cache["len"] + 1, sc)

    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q1 = q.reshape(b, 1, nk, g, dh)                    # Cq = 1
    # big caches: single pass, so a sequence-sharded cache reduces via SPMD
    # (flash-decoding: per-shard partial max/sum + all-reduce combine)
    ck = sc if sc >= 16384 else min(4096, sc)
    nck = sc // ck

    def chunks(a):
        return jnp.moveaxis(a.reshape((b, nck, ck) + a.shape[2:]), 1, 0)

    def kv_step(carry, inp):
        if quant:
            kj, kc_q, vc_q, ksc, vsc = inp
            kc = dequantize_kv(kc_q, ksc)
            vc = dequantize_kv(vc_q, vsc)
        else:
            kj, kc, vc = inp
        idx = kj * ck + jnp.arange(ck)
        mask = (idx < valid_len)[None, :]
        m, l, wv = _sdp_chunk(q1, kc, vc, mask, scale)
        return _merge(carry, (m, l, wv)), None

    init = (jnp.full((b, nk, g, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, nk, g, 1), jnp.float32),
            jnp.zeros((b, nk, g, 1, dh), jnp.float32))
    if quant:
        xs = (jnp.arange(nck), chunks(k), chunks(v), chunks(kscale),
              chunks(vscale))
    else:
        xs = (jnp.arange(nck), chunks(k), chunks(v))
    if nck == 1:
        (m, l, acc), _ = kv_step(init, jax.tree.map(lambda a: a[0], xs))
    else:
        (m, l, acc), _ = jax.lax.scan(kv_step, init, xs)
    out = (acc / jnp.maximum(l[..., None], 1e-30))     # (B, nk, g, 1, dh)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, nh * dh).astype(x.dtype)
    new_cache = dict(cache)
    if not xkv_cache_only:
        # ``len`` counts all tokens ever seen (ring slots wrap via len % sc);
        # ``offset`` stays 0 — absolute positions are offset + len.
        new_cache.update(k=k, v=v, len=cache["len"] + 1)
        if quant:
            new_cache.update(k_scale=kscale, v_scale=vscale)
    return out @ p["wo"], new_cache


def init_kv_cache(cfg, batch: int, seq: int, dtype):
    """Cache sized ``seq`` (sliding-window archs: min(seq, W) ring).

    With ``cfg.kv_quant`` the K/V payload is int8 with per-(token, head)
    absmax scales (KIVI-style, per-token post-RoPE) — halves decode HBM
    traffic and cache residency vs bf16.
    """
    size = seq if cfg.sliding_window is None else min(seq, cfg.sliding_window)
    shape = (batch, size, cfg.n_kv, cfg.d_head)
    cache = {"len": jnp.zeros((), jnp.int32), "offset": jnp.zeros((), jnp.int32)}
    if cfg.kv_quant:
        cache.update(k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
                     k_scale=jnp.zeros(shape[:3], jnp.bfloat16),
                     v_scale=jnp.zeros(shape[:3], jnp.bfloat16))
    else:
        cache.update(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))
    return cache


def quantize_kv(x):
    """(… , dh) -> int8 payload + per-(…) absmax scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
