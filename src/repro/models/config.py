"""Architecture configuration dataclass shared by all model families."""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int | None = None           # default d_model // n_heads

    # attention details
    qk_norm: bool = False               # qwen3
    qkv_bias: bool = False              # qwen1.5
    sliding_window: int | None = None   # mixtral
    rope_theta: float = 10_000.0
    attn_chunk: int = 512               # q-chunk for flash-style attention

    # norms / activation
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["silu", "gelu"] = "silu"

    # MoE
    n_experts: int = 0
    top_k: int = 2
    moe_sharding: Literal["tp", "ep"] = "tp"
    capacity_factor: float = 1.25

    # SSM (mamba2) / hybrid
    ssm_state: int = 64
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0                 # zamba2: shared attn block cadence
    ssm_chunk: int = 128                # SSD chunk length

    # RWKV6
    rwkv_headdim: int = 64
    rwkv_chunk: int = 64                # remat-scan chunk

    # encoder-decoder (whisper)
    enc_layers: int = 0
    dec_layers: int = 0

    # modality frontend stubs
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    n_patches: int = 576                # vision stub: patch embeddings prepended

    # dtypes / training / serving
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    kv_quant: bool = False      # int8 KV cache (per-token-per-head absmax)
    tie_embeddings: bool = False
    remat: Literal["none", "block", "full"] = "block"
    max_seq: int = 524_288

    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the long_500k shape? (see DESIGN.md)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6·N·D."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        nh, nk, dh = self.n_heads, self.n_kv, self.d_head
        attn = d * (nh * dh) + 2 * d * (nk * dh) + (nh * dh) * d
        mlp_dense = 3 * d * f if self.act == "silu" else 2 * d * f
        if self.family == "moe":
            mlp = self.n_experts * mlp_dense
        else:
            mlp = mlp_dense
        if self.family == "ssm":                      # rwkv6
            blk = 2 * d * d * 2 + 2 * d * f           # timemix + channelmix approx
            return v * d * 2 + self.n_layers * blk
        if self.family == "hybrid":                   # zamba2
            d_in = self.ssm_expand * d
            mamba = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            return v * d * 2 + self.n_layers * (mamba + mlp_dense // 3)
        n = v * d * (1 if self.tie_embeddings else 2)
        layers = self.enc_layers + self.dec_layers if self.family == "encdec" \
            else self.n_layers
        cross = attn if self.family == "encdec" else 0
        return n + layers * (attn + mlp + cross)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = (3 if self.act == "silu" else 2) * d * f
        full = self.param_count()
        mlp_all = self.n_layers * self.n_experts * per_expert
        mlp_act = self.n_layers * self.top_k * per_expert
        return full - mlp_all + mlp_act
