"""RWKV6 ("Finch") block: data-dependent per-channel decay linear attention.

Time-mix recurrence per head (K = V = rwkv_headdim):

    S_t = diag(w_t) · S_{t-1} + kᵀ_t v_t
    y_t = r_t · (S_{t-1} + diag(u) kᵀ_t v_t)

with w_t = exp(-exp(w0 + lora(x_t))) ∈ (0,1) per channel — the
data-dependent decay that distinguishes RWKV6 from RWKV5/RetNet.

Training/prefill runs the recurrence as a **chunk-rematerialized scan**:
an outer scan over chunks of ``cfg.rwkv_chunk`` steps is wrapped in
``jax.checkpoint``, so backward memory is O(S/chunk · state) instead of
O(S · state); each inner step is a batched rank-1 state update (VPU/MXU
einsums). A fully chunk-parallel GLA-style formulation is the obvious next
kernel (see EXPERIMENTS.md §Perf notes) but is numerically delicate for
per-channel decay; correctness wins here.

Channel-mix is the standard RWKV squared-ReLU FFN with token shift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def n_heads_of(cfg) -> int:
    return cfg.d_model // cfg.rwkv_headdim


def init_rwkv_block(cfg, key, dtype):
    d, f = cfg.d_model, cfg.d_ff
    lora = 64
    ks = jax.random.split(key, 10)
    p = {
        # time-mix
        "mu": 0.5 * jnp.ones((5, d), dtype),               # r,k,v,w,g shift mix
        "wr": dense_init(ks[0], (d, d), dtype),
        "wk": dense_init(ks[1], (d, d), dtype),
        "wv": dense_init(ks[2], (d, d), dtype),
        "wg": dense_init(ks[3], (d, d), dtype),
        "wo": dense_init(ks[4], (d, d), dtype),
        "w0": jnp.full((d,), -2.0, jnp.float32),           # decay bias
        "w_lora_a": dense_init(ks[5], (d, lora), dtype),
        "w_lora_b": dense_init(ks[6], (lora, d), dtype, scale=0.01),
        "u": jnp.zeros((d,), jnp.float32),                 # bonus
        "ln_scale": jnp.ones((d,), dtype),                 # per-head groupnorm
        # channel-mix
        "mu_c": 0.5 * jnp.ones((2, d), dtype),
        "ck": dense_init(ks[7], (d, f), dtype),
        "cv": dense_init(ks[8], (f, d), dtype),
        "cr": dense_init(ks[9], (d, d), dtype),
    }
    s = {"mu": ("none", "embed"), "wr": ("embed", "heads"), "wk": ("embed", "heads"),
         "wv": ("embed", "heads"), "wg": ("embed", "heads"), "wo": ("heads", "embed"),
         "w0": ("embed",), "w_lora_a": ("embed", "none"), "w_lora_b": ("none", "embed"),
         "u": ("embed",), "ln_scale": ("embed",),
         "mu_c": ("none", "embed"), "ck": ("embed", "mlp"), "cv": ("mlp", "embed"),
         "cr": ("embed", "heads")}
    return p, s


def _token_shift(x, prev):
    """prev: (B, 1, d) last token of previous segment (zeros at start)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _head_groupnorm(y, scale, n_heads, eps=1e-5):
    b, s, d = y.shape
    yh = y.reshape(b, s, n_heads, d // n_heads).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = ((yh - mu) ** 2).mean(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(b, s, d) * scale.astype(jnp.float32)).astype(y.dtype)


def _wkv_scan(r, k, v, w, u, state, chunk: int):
    """Chunk-rematerialized WKV recurrence.

    r,k,v,w: (B, S, H, K) f32 (w = decay in (0,1)); u (H, K);
    state (B, H, K, K). Returns (y (B,S,H,K), final state).
    """
    b, s, h, kd = r.shape
    cs = min(chunk, s)
    q = s // cs

    def step(st, inp):
        rt, kt, vt, wt = inp                              # (B,H,K)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, st + u[None, :, :, None] * kv)
        st = wt[..., None] * st + kv
        return st, y

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_fn(st, xs):
        return jax.lax.scan(step, st, xs)

    def to_chunks(x):                                      # (B,S,H,K)->(Q,Cs,B,H,K)
        return jnp.moveaxis(x, 1, 0).reshape(q, cs, b, h, kd)

    xs = tuple(map(to_chunks, (r, k, v, w)))

    def outer(st, xc):
        st, y = chunk_fn(st, xc)
        return st, y

    state, ys = jax.lax.scan(outer, state, xs)             # ys (Q,Cs,B,H,K)
    y = jnp.moveaxis(ys.reshape(s, b, h, kd), 0, 1)
    return y, state


def rwkv_block_forward(p, cfg, x, state=None):
    """x (B, S, d) -> (B, S, d). ``state`` carries (shift_t, shift_c, wkv)
    across segments; None for training from scratch."""
    b, s, d = x.shape
    h = n_heads_of(cfg)
    kd = cfg.rwkv_headdim
    if state is None:
        state = init_rwkv_state(cfg, b, x.dtype)

    # ---- time mix ----
    x_in = x
    prev = state["shift_t"]
    xs_ = _token_shift(x, prev)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mu[i][None, None] * (xs_ - x) for i in range(5))
    r = (xr @ p["wr"]).reshape(b, s, h, kd).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, s, h, kd).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(b, s, h, kd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    wlog = p["w0"][None, None] + (jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.clip(wlog, -8.0, 1.0))).reshape(b, s, h, kd)
    u = p["u"].astype(jnp.float32).reshape(h, kd)
    y, wkv = _wkv_scan(r, k, v, w, u, state["wkv"], cfg.rwkv_chunk)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = _head_groupnorm(y, p["ln_scale"], h) * g
    out_t = y @ p["wo"]
    x = x + out_t

    # ---- channel mix ----
    prev_c = state["shift_c"]
    xsc = _token_shift(x, prev_c)
    mu_c = p["mu_c"].astype(x.dtype)
    xk_c = x + mu_c[0][None, None] * (xsc - x)
    xr_c = x + mu_c[1][None, None] * (xsc - x)
    kc = jnp.square(jax.nn.relu(xk_c @ p["ck"]))
    out_c = jax.nn.sigmoid(xr_c @ p["cr"]) * (kc @ p["cv"])
    new_state = {"shift_t": x_in[:, -1:],   # last token of time-mix input
                 "shift_c": x[:, -1:],      # last token of channel-mix input
                 "wkv": wkv}
    return x + out_c, new_state


def init_rwkv_state(cfg, batch: int, dtype):
    h = n_heads_of(cfg)
    kd = cfg.rwkv_headdim
    return {"shift_t": jnp.zeros((batch, 1, cfg.d_model), dtype),
            "shift_c": jnp.zeros((batch, 1, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, h, kd, kd), jnp.float32)}
