"""Architecture registry: ``--arch <id>`` -> config + model functions +
per-shape input specs (ShapeDtypeStruct stand-ins, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, transformer
from repro.models.config import ArchConfig

ARCHS: dict[str, str] = {
    "smollm-360m": "smollm_360m",
    "stablelm-3b": "stablelm_3b",
    "qwen3-4b": "qwen3_4b",
    "qwen1.5-32b": "qwen15_32b",
    "mixtral-8x22b": "mixtral_8x22b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "zamba2-2.7b": "zamba2_27b",
    "whisper-base": "whisper_base",
    "phi-3-vision-4.2b": "phi3_vision",
    "rwkv6-3b": "rwkv6_3b",
}

SHAPES: dict[str, dict[str, int]] = {
    "train_4k":    {"seq": 4096,    "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768,   "batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq": 32768,   "batch": 128, "kind": "decode"},
    "long_500k":   {"seq": 524288,  "batch": 1,   "kind": "decode"},
}


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)


def cell_supported(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """Is (arch × shape) a runnable cell? (False, reason) if skipped."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention (full-attn arch)"
    return True, ""


def is_encdec(cfg: ArchConfig) -> bool:
    return cfg.family == "encdec"


def init_params(cfg: ArchConfig, key):
    if is_encdec(cfg):
        return encdec.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def forward(params, cfg: ArchConfig, batch: dict, *, mesh=None):
    if is_encdec(cfg):
        return encdec.forward(params, cfg, batch["tokens"], batch["frames"],
                              mesh=mesh)
    return transformer.forward(params, cfg, batch["tokens"],
                               img=batch.get("img"), mesh=mesh)


def decode_step(params, cfg: ArchConfig, batch: dict, caches, *, mesh=None):
    if is_encdec(cfg):
        return encdec.decode_step(params, cfg, batch["tokens"], caches, mesh=mesh)
    return transformer.decode_step(params, cfg, batch["tokens"], caches, mesh=mesh)


def init_caches(cfg: ArchConfig, batch: int, seq: int):
    if is_encdec(cfg):
        return encdec.init_decode_caches(cfg, batch, seq, enc_len=seq)
    return transformer.init_caches(cfg, batch, seq)


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sh = SHAPES[shape_name]
    b, s, kind = sh["batch"], sh["seq"], sh["kind"]
    if kind in ("train", "prefill"):
        specs = {"tokens": _sds((b, s), jnp.int32)}
        if kind == "train":
            specs["labels"] = _sds((b, s), jnp.int32)
        if cfg.family == "vlm":
            specs["img"] = _sds((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if is_encdec(cfg):
            specs["frames"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one token + caches of length s
    specs = {"tokens": _sds((b, 1), jnp.int32)}
    caches = jax.eval_shape(lambda: init_caches(cfg, b, s))
    specs["caches"] = caches
    return specs


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    updates = dict(
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=128, n_heads=4, n_kv=max(1, min(4, cfg.n_kv)),
        d_head=32, d_ff=256, vocab=512,
        param_dtype="float32", compute_dtype="float32",
        attn_chunk=64, ssm_chunk=32, rwkv_chunk=16,
        sliding_window=None if cfg.sliding_window is None else 64,
        n_patches=8, ssm_headdim=32, ssm_expand=2, ssm_state=16,
        rwkv_headdim=32, remat="none",
    )
    if cfg.family == "moe":
        updates["n_experts"] = 4
        updates["moe_sharding"] = cfg.moe_sharding
    if cfg.family == "hybrid":
        updates["n_layers"] = 4
        updates["attn_every"] = 2
        updates["n_kv"] = 4
    if cfg.family == "encdec":
        updates["enc_layers"] = 2
        updates["dec_layers"] = 2
        updates["n_layers"] = 2
    if cfg.family == "ssm":
        updates["n_heads"] = 4
        updates["n_kv"] = 4
    return dataclasses.replace(cfg, **updates)
