"""Mamba2 (SSD) block — chunked, matmul-dominant formulation for the MXU.

The SSD algorithm (Dao & Gu, 2024) splits the sequence into chunks of
``cfg.ssm_chunk``: within a chunk the recurrence is computed as a masked
(decay-weighted) attention-like matmul; across chunks a short scan carries
the (H, N, P) state. All heavy ops are einsums over (chunk × chunk) or
(state × headdim) — MXU-shaped, no per-token scan in training/prefill.

Decode is the O(1) recurrent step on the (B, H, N, P) state.
Simplifications vs. the reference CUDA implementation (documented in
DESIGN.md): single B/C group (G=1), no conv state left-pad subtleties beyond
a causal depthwise conv of width ``ssm_conv``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def d_inner_of(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_heads_of(cfg) -> int:
    return d_inner_of(cfg) // cfg.ssm_headdim


def init_mamba2(cfg, key, dtype):
    d = cfg.d_model
    din = d_inner_of(cfg)
    h = n_heads_of(cfg)
    n = cfg.ssm_state
    ks = jax.random.split(key, 5)
    # in_proj packs [z, x, B, C, dt]
    proj_out = 2 * din + 2 * n + h
    p = {
        "in_proj": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, din + 2 * n), dtype, scale=0.5),
        "A_log": jnp.zeros((h,), jnp.float32) + jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[2], (din, d), dtype),
    }
    s = {"in_proj": ("embed", "inner"), "conv_w": ("none", "inner"),
         "A_log": ("none",), "D": ("none",), "dt_bias": ("none",),
         "norm_scale": ("inner",), "out_proj": ("inner", "embed")}
    return p, s


def _split_proj(cfg, zxbcdt):
    din = d_inner_of(cfg)
    n = cfg.ssm_state
    h = n_heads_of(cfg)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:2 * din + 2 * n]
    dt = zxbcdt[..., 2 * din + 2 * n:2 * din + 2 * n + h]
    return z, xbc, dt


def _causal_conv(x, w):
    """Depthwise causal conv. x (B, S, C), w (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out)


def _gated_rmsnorm(x, z, scale, eps=1e-5):
    x = x * jax.nn.silu(z)
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * scale.astype(jnp.float32)).astype(x.dtype)


def _segsum(dA):
    """Stable 'segment sum' matrix: out[..., i, j] = Σ_{j<t<=i} dA[..., t],
    -inf above the diagonal. dA (..., Cs)."""
    cs = dA.shape[-1]
    cum = jnp.cumsum(dA, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]           # (..., i, j)
    i = jnp.arange(cs)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_forward(p, cfg, x, *, return_state: bool = False):
    """Training/prefill. x (B, S, d) -> (B, S, d) (+ final decode state)."""
    b, s, d = x.shape
    din = d_inner_of(cfg)
    h = n_heads_of(cfg)
    n = cfg.ssm_state
    ph = cfg.ssm_headdim
    cs = min(cfg.ssm_chunk, s)
    assert s % cs == 0
    q = s // cs

    zxbcdt = x @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc_raw, p["conv_w"])
    xs = xbc[..., :din]
    bmat = xbc[..., din:din + n]                           # (B, S, N)
    cmat = xbc[..., din + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])                               # (H,)
    da = dt * a[None, None, :]                             # (B,S,H) ≤ 0

    xh = xs.reshape(b, q, cs, h, ph).astype(jnp.float32)
    dtc = dt.reshape(b, q, cs, h)
    dac = da.reshape(b, q, cs, h)
    bc = bmat.reshape(b, q, cs, n).astype(jnp.float32)
    cc = cmat.reshape(b, q, cs, n).astype(jnp.float32)
    xdt = xh * dtc[..., None]                              # input × Δt

    # intra-chunk: y[i] += C_i · ( Σ_{j<=i} exp(Σ_{j<t<=i} dA) B_j x_j dt_j )
    lmat = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))     # (B,Q,H,Cs,Cs)
    scores = jnp.einsum("bqin,bqjn->bqij", cc, bc)         # (B,Q,Cs,Cs)
    y_intra = jnp.einsum("bqhij,bqij,bqjhp->bqihp",
                         lmat, scores, xdt)

    # chunk summary states: S_q = Σ_j exp(Σ_{j<t<=end} dA) B_j ⊗ (x_j dt_j)
    cum = jnp.cumsum(dac, axis=2)                          # (B,Q,Cs,H)
    total = cum[:, :, -1:, :]                              # (B,Q,1,H)
    decay_to_end = jnp.exp(total - cum)                    # (B,Q,Cs,H)
    s_chunk = jnp.einsum("bqjh,bqjn,bqjhp->bqhnp", decay_to_end, bc, xdt)

    # inter-chunk recurrence over Q chunks
    chunk_decay = jnp.exp(total[:, :, 0, :])               # (B,Q,H)

    def step(state, inp):
        dec, s_q = inp                                     # (B,H), (B,H,N,P)
        out_state = state                                  # state BEFORE chunk
        new = state * dec[..., None, None] + s_q
        return new, out_state

    init = jnp.zeros((b, h, n, ph), jnp.float32)
    final_state, states_before = jax.lax.scan(
        step, init, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    states_before = jnp.moveaxis(states_before, 0, 1)      # (B,Q,H,N,P)

    # inter-chunk contribution: y[i] += C_i · state_before · exp(cum_i)
    y_inter = jnp.einsum("bqin,bqih,bqhnp->bqihp", cc, jnp.exp(cum), states_before)

    y = (y_intra + y_inter).reshape(b, s, h, ph)
    y = y + xh.reshape(b, s, h, ph) * p["D"][None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = y @ p["out_proj"]
    if return_state:
        state = {"ssm": final_state,
                 "conv": xbc_raw[:, s - (cfg.ssm_conv - 1):, :]}
        return out, state
    return out


def mamba2_decode(p, cfg, x, state):
    """Single-token step. x (B, 1, d); state dict {ssm (B,H,N,P), conv
    (B, K-1, din+2N)} -> (out (B,1,d), new_state)."""
    b = x.shape[0]
    din = d_inner_of(cfg)
    h = n_heads_of(cfg)
    n = cfg.ssm_state
    ph = cfg.ssm_headdim

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([state["conv"], xbc], axis=1)   # (B, K, C)
    xbc_t = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, p["conv_w"]))[:, None]
    new_conv = conv_in[:, 1:]
    xs = xbc_t[..., :din]
    bmat = xbc_t[..., din:din + n].astype(jnp.float32)     # (B,1,N)
    cmat = xbc_t[..., din + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * a[None])                            # (B,H)
    xh = xs.reshape(b, h, ph).astype(jnp.float32)
    upd = jnp.einsum("bn,bhp->bhnp", bmat[:, 0], xh * dt[..., None])
    new_ssm = state["ssm"] * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0], new_ssm)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, din).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return y @ p["out_proj"], {"ssm": new_ssm, "conv": new_conv}


def init_mamba2_state(cfg, batch: int, dtype):
    return {"ssm": jnp.zeros((batch, n_heads_of(cfg), cfg.ssm_state,
                              cfg.ssm_headdim), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1,
                               d_inner_of(cfg) + 2 * cfg.ssm_state), dtype)}
