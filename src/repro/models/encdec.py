"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, ``[audio]`` entries specify the transformer backbone
only: ``input_specs()`` supplies precomputed frame embeddings (B, S_enc,
d_model) in place of the mel-spectrogram conv stem. Encoder: bidirectional
attention (sinusoidal positions folded into the stub embeddings). Decoder:
causal self-attention + cross-attention to encoder memory, LayerNorm + GELU
as in Whisper.

Serve path: ``encode`` runs once; per-layer cross K/V are precomputed;
``decode_step`` scans decoder layers with a self-attention KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (apply_mlp, apply_norm, dtype_of, embed_tokens,
                                 init_embed, init_mlp, init_norm, lm_logits,
                                 stack_layers)


def _init_enc_block(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    pa, sa = attn.init_attention(cfg, k1, dtype)
    pm, sm = init_mlp(cfg, k2, dtype)
    pn1, sn1 = init_norm(cfg, dtype)
    pn2, sn2 = init_norm(cfg, dtype)
    return ({"attn": pa, "mlp": pm, "ln1": pn1, "ln2": pn2},
            {"attn": sa, "mlp": sm, "ln1": sn1, "ln2": sn2})


def _init_dec_block(cfg, key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = _init_enc_block(cfg, k1, dtype)
    pc, sc = attn.init_attention(cfg, k2, dtype, cross=True)
    pn, sn = init_norm(cfg, dtype)
    p.update(cross=pc, ln_cross=pn)
    s.update(cross=sc, ln_cross=sn)
    return p, s


def init_encdec(cfg, key):
    dtype = dtype_of(cfg.param_dtype)
    ke, k1, k2 = jax.random.split(key, 3)
    pe, se = init_embed(cfg, ke, dtype)
    pn_e, sn_e = init_norm(cfg, dtype)
    pn_d, sn_d = init_norm(cfg, dtype)
    enc = [_init_enc_block(cfg, k, dtype) for k in jax.random.split(k1, cfg.enc_layers)]
    dec = [_init_dec_block(cfg, k, dtype) for k in jax.random.split(k2, cfg.dec_layers)]
    wrap = lambda s0: jax.tree.map(lambda a: ("layers",) + a, s0,
                                   is_leaf=lambda x: isinstance(x, tuple))
    params = {"embed": pe, "enc_norm": pn_e, "final_norm": pn_d,
              "enc_blocks": stack_layers([p for p, _ in enc]),
              "dec_blocks": stack_layers([p for p, _ in dec])}
    specs = {"embed": se, "enc_norm": sn_e, "final_norm": sn_d,
             "enc_blocks": wrap(enc[0][1]), "dec_blocks": wrap(dec[0][1])}
    return params, specs


def encode(params, cfg, frames):
    """frames (B, S_enc, d_model) stub embeddings -> encoder memory."""
    x = frames.astype(dtype_of(cfg.compute_dtype))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(xc, pl):
        h = apply_norm(pl["ln1"], xc, cfg.norm)
        h = attn.attention_train(pl["attn"], cfg, h, positions, causal=False)
        xc = xc + h
        h = apply_norm(pl["ln2"], xc, cfg.norm)
        return xc + apply_mlp(pl["mlp"], h, cfg.act), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg.norm)


def forward(params, cfg, tokens, frames, *, mesh=None):
    """Teacher-forced training forward -> logits (B, S_dec, V)."""
    memory = encode(params, cfg, frames)
    x = embed_tokens(params["embed"], tokens, dtype_of(cfg.compute_dtype))
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(xc, pl):
        h = apply_norm(pl["ln1"], xc, cfg.norm)
        h = attn.attention_train(pl["attn"], cfg, h, positions)
        xc = xc + h
        h = apply_norm(pl["ln_cross"], xc, cfg.norm)
        h = attn.attention_train(pl["cross"], cfg, h, positions, xkv=memory,
                                 causal=False)
        xc = xc + h
        h = apply_norm(pl["ln2"], xc, cfg.norm)
        return xc + apply_mlp(pl["mlp"], h, cfg.act), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embed"], x)


def init_decode_caches(cfg, batch: int, seq: int, enc_len: int):
    cdt = dtype_of(cfg.compute_dtype)
    l = cfg.dec_layers
    kv = {"k": jnp.zeros((l, batch, seq, cfg.n_kv, cfg.d_head), cdt),
          "v": jnp.zeros((l, batch, seq, cfg.n_kv, cfg.d_head), cdt)}
    cross = {"k": jnp.zeros((l, batch, enc_len, cfg.n_kv, cfg.d_head), cdt),
             "v": jnp.zeros((l, batch, enc_len, cfg.n_kv, cfg.d_head), cdt)}
    return {"kv": kv, "cross": cross, "len": jnp.zeros((), jnp.int32),
            "offset": jnp.zeros((), jnp.int32),
            "enc_len": jnp.asarray(enc_len, jnp.int32)}


def precompute_cross_kv(params, cfg, memory):
    """Per-decoder-layer K/V of the encoder memory (computed once)."""
    def one(pl):
        b, s, _ = memory.shape
        k = (memory @ pl["cross"]["wk"]).reshape(b, s, cfg.n_kv, cfg.d_head)
        v = (memory @ pl["cross"]["wv"]).reshape(b, s, cfg.n_kv, cfg.d_head)
        return {"k": k, "v": v}
    return jax.vmap(one)(params["dec_blocks"])


def decode_step(params, cfg, tokens, caches, *, mesh=None):
    """One decoder token against self KV cache + precomputed cross K/V."""
    x = embed_tokens(params["embed"], tokens, dtype_of(cfg.compute_dtype))

    def body(xc, inp):
        pl, kv, cross = inp
        cache = {"k": kv["k"], "v": kv["v"], "len": caches["len"],
                 "offset": caches["offset"]}
        h = apply_norm(pl["ln1"], xc, cfg.norm)
        h, nc = attn.attention_decode(pl["attn"], cfg, h, cache)
        xc = xc + h
        ccache = {"k": cross["k"], "v": cross["v"], "len": caches["enc_len"],
                  "offset": jnp.zeros((), jnp.int32)}
        h = apply_norm(pl["ln_cross"], xc, cfg.norm)
        h, _ = attn.attention_decode(pl["cross"], cfg, h, ccache,
                                     xkv_cache_only=True)
        xc = xc + h
        h = apply_norm(pl["ln2"], xc, cfg.norm)
        return xc + apply_mlp(pl["mlp"], h, cfg.act), {"k": nc["k"], "v": nc["v"]}

    x, new_kv = jax.lax.scan(body, x, (params["dec_blocks"], caches["kv"],
                                       caches["cross"]))
    new = dict(caches)
    new.update(kv=new_kv, len=caches["len"] + 1)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embed"], x), new
