"""Batched serving engine: prefill + greedy/temperature decode loop.

``generate`` — python-loop driver (tests/examples, small models).
``build_serve_step`` — the jitted one-token step used by launch/serve.py and
the decode-shape dry-runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.transformer import forward_with_caches


def build_serve_step(cfg, *, mesh=None):
    def serve_step(params, tokens, caches):
        logits, caches = registry.decode_step(params, cfg, {"tokens": tokens},
                                              caches, mesh=mesh)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches
    return serve_step


def generate(params, cfg, prompt_tokens, *, max_new: int = 32,
             cache_size: int | None = None, img=None, temperature: float = 0.0,
             key=None, mesh=None):
    """prompt_tokens (B, S) -> generated (B, max_new) int32 (greedy by
    default). Uses prefill-with-caches, then the jitted decode step."""
    b, s = prompt_tokens.shape
    cache_size = cache_size or (s + max_new)
    if registry.is_encdec(cfg):
        raise NotImplementedError("use whisper example for enc-dec serving")
    logits, caches = forward_with_caches(params, cfg, prompt_tokens, cache_size,
                                         img=img, mesh=mesh)
    step = jax.jit(build_serve_step(cfg, mesh=mesh))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    for i in range(max_new - 1):
        if temperature > 0.0 and key is not None:
            logits2, caches = registry.decode_step(params, cfg, {"tokens": tok},
                                                   caches, mesh=mesh)
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits2[:, -1] / temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok, caches = step(params, tok, caches)
        out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)
