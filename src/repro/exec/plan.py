"""Query planner: lake size × batch size × mesh × cost model -> QueryPlan.

A :class:`QueryPlan` names one choice per pipeline stage:

=============  =========================  ==============================
stage          choices                    picked by
=============  =========================  ==============================
candidates     all | lsh | hybrid |       mode, or cost model on "auto"
               tiered (coarse digest →
               survivor gather → fine)
score          local | (q × d) grid       mesh availability + lake size
merge          top_k | 2-phase gather     follows the score placement
=============  =========================  ==============================

Sharded plans place work on a 2-D **(query × data) device grid**: the
``grid=(q_shards, d_shards)`` placement dimension shards the query batch
over the ``query`` mesh axis alongside the lake's column axis over
``data``, so each device scores one (Q-shard, C-shard) tile.  The 1-D
plans of earlier revisions are the ``(1, d)`` degenerate grids; the other
degenerate family ``(q, 1)`` replicates the corpus but scales concurrent
batches with the mesh.  ``choose_grid`` picks the factorization from the
batch size, the lake size, and the (query-axis aware) cost model:

* ``q_shards`` never exceeds the padded batch — an idle query shard is
  pure waste;
* for ``d_shards > 1`` the per-device column shard must clear
  ``min_columns_per_shard`` (below that the probe/all_gather overhead
  beats the saving), while ``d_shards == 1`` is always admissible on the
  data side (the corpus is replicated, which is what the 1-D plans
  already did with the *query* batch) — though "auto" mode only goes
  sharded at all when some ``d_shards > 1`` option exists, i.e. when the
  lake itself justifies the mesh;
* among admissible factorizations the cheapest by the cost model wins —
  measured seconds when a calibrated ``cost_fn`` is injected, otherwise
  the analytic flop + HBM + collective-byte composite (flops alone are
  factorization-symmetric: ql·cl is constant at fixed q·d; the HBM term
  penalizes corpus replication, the collective term penalizes wide
  data-axis merges — that tension is the whole placement decision).

Plan selection ("auto" mode) compares the analytic per-stage costs
(``launch.costmodel.discovery_stage_costs`` unless the caller injects a
different hook): a pruned plan pays the bucket probe + profile proxy over
*all* columns to score only ``budget`` of them, so it wins exactly when
``budget`` is small relative to the lake — tiny lakes fall back to the
brute scan, where the probe overhead would exceed the savings.

The planner is deliberately stateless and cheap: the engine calls it per
micro-batch (lake size moves with catalog refreshes), and the chosen plan
is surfaced per query through ``DiscoveryEngine.stats()``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.exec.stages import CANDIDATE_KINDS

MODES = ("auto", "lsh", "full", "sharded", "tiered")

# Padded-batch bucket ladder the continuous-batching runtime snaps formed
# micro-batches to.  Powers of two so every (q_shards, d_shards) mesh
# factorization divides every bucket — the compiled executables and the
# per-bucket grid choices are shared across all batch sizes that snap to
# the same bucket, instead of one compile per odd batch size.  A measured
# ladder (``launch.costmodel.derive_batch_buckets``) replaces this default
# with the exact sizes a ``bench_service --batch-sweep`` run timed.
DEFAULT_BATCH_BUCKETS = (8, 16, 32, 64, 128, 256)

# The same idea on the CORPUS axis: engines taking live ingest snap the
# resident column count to this ladder (padding with sentinel rows the
# exclusion mask scores -inf), so a delta refresh that stays inside its
# bucket changes no traced shape — every AOT executable is reused verbatim
# and steady-state refresh performs zero recompiles.  Powers of two so
# every admissible d_shards divides every bucket and the streamed scorer's
# block path stays aligned; ``launch.costmodel.derive_column_buckets``
# replaces this default with a ladder fit to measured ingest-sweep data.
DEFAULT_COLUMN_BUCKETS = (1024, 2048, 4096, 8192, 16384, 32768,
                          65536, 131072)


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One fully-resolved execution plan for a query micro-batch."""

    candidates: str                 # "all" | "lsh" | "hybrid" | "tiered"
    sharded: bool                   # score per grid tile, 2-phase merge
    budget: int                     # GLOBAL candidate budget (n for "all")
    k: int
    n_shards: int = 1               # data-axis shards (= grid[1])
    grid: tuple = (1, 1)            # (q_shards, d_shards) device grid
    shard_axes: tuple = ("data",)
    survivor_budget: int = 0        # tiered only: coarse-pass gather width C'
    cost: dict = dataclasses.field(default_factory=dict, compare=False)

    def __post_init__(self):
        if self.candidates not in CANDIDATE_KINDS:
            raise ValueError(f"unknown candidate stage {self.candidates!r}")
        g = tuple(int(x) for x in self.grid)
        if len(g) != 2 or g[0] < 1 or g[1] < 1:
            raise ValueError(f"grid must be (q_shards, d_shards) >= (1, 1); "
                             f"got {self.grid!r}")
        if g == (1, 1) and self.n_shards > 1:
            g = (1, int(self.n_shards))     # legacy 1-D construction
        object.__setattr__(self, "grid", g)
        object.__setattr__(self, "n_shards", g[1])

    @property
    def kind(self) -> str:
        """Compact label for stats/benchmarks, e.g. ``sharded-hybrid``."""
        return f"{'sharded' if self.sharded else 'local'}-{self.candidates}"

    @property
    def q_shards(self) -> int:
        """Query-axis shard count of the placement grid."""
        return self.grid[0]

    @property
    def n_grid_devices(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def budget_per_shard(self) -> int:
        """Per-device slice of the global budget (ceil split over the DATA
        axis only — every query shard sees the full per-query budget)."""
        return max(1, -(-self.budget // max(self.n_shards, 1)))


@dataclasses.dataclass
class PlannerConfig:
    k: int = 10
    candidate_frac: float = 0.2     # pruned budget as a fraction of the lake
    max_candidates: int = 4096      # absolute cap on that budget
    n_bands: int = 64
    shard_axes: tuple = ("data",)
    # below this many columns per data shard, column-sharding costs more
    # than it saves (dispatch + all_gather against a trivial local scan);
    # gates d_shards > 1 factorizations (and hence "auto" sharding)
    min_columns_per_shard: int = 64
    # padded-batch bucket ladder (sorted ascending); empty = no snapping,
    # callers pad by their own multiple.  ``snap_batch`` rounds a formed
    # batch up to the smallest bucket that fits so compiled executables
    # and per-bucket grid choices are reused across batch sizes
    batch_buckets: tuple = ()
    # column-count bucket ladder (sorted ascending); empty = no snapping.
    # ``snap_columns`` rounds the resident column count up to the smallest
    # bucket that fits, so ingest deltas that stay inside a bucket keep
    # every traced corpus shape — and hence every AOT executable — stable
    column_buckets: tuple = ()
    # ---- tiered candidate stage knobs ----
    n_coarse_bands: int = 16        # super-band digest width S
    survivor_block: int = 32        # coarse survivor-block granularity
    survivor_frac: float = 0.05     # survivor budget as a fraction of the lake
    min_survivors: int = 512        # survivor budget floor
    # the survivor width is also the GBDT scoring width (tiered plans cap
    # budget at the survivor count), and scoring dominates the per-batch
    # wall once the probes are one fused compare each — measured at 10^5
    # columns, widening 2048 -> 4096 costs ~1.6x QPS for zero recall gain
    # (the digest+proxy fill's recall plateaus by ~2k: 0.912 at both),
    # so the cap is a scoring-width guard, not a recall knob
    max_survivors: int = 2048       # survivor budget cap


class Planner:
    """Resolves (mode, lake, batch, mesh) into a :class:`QueryPlan`.

    ``cost_fn(n_queries, n_columns, budget=..., candidates=..., n_bands=...,
    n_shards=..., q_shards=..., k=...)`` must return a dict with at least
    ``total_flops``; the default is the analytic discovery model in
    ``launch.costmodel``. Injecting a measured model here is the hook the
    ROADMAP's tuning items plug into — with one, grid selection compares
    predicted seconds instead of the analytic composite.
    """

    def __init__(self, config: PlannerConfig | None = None,
                 cost_fn: Callable | None = None):
        self.config = config or PlannerConfig()
        if cost_fn is None:
            from repro.launch.costmodel import discovery_stage_costs
            cost_fn = discovery_stage_costs
        self.cost_fn = cost_fn

    # -- helpers ------------------------------------------------------------

    def candidate_budget(self, n_columns: int) -> int:
        cfg = self.config
        want = max(cfg.k, int(n_columns * cfg.candidate_frac))
        return max(1, min(want, cfg.max_candidates, n_columns))

    def survivor_budget(self, n_columns: int, budget: int) -> int:
        """Coarse-pass gather width C' for a tiered plan: a small fraction
        of the lake (coarse survivors track the truly-similar population,
        not the lake size), floored by ``min_survivors`` so tiny lakes keep
        slack, capped by ``max_survivors`` (the measured point where the
        per-query gathered fine probe stops being cheaper than the shared
        full-lake probe), never beyond the lake, and rounded up to the
        survivor block so gathers stay aligned."""
        cfg = self.config
        want = max(int(n_columns * cfg.survivor_frac), cfg.min_survivors)
        want = min(want, cfg.max_survivors, max(n_columns, 1))
        blk = max(int(cfg.survivor_block), 1)
        return min(max(n_columns, 1), -(-want // blk) * blk)

    def snap_batch(self, n_queries: int) -> int:
        """Padded batch size for ``n_queries``: the smallest configured
        bucket that fits, the next multiple of the top bucket beyond the
        ladder, or ``n_queries`` itself when no ladder is configured."""
        n = max(int(n_queries), 1)
        buckets = tuple(sorted(self.config.batch_buckets))
        if not buckets:
            return n
        for b in buckets:
            if n <= b:
                return int(b)
        top = int(buckets[-1])
        return -(-n // top) * top

    def snap_columns(self, n_columns: int) -> int:
        """Padded corpus size for ``n_columns``: the smallest configured
        column bucket that fits, the next multiple of the top bucket beyond
        the ladder, or ``n_columns`` itself when no ladder is configured.
        The pad rows are inert sentinels (column id -1 → masked to -inf by
        the exclusion stage), bought so an ingest delta that stays inside
        its bucket re-dispatches the same compiled executables."""
        n = max(int(n_columns), 1)
        buckets = tuple(sorted(self.config.column_buckets))
        if not buckets:
            return n
        for b in buckets:
            if n <= b:
                return int(b)
        top = int(buckets[-1])
        return -(-n // top) * top

    def next_column_bucket(self, n_columns: int) -> int | None:
        """The bucket one rung above ``n_columns``'s — what a background
        pre-warm compiles ahead of a bucket-boundary crossing — or None
        when no ladder is configured."""
        if not self.config.column_buckets:
            return None
        cur = self.snap_columns(n_columns)
        return self.snap_columns(cur + 1)

    def _n_shards(self, mesh) -> int:
        """Grid capacity of ``mesh``: the data-shardable devices, times a
        pre-existing ``query`` axis when the caller built one."""
        if mesh is None:
            return 1
        n = 1
        for ax in self.config.shard_axes:
            n *= int(mesh.shape[ax])
        try:
            n *= int(mesh.shape["query"])
        except (KeyError, TypeError):
            pass
        return n

    def _cost(self, candidates: str, n_queries: int, n_columns: int,
              budget: int, n_shards: int, q_shards: int = 1,
              survivor_budget: int = 0) -> dict:
        kw = {}
        if candidates == "tiered":
            # only the tiered stage carries the extra geometry, and only
            # then do we pass it — injected cost_fns predating the tier
            # keep their old signature for every other kind
            kw = dict(survivor_budget=survivor_budget or
                      self.survivor_budget(n_columns, budget),
                      n_coarse_bands=self.config.n_coarse_bands)
        return self.cost_fn(n_queries, n_columns, budget=budget,
                            candidates=candidates, k=self.config.k,
                            n_bands=self.config.n_bands, n_shards=n_shards,
                            q_shards=q_shards, **kw)

    # -- grid placement -----------------------------------------------------

    def grid_options(self, n_devices: int, n_queries: int,
                     n_columns: int) -> list[tuple[int, int]]:
        """Admissible (q_shards, d_shards) factorizations of ``n_devices``.

        Hard constraints: q·d uses every grid device, q never exceeds the
        (padded) batch, and a d > 1 column shard must clear
        ``min_columns_per_shard``. Sorted by q for determinism.
        """
        cfg = self.config
        q_cap = max(int(n_queries), 1)
        out = []
        for q in range(1, n_devices + 1):
            if n_devices % q or q > q_cap:
                continue
            d = n_devices // q
            if d > 1 and -(-n_columns // d) < cfg.min_columns_per_shard:
                continue
            out.append((q, d))
        return out

    def choose_grid(self, n_devices: int, *, n_queries: int, n_columns: int,
                    candidates: str, budget: int) -> tuple[int, int] | None:
        """Cheapest admissible grid by the cost model, or None if no
        factorization is admissible (the caller then stays local, or falls
        back to (1, n_devices) when sharding was explicitly requested)."""
        options = self.grid_options(n_devices, n_queries, n_columns)
        if not options:
            return None

        def key(g):
            q, d = g
            c = self._cost(candidates, n_queries, max(n_columns, 1),
                           max(budget, 1), d, q)
            composite = (c.get("total_flops", 0.0)
                         + c.get("total_hbm_bytes", 0.0)
                         + c.get("total_collective_bytes", 0.0))
            # measured seconds win when a calibrated cost_fn is injected;
            # the analytic composite breaks (near-)ties, then smaller q
            # (the conservative legacy placement)
            return (c.get("total_cost", composite), composite, q)

        return min(options, key=key)

    def _resolve_grid(self, grid, n_devices: int, n_queries: int,
                      n_columns: int, candidates: str,
                      budget: int) -> tuple[int, int]:
        if grid is not None:
            q, d = (int(grid[0]), int(grid[1]))
            if q < 1 or d < 1 or q * d != n_devices:
                raise ValueError(
                    f"grid {grid!r} does not factorize the mesh's "
                    f"{n_devices} grid devices (want q*d == {n_devices})")
            if q > max(n_queries, 1):
                raise ValueError(
                    f"grid {grid!r}: q_shards={q} exceeds the padded batch "
                    f"of {n_queries} — idle query shards are pure waste")
            return (q, d)
        return (self.choose_grid(n_devices, n_queries=n_queries,
                                 n_columns=n_columns, candidates=candidates,
                                 budget=budget)
                or (1, n_devices))

    # -- entry point --------------------------------------------------------

    def plan(self, *, n_columns: int, n_queries: int = 1, mode: str = "auto",
             mesh=None, grid: tuple | None = None) -> QueryPlan:
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; want one of {MODES}")
        cfg = self.config
        n_dev = self._n_shards(mesh)
        budget = self.candidate_budget(n_columns)

        if mode == "sharded":
            if mesh is None:
                raise ValueError("mode='sharded' needs a mesh")
            cand, sharded = "all", True
        elif mode == "full":
            cand, sharded = "all", False
        elif mode == "tiered":
            # coarse digest -> survivor gather -> fine probe; local only
            # (the tier exists to keep one host sublinear in the lake)
            cand, sharded = "tiered", False
        elif mode == "lsh":
            # an explicit mesh is operator intent: shard whenever one exists
            cand, sharded = "hybrid", n_dev > 1
        else:  # auto: cost-based candidate stage, grid-gated sharding
            # shard only when the LAKE justifies it (an admissible d > 1
            # factorization exists — the legacy min-columns-per-shard gate,
            # generalized): a (q, 1) corpus-replicating grid alone must not
            # drag a tiny lake onto the mesh, where shard_map dispatch and
            # the two all_gathers dwarf the trivial local scan
            sharded = (n_dev > 1 and
                       any(d > 1 for _, d in
                           self.grid_options(n_dev, n_queries, n_columns)))
            # cost each candidate kind AT ITS OWN best admissible grid (the
            # geometry that would actually execute), then pick the kind —
            # costing both at a fixed (1, n_dev) could compare geometries
            # that are inadmissible and will never run
            if sharded:
                g_all = self._resolve_grid(grid, n_dev, n_queries,
                                           n_columns, "all", n_columns)
                g_pruned = self._resolve_grid(grid, n_dev, n_queries,
                                              n_columns, "hybrid", budget)
            else:
                g_all = g_pruned = (1, 1)
            c_full = self._cost("all", n_queries, n_columns, n_columns,
                                g_all[1], g_all[0])
            c_pruned = self._cost("hybrid", n_queries, n_columns, budget,
                                  g_pruned[1], g_pruned[0])
            # a calibrated cost_fn reports measured seconds as total_cost;
            # the analytic default only has flops
            pick = lambda c: c.get("total_cost", c["total_flops"])
            cand = "hybrid" if pick(c_pruned) < pick(c_full) else "all"
            if not sharded and cfg.n_coarse_bands > 0:
                # the tiered stage is a local-plan contender (only when a
                # coarse digest exists to scan): coarse digest scan +
                # skinny fine pass beats the full-lake hybrid probe
                # exactly when the lake dwarfs the survivor budget; it must
                # win strictly, so existing all/hybrid picks are unchanged
                c_tier = self._cost("tiered", n_queries, n_columns,
                                    budget, 1, 1)
                if pick(c_tier) < min(pick(c_pruned), pick(c_full)):
                    cand = "tiered"

        if cand == "all":
            budget = n_columns
        surv = (self.survivor_budget(n_columns, budget)
                if cand == "tiered" else 0)
        if cand == "tiered":
            # the fine tier can't score more columns than the coarse pass
            # gathered — capping the budget here keeps the scorer's gather
            # (and its compiled shape) as skinny as the survivor set
            budget = min(budget, surv)
        if sharded:
            g = self._resolve_grid(grid, n_dev, n_queries, n_columns,
                                   cand, budget)
        else:
            g = (1, 1)
        cost = self._cost(cand, n_queries, max(n_columns, 1),
                          max(budget, 1), max(g[1], 1), g[0],
                          survivor_budget=surv)
        return QueryPlan(candidates=cand, sharded=sharded, budget=budget,
                         k=cfg.k, n_shards=g[1], grid=g,
                         shard_axes=tuple(cfg.shard_axes),
                         survivor_budget=surv, cost=cost)

    # -- admissible-set enumeration (AOT warmup) ----------------------------

    def _make_plan(self, cand: str, sharded: bool, grid: tuple,
                   n_columns: int, n_queries: int) -> QueryPlan:
        """A fully-resolved plan for an explicitly chosen (kind, placement)
        — the budget/survivor resolution of :meth:`plan` without its mode
        logic, so warmup can enumerate kinds the mode would not pick."""
        budget = (n_columns if cand == "all"
                  else self.candidate_budget(n_columns))
        surv = (self.survivor_budget(n_columns, budget)
                if cand == "tiered" else 0)
        if cand == "tiered":
            budget = min(budget, surv)
        g = (int(grid[0]), int(grid[1]))
        cost = self._cost(cand, n_queries, max(n_columns, 1),
                          max(budget, 1), max(g[1], 1), g[0],
                          survivor_budget=surv)
        return QueryPlan(candidates=cand, sharded=sharded, budget=budget,
                         k=self.config.k, n_shards=g[1], grid=g,
                         shard_axes=tuple(self.config.shard_axes),
                         survivor_budget=surv, cost=cost)

    def plan_set(self, *, n_columns: int, n_queries: int = 1,
                 mode: str = "auto", mesh=None, grid: tuple | None = None,
                 scope: str = "serve") -> list[QueryPlan]:
        """The admissible executable set for one padded batch size — what
        AOT warmup compiles before the scheduler admits traffic.

        ``scope="serve"``: the plan this (mode, batch, mesh) actually
        executes, plus the exhaustive recall baseline ``measure_recall``
        runs next to it (same placement family and grid, so the baseline's
        first execution is warm too).  ``scope="full"``: additionally every
        candidate kind (all/lsh→hybrid/tiered) crossed with the local
        placement and every admissible :meth:`grid_options` factorization
        of the mesh (or the operator-pinned ``grid`` alone, when set).
        Deduplicated on the plan's identity fields; the executor skips any
        enumerated plan its corpus can't serve (no band keys / coarse
        digest / mesh)."""
        if scope not in ("serve", "full"):
            raise ValueError(f"unknown warmup scope {scope!r}; "
                             f"want 'serve' or 'full'")
        served = self.plan(n_columns=n_columns, n_queries=n_queries,
                           mode=mode, mesh=mesh, grid=grid)
        base = self.plan(n_columns=n_columns, n_queries=n_queries,
                         mode="sharded" if served.sharded else "full",
                         mesh=mesh if served.sharded else None,
                         grid=served.grid if served.sharded else None)
        plans = [served, base]
        if scope == "full":
            n_dev = self._n_shards(mesh)
            if grid is not None:
                grids = [(int(grid[0]), int(grid[1]))]
            elif mesh is not None and n_dev > 1:
                grids = self.grid_options(n_dev, n_queries, n_columns)
            else:
                grids = []
            for cand in CANDIDATE_KINDS:
                if cand == "tiered" and self.config.n_coarse_bands <= 0:
                    continue                # no coarse digest to scan
                plans.append(self._make_plan(cand, False, (1, 1),
                                             n_columns, n_queries))
                if cand == "tiered":
                    continue                # tiered plans are local-only
                for g in grids:
                    plans.append(self._make_plan(cand, True, g,
                                                 n_columns, n_queries))
        out, seen = [], set()
        for p in plans:
            key = (p.candidates, p.sharded, p.budget, p.k, p.grid,
                   p.survivor_budget)
            if key not in seen:
                seen.add(key)
                out.append(p)
        return out
