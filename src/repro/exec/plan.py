"""Query planner: lake size × mesh × budget × cost model -> QueryPlan.

A :class:`QueryPlan` names one choice per pipeline stage:

=============  =========================  ==============================
stage          choices                    picked by
=============  =========================  ==============================
candidates     all | lsh | hybrid         mode, or cost model on "auto"
score          local | sharded            mesh availability + lake size
merge          top_k | topk+all_gather    follows the score placement
=============  =========================  ==============================

Plan selection ("auto" mode) compares the analytic per-stage costs
(``launch.costmodel.discovery_stage_costs`` unless the caller injects a
different hook): a pruned plan pays the bucket probe + profile proxy over
*all* columns to score only ``budget`` of them, so it wins exactly when
``budget`` is small relative to the lake — tiny lakes fall back to the
brute scan, where the probe overhead would exceed the savings.

The planner is deliberately stateless and cheap: the engine calls it per
micro-batch (lake size moves with catalog refreshes), and the chosen plan
is surfaced per query through ``DiscoveryEngine.stats()``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.exec.stages import CANDIDATE_KINDS

MODES = ("auto", "lsh", "full", "sharded")


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One fully-resolved execution plan for a query micro-batch."""

    candidates: str                 # "all" | "lsh" | "hybrid"
    sharded: bool                   # score per shard, merge via all_gather
    budget: int                     # GLOBAL candidate budget (n for "all")
    k: int
    n_shards: int = 1
    shard_axes: tuple = ("data",)
    cost: dict = dataclasses.field(default_factory=dict, compare=False)

    def __post_init__(self):
        if self.candidates not in CANDIDATE_KINDS:
            raise ValueError(f"unknown candidate stage {self.candidates!r}")

    @property
    def kind(self) -> str:
        """Compact label for stats/benchmarks, e.g. ``sharded-hybrid``."""
        return f"{'sharded' if self.sharded else 'local'}-{self.candidates}"

    @property
    def budget_per_shard(self) -> int:
        """Per-device slice of the global budget (ceil split)."""
        return max(1, -(-self.budget // max(self.n_shards, 1)))


@dataclasses.dataclass
class PlannerConfig:
    k: int = 10
    candidate_frac: float = 0.2     # pruned budget as a fraction of the lake
    max_candidates: int = 4096      # absolute cap on that budget
    n_bands: int = 64
    shard_axes: tuple = ("data",)
    # below this many columns per shard, sharding costs more than it saves
    # (dispatch + all_gather against a trivial local scan) — "auto" only
    min_columns_per_shard: int = 64


class Planner:
    """Resolves (mode, lake, mesh) into a :class:`QueryPlan`.

    ``cost_fn(n_queries, n_columns, budget=..., candidates=..., n_bands=...,
    n_shards=..., k=...)`` must return a dict with at least
    ``total_flops``; the default is the analytic discovery model in
    ``launch.costmodel``. Injecting a measured model here is the hook the
    ROADMAP's tuning items plug into.
    """

    def __init__(self, config: PlannerConfig | None = None,
                 cost_fn: Callable | None = None):
        self.config = config or PlannerConfig()
        if cost_fn is None:
            from repro.launch.costmodel import discovery_stage_costs
            cost_fn = discovery_stage_costs
        self.cost_fn = cost_fn

    # -- helpers ------------------------------------------------------------

    def candidate_budget(self, n_columns: int) -> int:
        cfg = self.config
        want = max(cfg.k, int(n_columns * cfg.candidate_frac))
        return max(1, min(want, cfg.max_candidates, n_columns))

    def _n_shards(self, mesh) -> int:
        if mesh is None:
            return 1
        n = 1
        for ax in self.config.shard_axes:
            n *= int(mesh.shape[ax])
        return n

    def _cost(self, candidates: str, n_queries: int, n_columns: int,
              budget: int, n_shards: int) -> dict:
        return self.cost_fn(n_queries, n_columns, budget=budget,
                            candidates=candidates, k=self.config.k,
                            n_bands=self.config.n_bands, n_shards=n_shards)

    # -- entry point --------------------------------------------------------

    def plan(self, *, n_columns: int, n_queries: int = 1, mode: str = "auto",
             mesh=None) -> QueryPlan:
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; want one of {MODES}")
        cfg = self.config
        n_shards = self._n_shards(mesh)
        budget = self.candidate_budget(n_columns)

        if mode == "sharded":
            if mesh is None:
                raise ValueError("mode='sharded' needs a mesh")
            cand, sharded = "all", True
        elif mode == "full":
            cand, sharded = "all", False
        elif mode == "lsh":
            # an explicit mesh is operator intent: shard whenever one exists
            cand, sharded = "hybrid", n_shards > 1
        else:  # auto: cost-based candidate stage, size-gated sharding
            sharded = (n_shards > 1 and
                       n_columns >= cfg.min_columns_per_shard * n_shards)
            shards_eff = n_shards if sharded else 1
            c_full = self._cost("all", n_queries, n_columns, n_columns,
                                shards_eff)
            c_pruned = self._cost("hybrid", n_queries, n_columns, budget,
                                  shards_eff)
            # a calibrated cost_fn reports measured seconds as total_cost;
            # the analytic default only has flops
            pick = lambda c: c.get("total_cost", c["total_flops"])
            cand = "hybrid" if pick(c_pruned) < pick(c_full) else "all"

        if not sharded:
            n_shards = 1
        if cand == "all":
            budget = n_columns
        cost = self._cost(cand, n_queries, max(n_columns, 1),
                          max(budget, 1), max(n_shards, 1))
        return QueryPlan(candidates=cand, sharded=sharded, budget=budget,
                         k=cfg.k, n_shards=n_shards,
                         shard_axes=tuple(cfg.shard_axes), cost=cost)
