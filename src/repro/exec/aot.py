"""Persistent AOT executable cache for zero-compile serving restarts.

``Executor.aot_compile`` lowers every admissible (bucket × grid × plan
kind) pipeline ahead of traffic via ``jit(...).lower(...).compile()``.
That kills the in-process cold start, but a *restarted* engine would
still re-trace and re-compile the whole bucket ladder.  This module
persists the compiled executables across processes:

* entries are keyed by a blake2b digest over a **signature dict** —
  jax version, backend, device kind and count, the XLA flags, the mesh
  geometry (axis names/sizes + shard/query axes for sharded pipelines),
  the pipeline name with its static arguments (plan kind, k, budget,
  survivor geometry, kernel tile/block config), and the shapes+dtypes of
  every dynamic argument.  Any environment or plan drift lands on a
  different digest, so stale entries are simply never found;
* the payload is ``jax.experimental.serialize_executable.serialize``'s
  ``(payload, in_tree, out_tree)`` triple, pickled together with the full
  signature dict.  ``load`` re-checks the stored signature against the
  requested one (digest collisions, hand-edited files) and treats *any*
  failure — unreadable file, unpickling error, deserialization error —
  as a miss, so a corrupt entry always falls back to a fresh compile;
* writes go through a per-process temp file + ``os.replace`` (the
  ``CatalogStore`` publish idiom), so engines sharing one cache
  directory never observe torn entries; last writer of an identical
  signature wins, which is harmless because the payloads are equivalent.

Deserialization is one in_tree/out_tree reconstruction plus an XLA
executable load — measured 10-30× cheaper than the trace+compile it
replaces on this container's CPU backend — which is what makes a warm
restart land in milliseconds.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import tempfile
from pathlib import Path

import jax

# Bump whenever a pipeline's jaxpr changes without its signature moving
# (signatures hash statics+avals, not the traced program): schema 2 =
# live-column counting in ``_local_all`` for bucket-padded corpora.
_SCHEMA = 2


def environment_signature() -> dict:
    """The process-environment half of every cache key: anything that can
    change the compiled artifact between runs without the plan moving."""
    devs = jax.devices()
    return {
        "schema": _SCHEMA,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "n_devices": len(devs),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
    }


def tree_aval_descriptors(tree) -> list:
    """(shape, dtype) per leaf of a pytree of arrays/ShapeDtypeStructs —
    the dynamic-argument half of a cache key."""
    return [[list(int(s) for s in leaf.shape), str(leaf.dtype)]
            for leaf in jax.tree_util.tree_leaves(tree)]


class ExecutableCache:
    """On-disk store of serialized XLA executables, shared across engine
    processes.  All failures degrade to a miss; ``store`` is best-effort
    (a read-only or full disk never breaks serving)."""

    def __init__(self, root: str | os.PathLike, *, env: dict | None = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        # injectable for tests simulating a jax-version / device mismatch
        self.env = dict(env) if env is not None else environment_signature()
        self.stats = {"hits": 0, "misses": 0, "stores": 0, "errors": 0}

    # -- keys ---------------------------------------------------------------

    def signature(self, name: str, statics, avals, mesh_desc=None) -> dict:
        """Full signature dict for one executable unit.  ``statics`` is the
        pipeline's static-argument mapping, ``avals`` the descriptor list
        from :func:`tree_aval_descriptors`, ``mesh_desc`` the mesh geometry
        for sharded units (None for local pipelines)."""
        return {
            **self.env,
            "name": str(name),
            "statics": repr(tuple(sorted(dict(statics).items()))),
            "avals": list(avals),
            "mesh": repr(mesh_desc),
        }

    def _path(self, sig: dict) -> Path:
        blob = json.dumps(sig, sort_keys=True).encode()
        digest = hashlib.blake2b(blob, digest_size=16).hexdigest()
        return self.root / f"{digest}.exe"

    # -- load / store -------------------------------------------------------

    def load(self, sig: dict):
        """Deserialized executable for ``sig``, or None on miss/corruption
        (the caller then compiles fresh and usually ``store``s)."""
        from jax.experimental import serialize_executable as se

        path = self._path(sig)
        try:
            with open(path, "rb") as f:
                entry = pickle.load(f)
            if entry.get("sig") != sig:     # digest collision / stale file
                raise ValueError("signature mismatch")
            exe = se.deserialize_and_load(entry["payload"],
                                          entry["in_tree"],
                                          entry["out_tree"])
        except FileNotFoundError:
            self.stats["misses"] += 1
            return None
        except Exception:
            self.stats["errors"] += 1
            self.stats["misses"] += 1
            return None
        self.stats["hits"] += 1
        return exe

    def store(self, sig: dict, compiled) -> bool:
        """Persist a compiled executable under ``sig``, atomically; best
        effort (False on any failure — serving proceeds uncached)."""
        from jax.experimental import serialize_executable as se

        path = self._path(sig)
        try:
            payload, in_tree, out_tree = se.serialize(compiled)
            blob = pickle.dumps({"sig": sig, "payload": payload,
                                 "in_tree": in_tree, "out_tree": out_tree})
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(blob)
                os.replace(tmp, path)       # atomic: readers see old or new
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        except Exception:
            self.stats["errors"] += 1
            return False
        self.stats["stores"] += 1
        return True
