"""Composable pipeline stages: candidate generation, scoring, top-k merge.

Every discovery query — local or mesh-sharded, pruned or brute — is the
same three-stage pipeline over a (local shard of the) corpus:

1. **candidates** — which columns may the scorer see?  Kinds:
   ``all`` (full-scan mask: every live column), ``lsh`` (banded-MinHash
   bucket probe via the ``lsh_probe`` Pallas kernel), ``hybrid`` (LSH hits
   ranked first, remaining budget filled by profile-space proximity — the
   blocking construction of Flores et al.);
2. **score** — distance features + GBDT over exactly the surviving
   columns (gathered to a fixed budget so shapes stay jit-cacheable);
3. **merge** — local top-k, and on a mesh per-device top-k + one small
   ``all_gather`` (collective bytes O(Q·k·devices), lake-size free).

The functions here are pure jnp/Pallas and run identically inside ``jit``
and inside ``shard_map`` — ``executor.py`` composes them into the local
pipelines, ``sharded.py`` into the per-device bodies.  Column ids are
always *global* (``cids``), so exclusion masks (self, same-table, padding)
work unchanged on a shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.predictor import distance_features_ref, gbdt_predict_ref
from repro.kernels.lsh_probe import lsh_probe_gathered_tile, lsh_probe_tile

CANDIDATE_KINDS = ("all", "lsh", "hybrid", "tiered")

# LSH hits outrank every profile-proximity score: the proxy is squashed
# into (-1, 1), so any offset > 2 keeps the two bands disjoint.
_LSH_PRIORITY_BOOST = 4.0


# ---------------------------------------------------------------------------
# stage 1: candidate generation
# ---------------------------------------------------------------------------

def live_count(cids):
    """Number of live (non-padding) columns on this corpus axis — counts
    ``cids >= 0`` so bucket-padded sentinel rows never inflate per-query
    scored-column accounting."""
    return jnp.sum((cids >= 0).astype(jnp.int32))


def exclusion_mask(cids, tids, tq, qid):
    """(Q, C) bool — True where a column must NOT be returned for a query.

    Masks padding columns (cid < 0), the query itself (global id match;
    qid=-1 marks an external query and matches nothing), and same-table
    columns (tq=-1 disables the table mask for that row).
    """
    pad = (cids < 0)[None, :]
    self_hit = cids[None, :] == qid[:, None]
    same_table = (tq[:, None] >= 0) & (tids[None, :] == tq[:, None])
    return pad | self_hit | same_table


def candidate_priorities(kind: str, zq, qkeys, z, ckeys, cids, tids, tq, qid,
                         *, interpret: bool = True):
    """(Q, C) float32 priorities; -inf means "never a candidate".

    ``kind``: ``lsh`` — bucket hits only (missing the budget is fine: the
    un-hit remainder stays -inf); ``hybrid`` — hits first, then nearest
    columns in z-scored profile space via one matmul (squared-L2 up to a
    per-query constant — no trees, no word features at this stage).
    """
    excl = exclusion_mask(cids, tids, tq, qid)
    if kind == "lsh":
        hit = lsh_probe_tile(qkeys, ckeys, interpret=interpret)
        prio = jnp.where(hit > 0, 0.0, -jnp.inf)
    elif kind == "hybrid":
        hit = lsh_probe_tile(qkeys, ckeys, interpret=interpret)
        # -||zq - z||² up to a per-query constant: 2·zq@zᵀ - ||z||²
        proxy = 2.0 * zq @ z.T - jnp.sum(z * z, axis=1)[None]
        proxy = proxy / (1.0 + jnp.abs(proxy))            # squash to (-1, 1)
        prio = hit.astype(jnp.float32) * _LSH_PRIORITY_BOOST + proxy
    else:
        raise ValueError(f"unknown candidate kind {kind!r}; "
                         f"want one of {CANDIDATE_KINDS}")
    return jnp.where(excl, -jnp.inf, prio)


def tiered_survivors(qcoarse, coarse, cids, tids, tq, qid, *,
                     survivor_budget: int, block_c: int = 32,
                     proxy=None, interpret: bool = True):
    """Coarse pass of the tiered candidate stage: pick survivor blocks.

    Probes the small (C, S) super-band digest with the (Q, S) coarse query
    keys, expands column hits to *blocks* of ``block_c`` contiguous
    columns (so the downstream gather reads aligned runs, not scattered
    singletons), and keeps up to ``survivor_budget`` columns per query —
    direct coarse hits ranked above their block-mates.

    ``proxy`` (Q, C), when given, fills survivor-budget slots the digest
    left empty with the proxy-nearest columns (ranked strictly below every
    digest hit, mirroring the ``hybrid`` construction).  The digest only
    sees *value overlap*; the exact GBDT top-k also contains columns that
    are merely profile-similar, and at 10^5 columns the digest's hit set
    is far smaller than the budget — without the fill those slots are
    wasted and tiered recall trails the single-tier hybrid probe.

    Returns ``(pos, valid, n_hits, n_survivors)``: gather positions
    (Q, M') into the local corpus, their validity mask, and per-query
    counts of direct coarse hits and digest-eligible survivor columns (the
    numbers the ``coarse_pass`` event reports — proxy fill does not count
    as a digest survivor).
    """
    c = coarse.shape[0]
    hit = lsh_probe_tile(qcoarse, coarse, interpret=interpret)   # (Q, C)
    pad_c = (-c) % block_c
    hp = jnp.pad(hit, ((0, 0), (0, pad_c)))
    nb = hp.shape[1] // block_c
    block_hit = jnp.any(hp.reshape(hit.shape[0], nb, block_c) > 0, axis=-1)
    block_hit = jnp.repeat(block_hit, block_c, axis=1)[:, :c]     # (Q, C)
    excl = exclusion_mask(cids, tids, tq, qid)
    if proxy is None:
        prio = jnp.where(block_hit, 1.0, -jnp.inf) + hit.astype(jnp.float32)
    else:
        # squashed proxy lives in (-1, 1); the boost keeps every digest
        # hit (and its block-mates) strictly above every proxy-only fill
        prio = (jnp.where(block_hit, _LSH_PRIORITY_BOOST, 0.0)
                + hit.astype(jnp.float32)
                + proxy / (1.0 + jnp.abs(proxy)))
    prio = jnp.where(excl, -jnp.inf, prio)
    pos, valid = gather_candidates(prio, survivor_budget)
    n_hits = jnp.sum((hit > 0) & ~excl, axis=1)
    n_survivors = jnp.sum(block_hit & ~excl, axis=1)
    return pos, valid, n_hits, n_survivors


def tiered_priorities(zq, qkeys, zg, keys_g, valid, *, interpret: bool = True):
    """Fine pass of the tiered stage over gathered survivors.

    ``zg`` (Q, M', F_NUM) and ``keys_g`` (Q, M', B) are the survivors'
    profiles and fine band keys gathered per query; the skinny-geometry
    probe kernel plus the per-query proxy replace the full-lake hybrid
    pass. Returns (Q, M') priorities with invalid slots at -inf.
    """
    hit = lsh_probe_gathered_tile(qkeys, keys_g, interpret=interpret)
    proxy = 2.0 * jnp.einsum("qf,qmf->qm", zq, zg) - jnp.sum(zg * zg, axis=-1)
    proxy = proxy / (1.0 + jnp.abs(proxy))
    prio = hit.astype(jnp.float32) * _LSH_PRIORITY_BOOST + proxy
    return jnp.where(valid, prio, -jnp.inf)


def gather_candidates(prio, budget: int):
    """Top-``budget`` columns by priority -> (positions (Q, M), valid (Q, M)).

    Positions index the local corpus axis; invalid rows (priority -inf)
    mark budget slots the scorer must ignore.
    """
    pval, pos = jax.lax.top_k(prio, budget)
    return pos, jnp.isfinite(pval)


# ---------------------------------------------------------------------------
# stage 2: scoring
# ---------------------------------------------------------------------------

def score_columns(zq, wq, zc, wc, gbdt_tuple):
    """GBDT join-quality scores. zc/wc (C, F) -> (Q, C); an extra leading
    axis on zc/wc ((Q, M, F) gathered candidates) scores per-query sets."""
    if zc.ndim == 2:
        zc, wc = zc[None], wc[None]
    d = distance_features_ref(zq[:, None], wq[:, None], zc, wc)
    return gbdt_predict_ref(gbdt_tuple, d)


def score_streamed(zq, wq, z, w, gbdt_tuple, *, block: int = 4096):
    """Full-corpus scoring, streamed in column blocks of ``block``.

    The jnp mirror of the fused Pallas kernel: the (Q, N, F) distance
    tensor never materializes, so HBM traffic is the profiles themselves
    plus the (Q, N) score row — bandwidth-bound at profile size.
    """
    n = z.shape[0]
    nb = max(n // block, 1)

    def score_blk(args):
        zb, wb = args
        return score_columns(zq, wq, zb, wb, gbdt_tuple)

    if n % block == 0 and n > block:
        zc = z.reshape(nb, block, z.shape[1])
        wc = w.reshape(nb, block, w.shape[1])
        s = jax.lax.map(score_blk, (zc, wc))              # (nb, Q, block)
        return jnp.moveaxis(s, 0, 1).reshape(zq.shape[0], n)
    return score_blk((z, w))


# ---------------------------------------------------------------------------
# stage 3: top-k merge
# ---------------------------------------------------------------------------

def merge_topk(scores, cids, k: int):
    """Local top-k -> (scores (Q, k'), global ids (Q, k')), k' = min(k, C).

    ``cids`` is (C,) for a shared corpus axis or (Q, C) for per-query
    gathered candidate sets. Non-finite slots come back with id -1 (the
    caller-visible padding convention)."""
    kl = min(k, scores.shape[1])
    sc, pos = jax.lax.top_k(scores, kl)
    if cids.ndim == 1:
        cids = jnp.broadcast_to(cids[None], scores.shape)
    ids = jnp.take_along_axis(cids, pos, axis=1)
    return sc, jnp.where(jnp.isfinite(sc), ids, -1)


def merge_topk_sharded(local_scores, local_ids, k: int, axes):
    """Per-device top-k results -> replicated global top-k.

    One tiled ``all_gather`` per mesh axis moves the (Q, k_local) candidate
    pairs of every shard; a final top-k over the (Q, k_local · devices)
    union re-ranks. Runs inside ``shard_map``.
    """
    all_s, all_i = local_scores, local_ids
    for ax in axes:
        all_s = jax.lax.all_gather(all_s, ax, axis=1, tiled=True)
        all_i = jax.lax.all_gather(all_i, ax, axis=1, tiled=True)
    gs, gp = jax.lax.top_k(all_s, min(k, all_s.shape[1]))
    gi = jnp.take_along_axis(all_i, gp, axis=1)
    return gs, jnp.where(jnp.isfinite(gs), gi, -1)


def assemble_query_shards(scores, ids, n_scored, axes):
    """Phase-2 merge of the 2-D grid: reassemble the query batch.

    After :func:`merge_topk_sharded` reduced over the DATA axis, every
    device holds the finished (Q_local, k) rows of its *query* shard. One
    tiled ``all_gather`` per query axis (row axis 0, so shard order is
    batch order) replicates the full (Q, k) batch — collective bytes
    O(Q·k), independent of both the lake size and the data-axis width.
    ``n_scored`` rides along so per-query accounting follows its row. Runs
    inside ``shard_map``; a no-op when ``axes`` is empty (1-D plans).
    """
    for ax in axes:
        scores = jax.lax.all_gather(scores, ax, axis=0, tiled=True)
        ids = jax.lax.all_gather(ids, ax, axis=0, tiled=True)
        n_scored = jax.lax.all_gather(n_scored, ax, axis=0, tiled=True)
    return scores, ids, n_scored
