"""Executor: runs a QueryPlan's candidate→score→merge pipeline on a corpus.

One ``Executor`` wraps one immutable corpus view (profiles, table ids,
optional LSH band keys) plus the GBDT parameters, and executes any
:class:`~repro.exec.plan.QueryPlan` against it:

* local plans dispatch to module-level jitted pipelines (cached by jax
  across executors, so a catalog refresh never recompiles);
* sharded plans run on the plan's 2-D ``grid=(q_shards, d_shards)``: the
  executor re-shapes its mesh's devices into a (query × data) grid mesh
  per geometry, places the corpus over each grid's ``data`` axis **once**
  (cached per grid — the seed implementation re-placed per query batch),
  pads the query batch to a multiple of ``q_shards``, shards it over the
  ``query`` axis, and unpads the reassembled result. ``(1, d)`` grids use
  the caller's own mesh and the legacy replicated-query specs, so 1-D
  plans (and multi-axis ``shard_axes`` like the dry-run's pod×data) are
  untouched.

Both ``core.discovery.rank``/``rank_sharded`` and the service's
``DiscoveryEngine`` are thin adapters over this class — the single copy of
the scoring pipeline in the repo.

The returned contract is uniform: ``(scores (Q, k), global ids (Q, k),
n_scored (Q,))`` as numpy, padded with -inf / -1 when fewer than k columns
are rankable, with ``n_scored`` the *global* number of columns the GBDT
actually scored per query (psum-ed over the data axes on a mesh).

With a quantized ``profile_dtype`` (int8/fp16 sidecar + per-feature
dequant scale) the scan streams the small sidecar, over-fetches
``RESCORE_MULT × k`` candidates, and an exact fp32 re-rank of that tiny
gathered set restores the fp32 top-k ordering — returned scores are
always fp32-exact regardless of the resident dtype.
"""
from __future__ import annotations

import threading
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import features as FT
from repro.exec import stages
from repro.exec.aot import tree_aval_descriptors
from repro.exec.plan import QueryPlan
from repro.exec.sharded import (_pad_to, build_sharded_pipeline,
                                place_sharded_corpus)
from repro.kernels.lsh_probe import PAD_CORPUS
from repro.kernels.profile_distance import dequantize, quantize_profiles


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# Ingest deltas are padded up to this many rows before the on-device row
# update, so the update executable's shapes come from a tiny fixed set
# (one per grain multiple) instead of one per odd delta size.
DELTA_ROW_GRAIN = 256


class PlacementBundle:
    """Refcounted bundle of device-resident arrays.

    Successor executors built by :meth:`Executor.extended` retain their
    predecessor's immutable bundles (the GBDT parameters) instead of
    re-placing them, while per-version row arrays live in a bundle owned
    by exactly one executor.  ``Executor.close`` releases its bundles;
    device memory is freed only when the last holder releases —
    retiring an old snapshot version never yanks arrays a newer version
    still serves from, and the class-level live count gives leak tests a
    direct handle on how many placements exist.
    """

    _live = 0
    _live_lock = threading.Lock()

    def __init__(self, arrays: dict):
        self.arrays = dict(arrays)
        self.refs = 1
        self._lock = threading.Lock()
        with PlacementBundle._live_lock:
            PlacementBundle._live += 1

    def retain(self) -> "PlacementBundle":
        with self._lock:
            if self.refs <= 0:
                raise RuntimeError("retain() on a released bundle")
            self.refs += 1
        return self

    def release(self) -> None:
        with self._lock:
            self.refs -= 1
            if self.refs > 0:
                return
            self.arrays.clear()
        with PlacementBundle._live_lock:
            PlacementBundle._live -= 1

    def nbytes(self) -> int:
        return sum(int(getattr(a, "nbytes", 0))
                   for a in self.arrays.values() if a is not None)


def live_placement_bundles() -> int:
    """Device placement bundles currently holding memory — bounded by
    (live versions) × (bundles per executor) when nothing leaks."""
    with PlacementBundle._live_lock:
        return PlacementBundle._live


@jax.jit
def _update_rows2(arr, rows, row0):
    """Write ``rows`` into ``arr[row0:row0+len(rows)]`` on device — the
    delta-placement primitive: only ``rows`` crosses the host-device
    link; the unchanged prefix is forked at HBM bandwidth."""
    return jax.lax.dynamic_update_slice(arr, rows, (row0, jnp.int32(0)))


@jax.jit
def _update_rows1(arr, rows, row0):
    return jax.lax.dynamic_update_slice(arr, rows, (row0,))


@jax.jit
def _update_rows_tree(arrs, rows, row0):
    """Fused delta fork: ONE dispatch DUS-forks every array of the
    corpus bundle (dict pytree) — XLA schedules the prefix copies
    together instead of paying per-array dispatch latency."""
    return jax.tree_util.tree_map(
        lambda a, r: jax.lax.dynamic_update_slice(
            a, r, (row0,) if a.ndim == 1 else (row0, jnp.int32(0))),
        arrs, rows)


# quantized scans over-fetch this multiple of k, then an exact fp32
# re-rank of the over-fetched set restores the fp32 top-k ordering —
# GBDT scores are threshold-discontinuous, so even fp16's ~5e-4 profile
# error flips near-boundary ranks that no finer quantizer would fix
RESCORE_MULT = 4


@partial(jax.jit, static_argnames=("k",))
def _rescore_exact(zq, wq, zg, wg, gbdt_tuple, sc_scan, ids, k: int):
    """Re-rank an over-fetched (Q, R) candidate set with exact fp32
    profiles; invalid scan slots (non-finite score) stay excluded."""
    s = stages.score_columns(zq, wq, zg, wg, gbdt_tuple)
    s = jnp.where(jnp.isfinite(sc_scan), s, -jnp.inf)
    sc, pos = jax.lax.top_k(s, min(k, s.shape[1]))
    return sc, jnp.where(jnp.isfinite(sc),
                         jnp.take_along_axis(ids, pos, axis=1), -1)


def pad_rows(arrays, multiple: int):
    """Pad every array's leading (query) axis up to a multiple of
    ``multiple`` by repeating the last row — the repeated rows carry their
    qid/tq along, so masking stays consistent, and the caller slices the
    duplicate results back off. Returns (padded_arrays, original_length)."""
    q = int(np.asarray(arrays[0]).shape[0])
    pad = -(-q // max(multiple, 1)) * max(multiple, 1)
    if pad == q:
        return [np.asarray(a) for a in arrays], q
    rep = lambda a: np.concatenate(
        [np.asarray(a), np.repeat(np.asarray(a)[-1:], pad - q, axis=0)])
    return [rep(a) for a in arrays], q


def pad_topk(scores: np.ndarray, ids: np.ndarray, k: int):
    """Pad (Q, k_eff) top-k results out to k columns (-inf scores, -1 ids)."""
    k_eff = scores.shape[1]
    if k_eff >= k:
        return scores[:, :k], ids[:, :k]
    pad = ((0, 0), (0, k - k_eff))
    return (np.pad(scores, pad, constant_values=-np.inf),
            np.pad(ids, pad, constant_values=-1))


# ---------------------------------------------------------------------------
# local pipelines (jitted once per shape at module level)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "block"))
def _local_all(zq, wq, tq, qid, z, zscale, w, cids, tids, gbdt_tuple,
               k: int, block: int):
    s = stages.score_streamed(zq, wq, dequantize(z, zscale), w, gbdt_tuple,
                              block=block)
    s = jnp.where(stages.exclusion_mask(cids, tids, tq, qid), -jnp.inf, s)
    sc, ids = stages.merge_topk(s, cids, k)
    # count live columns, not the (possibly bucket-padded) corpus rows
    n = jnp.full((zq.shape[0],), stages.live_count(cids), jnp.int32)
    return sc, ids, n


@partial(jax.jit, static_argnames=("kind", "k", "budget", "interpret"))
def _local_pruned(zq, wq, qkeys, tq, qid, z, zscale, w, ckeys, cids, tids,
                  gbdt_tuple, kind: str, k: int, budget: int,
                  interpret: bool):
    zf = dequantize(z, zscale)
    prio = stages.candidate_priorities(kind, zq, qkeys, zf, ckeys, cids,
                                       tids, tq, qid, interpret=interpret)
    pos, valid = stages.gather_candidates(prio, budget)
    s = stages.score_columns(zq, wq, zf[pos], w[pos], gbdt_tuple)
    s = jnp.where(valid, s, -jnp.inf)
    sc, ids = stages.merge_topk(s, cids[pos], k)
    return sc, ids, valid.sum(axis=1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "budget", "survivor_budget",
                                   "block_c", "interpret"))
def _local_tiered(zq, wq, qkeys, qcoarse, tq, qid, z, zscale, w, ckeys,
                  coarse, cids, tids, gbdt_tuple, k: int, budget: int,
                  survivor_budget: int, block_c: int, interpret: bool):
    """Two-tier candidate pipeline: coarse digest scan over the full lake,
    then fine probe + proxy + GBDT only over the gathered survivors.  The
    full-lake pass touches the (C, S) digest plus one proxy matmul over
    the resident (quantized) sidecar, which fills budget slots the digest
    left empty with profile-nearest columns — without the fill, the exact
    top-k's profile-similar-but-non-overlapping columns are unreachable
    and large-lake recall trails the single-tier hybrid probe."""
    zf = dequantize(z, zscale)
    # -||zq - z||² up to a per-query constant, fused over the sidecar
    fill = 2.0 * zq @ zf.T - jnp.sum(zf * zf, axis=1)[None]
    pos, valid, n_hits, n_surv = stages.tiered_survivors(
        qcoarse, coarse, cids, tids, tq, qid,
        survivor_budget=survivor_budget, block_c=block_c, proxy=fill,
        interpret=interpret)
    zg = dequantize(z[pos], zscale)                      # (Q, M', F_NUM)
    prio = stages.tiered_priorities(zq, qkeys, zg, ckeys[pos], valid,
                                    interpret=interpret)
    pos2, valid2 = stages.gather_candidates(prio, budget)
    gpos = jnp.take_along_axis(pos, pos2, axis=1)        # (Q, M) global cols
    s = stages.score_columns(zq, wq, dequantize(z[gpos], zscale), w[gpos],
                             gbdt_tuple)
    s = jnp.where(valid2, s, -jnp.inf)
    sc, ids = stages.merge_topk(s, cids[gpos], k)
    return sc, ids, valid2.sum(axis=1).astype(jnp.int32), n_hits, n_surv


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------

class Executor:
    """Executes query plans against one corpus view."""

    def __init__(self, z: np.ndarray, w: np.ndarray, gbdt_tuple,
                 *, table_ids: np.ndarray | None = None,
                 band_keys: np.ndarray | None = None,
                 coarse_keys: np.ndarray | None = None,
                 profile_dtype: str = "fp32", z_scale=None,
                 fp32_rows=None, survivor_block: int = 32,
                 mesh=None, score_block: int = 4096, events=None,
                 exec_cache=None, n_padded: int | None = None):
        # n_live = true resident columns; n_columns = the (optionally
        # bucket-padded) corpus dimension every traced shape and every
        # plan static is computed from.  Pad rows are inert sentinels
        # (cid -1 → exclusion mask → -inf), bought so an ingest delta
        # that stays inside its column bucket changes no compiled shape.
        self.n_live = int(z.shape[0])
        self.n_columns = max(int(n_padded), self.n_live) \
            if n_padded is not None else self.n_live
        self.profile_dtype = str(profile_dtype)
        self.survivor_block = int(survivor_block)
        # the resident profile matrix: quantized sidecar + per-feature
        # dequant scale ("fp32" keeps the identity scale, so every
        # pipeline treats the three dtypes uniformly).  A caller that
        # already quantized (e.g. the engine streaming a memmapped
        # snapshot in chunks) passes the sidecar + its scale directly.
        if z_scale is not None:
            self._z_np = np.asarray(z)
            self._zscale_np = np.asarray(z_scale, np.float32)
            self._zf_np = None        # pre-quantized caller: no fp32 source
        else:
            self._z_np, self._zscale_np = quantize_profiles(
                z, self.profile_dtype)
            # keep the fp32 source (host-side only) when the resident
            # matrix is quantized: quantized scans over-fetch and the
            # exact re-rank gathers these few rows back
            self._zf_np = (None if self.profile_dtype == "fp32"
                           else np.asarray(z, np.float32))
        # exact-rescore row source, in precedence order: an explicit
        # gather callable (``ids -> (…, F) float32`` — the engine streaming
        # a lazy memmapped snapshot re-z-scores just the gathered rows), a
        # host fp32 copy of the corpus, or None (fp32 resident: the scan
        # itself is exact and no re-rank runs)
        if fp32_rows is not None:
            self._fp32_rows = fp32_rows
        elif self._zf_np is not None:
            self._fp32_rows = self._zf_np.__getitem__
        else:
            self._fp32_rows = None
        self._w_np = np.asarray(w)
        self._tids_np = (np.asarray(table_ids, np.int32)
                         if table_ids is not None
                         else np.zeros((self.n_live,), np.int32))
        self._ckeys_np = (np.asarray(band_keys, np.uint32)
                          if band_keys is not None else None)
        self._coarse_np = (np.asarray(coarse_keys, np.uint32)
                           if coarse_keys is not None else None)
        self._cids_np = np.arange(self.n_live, dtype=np.int32)
        if self.n_columns > self.n_live:
            # sentinel pad rows, mirroring place_sharded_corpus: the
            # exclusion mask scores cid < 0 rows -inf everywhere
            n = self.n_columns
            self._z_np = _pad_to(self._z_np, n,
                                 np.zeros((), self._z_np.dtype))
            self._w_np = _pad_to(self._w_np, n, FT.HASH_SENTINEL)
            self._tids_np = _pad_to(self._tids_np, n, -2)
            self._cids_np = _pad_to(self._cids_np, n, -1)
            if self._ckeys_np is not None:
                self._ckeys_np = _pad_to(self._ckeys_np, n, PAD_CORPUS)
            if self._coarse_np is not None:
                self._coarse_np = _pad_to(self._coarse_np, n, PAD_CORPUS)
        # spare-tail claim for the padded host mirrors: the FIRST same-
        # bucket successor writes its delta rows into this executor's pad
        # region in place (safe: cids/tids liveness masks are always per-
        # executor copies, so our views keep masking those rows dead);
        # later forks from the same predecessor fall back to a copy.  The
        # claim cell is SHARED by zero-delta successors (they alias the
        # same buffers, so a claim through either must stick for both).
        self._host_lock = threading.Lock()
        self._host_spare = [False]
        self._gbdt = tuple(map(jnp.asarray, gbdt_tuple))
        self._gbdt_bundle = PlacementBundle(
            {f"gbdt{i}": a for i, a in enumerate(self._gbdt)})
        self.mesh = mesh
        self.score_block = int(score_block)
        # device-resident copies for the local pipelines
        self._z = jnp.asarray(self._z_np)
        self._zscale = jnp.asarray(self._zscale_np)
        self._w = jnp.asarray(self._w_np)
        self._cids = jnp.asarray(self._cids_np)
        self._tids = jnp.asarray(self._tids_np)
        self._ckeys = (jnp.asarray(self._ckeys_np)
                       if self._ckeys_np is not None else None)
        self._coarse = (jnp.asarray(self._coarse_np)
                        if self._coarse_np is not None else None)
        self._rows_bundle = PlacementBundle(dict(
            z=self._z, zscale=self._zscale, w=self._w, cids=self._cids,
            tids=self._tids, ckeys=self._ckeys, coarse=self._coarse))
        # host→device bytes spent placing this corpus view (a successor
        # built by ``extended`` uploads only its delta rows)
        self.bytes_uploaded = self._rows_bundle.nbytes()
        # sharded state, built lazily per placement (shard_axes / grid)
        self._placed: dict[tuple, dict] = {}
        self._pipelines: dict[tuple, object] = {}
        self._grid_meshes: dict[tuple, Mesh] = {}
        # AOT dispatch table: exact-shape executables registered by
        # ``aot_compile`` (fresh lower+compile or a persistent-cache load).
        # ``lower().compile()`` does NOT feed jax's jit call cache, so the
        # serving path must dispatch through this dict to reuse them; a
        # shape with no entry falls back to the plain jitted pipeline.
        self._compiled: dict[tuple, object] = {}
        self._exec_cache = exec_cache
        self._dispatch_stats = {"aot": 0, "fallback": 0}
        self._closed = False
        # observability: duck-typed event sink (anything with
        # .publish(type, **payload) — service.events.EventBus; exec stays
        # dependency-free) + first-contact tracking so the compile spike
        # a (plan kind, grid, batch shape) pays on its first execution is
        # a visible event, not a mystery p99 outlier
        self._events = events
        self._seen_shapes: set[tuple] = set()
        self._tls = threading.local()

    # -- lifecycle ----------------------------------------------------------

    def close(self) -> None:
        """Release the corpus's device placements (local copies AND the
        cached sharded placement). One executor wraps one immutable catalog
        version; the MVCC engine keeps a per-version executor cache and
        closes each executor when the last in-flight query batch unpins its
        version — so retiring a snapshot actually frees device memory
        instead of leaking one corpus placement per catalog refresh.
        Idempotent; ``execute`` after close raises."""
        if self._closed:
            return
        self._closed = True
        self._placed.clear()
        self._pipelines.clear()
        self._grid_meshes.clear()
        self._compiled.clear()
        self._z = self._w = self._cids = self._tids = self._ckeys = None
        self._zscale = self._coarse = None
        # release the refcounted bundles: the row bundle is owned (freed
        # now unless a successor forked mid-flight), the GBDT bundle is
        # shared across versions and frees only at its last release
        self._rows_bundle.release()
        self._gbdt_bundle.release()

    @property
    def closed(self) -> bool:
        return self._closed

    # -- delta placement ----------------------------------------------------

    def extended(self, z_rows, w_rows, *, table_ids, band_keys=None,
                 coarse_keys=None, fp32_rows=None,
                 n_padded: int | None = None) -> "Executor":
        """Successor executor for an append-only corpus delta.

        Only the new rows (grain-padded to :data:`DELTA_ROW_GRAIN`) cross
        the host-device link: when the padded corpus stays inside the
        same column bucket, every device tensor is forked on-device by
        one ``dynamic_update_slice`` over the predecessor's resident
        array — compiled once per grain multiple and reused for every
        later ingest.  The successor shares the predecessor's GBDT
        placement (refcounted), its AOT dispatch table, pipelines and
        first-contact set, so a same-bucket successor serves with **zero
        recompiles**; a zero-row delta shares the row bundle outright.
        Sharded ``_placed`` corpora are rebuilt lazily on first sharded
        execute.  Crossing a bucket boundary re-places the corpus at the
        new padded size (ideally pre-warmed in the background first).

        ``z_rows`` must be fp32 z-scored rows under the predecessor's
        normalization stats — quantized-resident corpora fall back to a
        full rebuild at the engine layer.
        """
        if self._closed:
            raise RuntimeError("executor is closed")
        if self.profile_dtype != "fp32":
            raise NotImplementedError(
                "delta placement requires an fp32-resident corpus; "
                "quantized corpora take the full-rebuild path")
        z_rows = np.asarray(z_rows, np.float32)
        w_rows = np.asarray(w_rows, self._w_np.dtype)
        d = int(z_rows.shape[0])
        if (self._ckeys_np is None) != (band_keys is None):
            raise ValueError("band_keys must match the predecessor's")
        if (self._coarse_np is None) != (coarse_keys is None):
            raise ValueError("coarse_keys must match the predecessor's")
        n_live2 = self.n_live + d
        n_pad2 = max(int(n_padded), n_live2) if n_padded is not None \
            else max(self.n_columns, n_live2)

        ex = object.__new__(Executor)
        ex.n_live = n_live2
        ex.n_columns = n_pad2
        ex.profile_dtype = self.profile_dtype
        ex.survivor_block = self.survivor_block
        ex.mesh = self.mesh
        ex.score_block = self.score_block
        ex._zscale_np = self._zscale_np
        ex._zf_np = None
        ex._fp32_rows = fp32_rows
        ex._gbdt = self._gbdt
        ex._gbdt_bundle = self._gbdt_bundle.retain()
        ex._exec_cache = self._exec_cache
        ex._events = self._events
        ex._placed = {}
        ex._pipelines = dict(self._pipelines)
        ex._grid_meshes = dict(self._grid_meshes)
        ex._compiled = dict(self._compiled)
        ex._seen_shapes = set(self._seen_shapes)
        ex._dispatch_stats = {"aot": 0, "fallback": 0}
        ex._tls = threading.local()
        ex._closed = False

        def cat(old, rows, fill):
            out = np.concatenate([np.asarray(old[:self.n_live]), rows]) \
                if d else np.asarray(old[:self.n_live])
            return _pad_to(out, n_pad2, fill)

        cid_rows = np.arange(self.n_live, n_live2, dtype=np.int32)
        tid_rows = np.asarray(table_ids, np.int32)
        # same-bucket successors write the big value mirrors into the
        # predecessor's spare pad tail in place (first claimant only) —
        # O(delta) instead of an O(bucket) host copy.  The pad rows'
        # VALUES changing under the predecessor is harmless: liveness is
        # decided by cids/tids, which stay per-executor copies below, so
        # every predecessor view keeps masking those rows dead.  A
        # zero-delta same-pad successor aliases the buffers outright and
        # shares the claim cell, so a later claim through either sticks.
        ex._host_lock = threading.Lock()
        same_pad = n_pad2 == self.n_columns
        inplace = False
        if d and same_pad:
            with self._host_lock:
                inplace = not self._host_spare[0]
                if inplace:
                    self._host_spare[0] = True
        ex._host_spare = self._host_spare if (d == 0 and same_pad) \
            else [False]

        def share(old, rows):
            old[self.n_live:n_live2] = rows
            return old

        if d == 0 and same_pad:
            ex._z_np, ex._w_np = self._z_np, self._w_np
            ex._ckeys_np, ex._coarse_np = self._ckeys_np, self._coarse_np
        elif inplace:
            ex._z_np = share(self._z_np, z_rows)
            ex._w_np = share(self._w_np, w_rows)
            ex._ckeys_np = None if band_keys is None else \
                share(self._ckeys_np, np.asarray(band_keys, np.uint32))
            ex._coarse_np = None if coarse_keys is None else \
                share(self._coarse_np, np.asarray(coarse_keys, np.uint32))
        else:
            ex._z_np = cat(self._z_np, z_rows, 0.0)
            ex._w_np = cat(self._w_np, w_rows, FT.HASH_SENTINEL)
            ex._ckeys_np = None if band_keys is None else \
                cat(self._ckeys_np, np.asarray(band_keys, np.uint32),
                    PAD_CORPUS)
            ex._coarse_np = None if coarse_keys is None else \
                cat(self._coarse_np, np.asarray(coarse_keys, np.uint32),
                    PAD_CORPUS)
        if d == 0 and same_pad:
            ex._tids_np, ex._cids_np = self._tids_np, self._cids_np
        else:
            ex._tids_np = cat(self._tids_np, tid_rows, -2)
            ex._cids_np = cat(self._cids_np, cid_rows, -1)

        if d == 0 and n_pad2 == self.n_columns:
            # nothing to upload: share the row bundle outright
            ex._z, ex._zscale, ex._w = self._z, self._zscale, self._w
            ex._cids, ex._tids = self._cids, self._tids
            ex._ckeys, ex._coarse = self._ckeys, self._coarse
            ex._rows_bundle = self._rows_bundle.retain()
            ex.bytes_uploaded = 0
        elif n_pad2 == self.n_columns:
            # same bucket: upload the grain-padded delta, fork on device
            grain = min(-(-d // DELTA_ROW_GRAIN) * DELTA_ROW_GRAIN,
                        n_pad2 - self.n_live)
            row0 = jnp.int32(self.n_live)
            olds: dict = {}
            news: dict = {}

            def stage(key, old_dev, rows, fill):
                olds[key] = old_dev
                news[key] = _pad_to(rows, grain, fill)

            stage("z", self._z, z_rows, 0.0)
            stage("w", self._w, w_rows, FT.HASH_SENTINEL)
            stage("cids", self._cids, cid_rows, -1)
            stage("tids", self._tids, tid_rows, -2)
            if band_keys is not None:
                stage("ckeys", self._ckeys,
                      np.asarray(band_keys, np.uint32), PAD_CORPUS)
            if coarse_keys is not None:
                stage("coarse", self._coarse,
                      np.asarray(coarse_keys, np.uint32), PAD_CORPUS)
            ex.bytes_uploaded = sum(int(v.nbytes) for v in news.values())
            upd = _update_rows_tree(olds, news, row0)
            ex._z, ex._w = upd["z"], upd["w"]
            ex._cids, ex._tids = upd["cids"], upd["tids"]
            ex._ckeys = upd.get("ckeys")
            ex._coarse = upd.get("coarse")
            ex._zscale = self._zscale        # per-feature: no row axis
        else:
            # bucket boundary crossed: re-place at the new padded size
            ex._z = jnp.asarray(ex._z_np)
            ex._zscale = self._zscale
            ex._w = jnp.asarray(ex._w_np)
            ex._cids = jnp.asarray(ex._cids_np)
            ex._tids = jnp.asarray(ex._tids_np)
            ex._ckeys = (jnp.asarray(ex._ckeys_np)
                         if ex._ckeys_np is not None else None)
            ex._coarse = (jnp.asarray(ex._coarse_np)
                          if ex._coarse_np is not None else None)
            ex.bytes_uploaded = sum(
                int(a.nbytes) for a in (ex._z, ex._w, ex._cids, ex._tids,
                                        ex._ckeys, ex._coarse)
                if a is not None)
        ex._rows_bundle = getattr(ex, "_rows_bundle", None) or \
            PlacementBundle(dict(
                z=ex._z, zscale=ex._zscale, w=ex._w, cids=ex._cids,
                tids=ex._tids, ckeys=ex._ckeys, coarse=ex._coarse))
        return ex

    # -- sharded state ------------------------------------------------------

    def _grid_mesh(self, grid: tuple) -> Mesh:
        """(q, d) -> a (query × data × model) mesh over this executor's
        devices (``launch.mesh.make_grid_mesh``, cached per geometry). The
        flat device order is preserved, so a (1, d) grid's data placement
        is byte-identical to the caller's own mesh."""
        from repro.launch.mesh import make_grid_mesh

        grid = tuple(grid)
        if grid not in self._grid_meshes:
            self._grid_meshes[grid] = make_grid_mesh(
                grid[0], grid[1], devices=self.mesh.devices)
        return self._grid_meshes[grid]

    def _plan_mesh_axes(self, plan: QueryPlan):
        """Mesh + (shard_axes, query_axes) a plan executes with.

        (1, d) grids keep the caller's mesh and replicated-query specs —
        the legacy 1-D pipeline, including multi-axis ``shard_axes``;
        q > 1 grids (or a caller mesh that already carries a non-trivial
        ``query`` axis) run on the re-shaped (query × data) grid mesh."""
        names = tuple(getattr(self.mesh, "axis_names", ()))
        premade_q = ("query" in names
                     and int(self.mesh.shape["query"]) > 1)
        if plan.grid[0] == 1 and not premade_q:
            return self.mesh, plan.shard_axes, ()
        return self._grid_mesh(plan.grid), ("data",), ("query",)

    def _corpus(self, plan: QueryPlan) -> dict:
        # one placement per (mesh geometry, data axes): band keys ride
        # along whenever the executor has them, so an "all" plan and a
        # pruned plan (e.g. the recall baseline next to the served plan)
        # share the z/w/cids/tids device copies instead of double-placing
        # the corpus
        mesh, axes, qaxes = self._plan_mesh_axes(plan)
        key = (plan.grid if qaxes else (), axes)
        if key not in self._placed:
            # sharded pipelines run on f32 shards: a quantized sidecar is
            # dequantized once at placement (the per-device shard is what
            # stays resident, so the transient full matrix is host-only)
            z = self._z_np
            if z.dtype != np.float32:
                z = np.asarray(z, np.float32) * self._zscale_np
            self._placed[key] = place_sharded_corpus(
                mesh, axes, z, self._w_np,
                table_ids=self._tids_np, band_keys=self._ckeys_np,
                cids=self._cids_np)
        return self._placed[key]

    def _pipeline(self, plan: QueryPlan):
        mesh, axes, qaxes = self._plan_mesh_axes(plan)
        key = (plan.candidates, plan.k, plan.budget_per_shard, axes,
               plan.grid if qaxes else ())
        if key not in self._pipelines:
            self._pipelines[key] = build_sharded_pipeline(
                mesh, self._gbdt, candidates=plan.candidates,
                k=plan.k,
                budget_per_shard=(plan.budget_per_shard
                                  if plan.candidates != "all" else None),
                shard_axes=axes, query_axes=qaxes, block=self.score_block,
                interpret=_interpret())
        return self._pipelines[key]

    # -- AOT warmup ---------------------------------------------------------

    def aot_compile(self, entries, *, cache=None,
                    n_columns: int | None = None) -> dict:
        """AOT-compile (or load from the persistent executable cache) every
        pipeline the ``(plan, padded_batch)`` pairs in ``entries`` would
        touch, register them in the dispatch table, and pre-seed the
        first-contact set — so a warmed shape's first real request carries
        no ``compile_ms`` attribution and no compile event.

        ``jit(...).lower(...).compile()`` bypasses jax's jit call cache,
        which is exactly why the result must be held in ``self._compiled``
        — and why the persistent-cache path is an honest restart
        measurement: nothing in the process jit cache can serve it.

        Publishes ``executable_cache_hit``/``executable_cache_miss`` per
        unit (with a ``remaining`` countdown the metrics layer exposes as
        the ``warmup_remaining`` gauge) and a ``compile_begin``/``end``
        pair for every fresh compile, so warmup compiles land in the same
        ``compile_ms`` histogram first-contact serving compiles do.
        Inadmissible plans (no band keys / coarse digest / mesh) are
        counted as skips, not errors.

        ``n_columns`` pre-warms for a DIFFERENT corpus size than the
        resident one — the background next-column-bucket warm ahead of a
        bucket-boundary crossing.  Corpus avals are shape stand-ins at
        that size (local plans only; sharded plans are skipped), and the
        compiled executables land in both the dispatch table and the
        persistent cache, keyed by the override size — a successor built
        at that bucket inherits them and serves its first request with
        zero compiles.  Returns a report dict."""
        if self._closed:
            raise RuntimeError("executor is closed")
        cache = cache if cache is not None else self._exec_cache
        units, seen_units, planned, skipped = [], set(), [], 0
        for plan, q in entries:
            us = self._plan_units(plan, int(q), n_columns=n_columns)
            if us is None:
                skipped += 1
                continue
            planned.append((plan, int(q)))
            for u in us:
                if u["key"] not in seen_units:
                    seen_units.add(u["key"])
                    units.append(u)
        report = {"n_plans": len(planned), "n_executables": len(units),
                  "skipped_plans": skipped, "cache_hits": 0,
                  "cache_misses": 0, "already_warm": 0, "compile_ms": 0.0}
        remaining = len(units)
        for u in units:
            remaining -= 1
            if u["key"] in self._compiled:
                report["already_warm"] += 1
                continue
            sig = exe = None
            if cache is not None:
                sig = cache.signature(u["name"], u["statics"],
                                      tree_aval_descriptors(u["dyn"]),
                                      u["mesh_desc"])
                exe = cache.load(sig)
            if exe is not None:
                report["cache_hits"] += 1
                if self._events is not None:
                    self._events.publish("executable_cache_hit",
                                         name=u["name"], n_queries=u["q"],
                                         remaining=remaining)
            else:
                if self._events is not None:
                    self._events.publish("compile_begin", plan=u["name"],
                                         grid=[], n_queries=u["q"], k=0,
                                         source="warmup")
                t0 = time.perf_counter()
                exe = u["lower"]().compile()
                ms = (time.perf_counter() - t0) * 1e3
                report["cache_misses"] += 1
                report["compile_ms"] += ms
                if self._events is not None:
                    self._events.publish("executable_cache_miss",
                                         name=u["name"], n_queries=u["q"],
                                         remaining=remaining)
                    self._events.publish("compile_end", plan=u["name"],
                                         grid=[], n_queries=u["q"], k=0,
                                         ms=ms, source="warmup")
                if cache is not None:
                    cache.store(sig, exe)
            self._compiled[u["key"]] = exe
        for plan, q in planned:
            self._seen_shapes.add((plan.kind, plan.k, plan.budget,
                                   plan.grid, q))
        return report

    def _plan_units(self, plan: QueryPlan, q: int,
                    n_columns: int | None = None):
        """Executable units — dispatch key, dynamic avals, lazy ``lower``
        thunk, cache-signature fields — that ``plan`` touches at padded
        batch ``q``: the scan pipeline, plus the exact-rescore re-rank when
        the resident profiles are quantized.  None when this executor
        cannot serve the plan at all.  ``n_columns`` overrides the corpus
        size (next-bucket pre-warm: corpus avals become shape stand-ins;
        local plans only)."""
        c_over = None if n_columns is None or \
            int(n_columns) == self.n_columns else int(n_columns)
        if (self.n_columns == 0 and c_over is None) or q <= 0:
            return None
        if plan.candidates != "all" and self._ckeys_np is None:
            return None
        if plan.candidates == "tiered" and (plan.sharded or
                                            self._coarse_np is None):
            return None
        if plan.sharded and (self.mesh is None or c_over is not None):
            return None
        fnum = int(self._z_np.shape[1])
        fw = int(self._w_np.shape[1])
        wdt = self._w_np.dtype
        S = jax.ShapeDtypeStruct
        units = []
        if plan.sharded:
            mesh, axes, qaxes = self._plan_mesh_axes(plan)
            corpus = self._corpus(plan)
            # _execute_sharded pads the batch to a multiple of q_shards
            qp = -(-q // plan.grid[0]) * plan.grid[0]
            qsh = NamedSharding(mesh, P(qaxes) if qaxes else P())
            sq = lambda shape, dt: S(shape, dt, sharding=qsh)
            if plan.candidates == "all":
                dyn = (corpus["z"], corpus["w"], corpus["cids"],
                       corpus["tids"], sq((qp, fnum), np.float32),
                       sq((qp, fw), wdt), sq((qp,), np.int32),
                       sq((qp,), np.int32))
            else:
                nb = int(self._ckeys_np.shape[1])
                dyn = (corpus["z"], corpus["w"], corpus["cids"],
                       corpus["tids"], corpus["ckeys"],
                       sq((qp, fnum), np.float32), sq((qp, fw), wdt),
                       sq((qp, nb), np.uint32), sq((qp,), np.int32),
                       sq((qp,), np.int32))
            statics = self._sharded_statics(plan)
            fn = self._pipeline(plan)
            mesh_desc = (tuple(str(a) for a in mesh.axis_names),
                         tuple(int(mesh.shape[a]) for a in mesh.axis_names),
                         tuple(axes), tuple(qaxes))
            units.append(dict(
                key=self._exe_key("sharded", qp, statics), name="sharded",
                q=qp, statics=statics, dyn=dyn, mesh_desc=mesh_desc,
                lower=lambda fn=fn, dyn=dyn: fn.lower(*dyn)))
            if self._fp32_rows is not None:
                # the sharded merge returns min(k, k_local · data shards)
                # columns — that width is the rescore gather's R
                d_total = 1
                for a in axes:
                    d_total *= int(mesh.shape[a])
                local_cols = int(corpus["z"].shape[0]) // max(d_total, 1)
                width = (plan.budget_per_shard
                         if plan.candidates != "all" else local_cols)
                r = min(plan.k, min(plan.k, max(width, 1)) * d_total)
                units.append(self._rescore_unit(q, r, plan.k, fnum, fw, wdt))
        else:
            name, fn, statics = self._local_spec(plan, n_columns=c_over)
            zq, wq = S((q, fnum), np.float32), S((q, fw), wdt)
            tqv, qidv = S((q,), np.int32), S((q,), np.int32)
            qk = (S((q, int(self._ckeys_np.shape[1])), np.uint32)
                  if plan.candidates != "all" else None)
            qc = (S((q, int(self._coarse_np.shape[1])), np.uint32)
                  if plan.candidates == "tiered" else None)
            dyn = self._local_dyn(plan, zq, wq, tqv, qidv, qk, qc,
                                  n_columns=c_over)
            units.append(dict(
                key=self._exe_key(name, q, statics, n_columns=c_over),
                name=name, q=q,
                statics=statics, dyn=dyn, mesh_desc=None,
                lower=lambda fn=fn, dyn=dyn, statics=statics:
                    fn.lower(*dyn, **statics)))
            if self._fp32_rows is not None:
                # local scans over-fetch: the pipeline's static k IS the
                # width of the top set handed to the exact re-rank
                units.append(self._rescore_unit(q, int(statics["k"]),
                                                plan.k, fnum, fw, wdt,
                                                n_columns=c_over))
        return units

    def _rescore_unit(self, q, r, k, fnum, fw, wdt, n_columns=None):
        S = jax.ShapeDtypeStruct
        statics = dict(k=k)
        dyn = (S((q, fnum), np.float32), S((q, fw), wdt),
               S((q, r, fnum), np.float32), S((q, r, fw), wdt),
               self._gbdt, S((q, r), np.float32), S((q, r), np.int32))
        return dict(key=self._exe_key("_rescore_exact", q, statics, (r,),
                                      n_columns=n_columns),
                    name="_rescore_exact", q=q, statics=statics, dyn=dyn,
                    mesh_desc=None,
                    lower=lambda dyn=dyn, k=k:
                        _rescore_exact.lower(*dyn, k=k))

    # -- entry point --------------------------------------------------------

    def execute(self, plan: QueryPlan, zq, wq, tq, qid, qkeys=None,
                qcoarse=None):
        """Run ``plan`` for a query batch.

        ``zq`` (Q, F_NUM) float32, ``wq`` (Q, F_WORDS) uint32; ``tq`` (Q,)
        table ids to exclude (-1 disables); ``qid`` (Q,) global column id
        of resident queries (-1 for external); ``qkeys`` (Q, B) LSH band
        keys, required by pruned plans; ``qcoarse`` (Q, S) super-band
        digest keys, required by tiered plans. Returns numpy
        ``(scores (Q, k), ids (Q, k), n_scored (Q,))``.
        """
        if self._closed:
            raise RuntimeError("executor is closed (its snapshot version "
                               "was retired); pin a live version instead")
        q = int(np.asarray(zq).shape[0])
        if self.n_live == 0 or q == 0:
            return (np.full((q, plan.k), -np.inf, np.float32),
                    np.full((q, plan.k), -1, np.int32),
                    np.zeros((q,), np.int32))
        if plan.candidates != "all":
            if self._ckeys_np is None:
                raise ValueError(f"plan {plan.kind!r} needs LSH band keys, "
                                 f"but this executor has none")
            if qkeys is None:
                raise ValueError(f"plan {plan.kind!r} needs query band keys")
        if plan.candidates == "tiered":
            if plan.sharded:
                raise ValueError("tiered plans are local-only")
            if self._coarse_np is None:
                raise ValueError("plan 'tiered' needs a coarse super-band "
                                 "digest, but this executor has none")
            if qcoarse is None:
                raise ValueError("plan 'tiered' needs coarse query keys")
        if plan.sharded and self.mesh is None:
            raise ValueError(f"plan {plan.kind!r} needs a mesh")
        # first contact with this (kind, k, budget, grid, batch shape)
        # pays the jit trace+compile inside the dispatch below — surface
        # it as a compile_begin/end event pair and stash the wall in a
        # thread-local the engine folds into the request trace
        shape_key = (plan.kind, plan.k, plan.budget, plan.grid, q)
        first = shape_key not in self._seen_shapes
        self._seen_shapes.add(shape_key)
        self._tls.compile_ms = None
        if first and self._events is not None:
            self._events.publish("compile_begin", plan=plan.kind,
                                 grid=list(plan.grid), n_queries=q, k=plan.k)
        t0 = time.perf_counter()
        self._tls.tier_stats = None
        if plan.sharded:
            sc, ids, n = self._execute_sharded(plan, zq, wq, tq, qid, qkeys)
        else:
            sc, ids, n = self._execute_local(plan, zq, wq, tq, qid, qkeys,
                                             qcoarse)
        if self._fp32_rows is not None:
            # exact fp32 re-rank of the quantized scan's top set (local
            # scans over-fetched RESCORE_MULT × k above; sharded scans
            # re-rank their returned k — ordering repaired, no recovery
            # of ids the quantized scan dropped)
            sc, ids = self._rescore(zq, wq, sc, ids, plan.k)
        sc, ids = pad_topk(np.asarray(sc), np.asarray(ids), plan.k)
        n = np.asarray(n)               # block until ready before timing
        if first:
            wall_ms = (time.perf_counter() - t0) * 1e3
            self._tls.compile_ms = wall_ms
            if self._events is not None:
                self._events.publish("compile_end", plan=plan.kind,
                                     grid=list(plan.grid), n_queries=q,
                                     k=plan.k, ms=wall_ms)
        tier = getattr(self._tls, "tier_stats", None)
        if tier is not None and self._events is not None:
            n_hits, n_surv = tier
            frac = float(n_surv.mean()) / max(self.n_live, 1)
            self._events.publish(
                "coarse_pass", n_queries=q, n_columns=self.n_live,
                survivor_budget=plan.survivor_budget,
                hits_mean=float(n_hits.mean()),
                survivors_mean=float(n_surv.mean()),
                survivors_max=int(n_surv.max()), survivor_fraction=frac)
            self._events.publish(
                "fine_probe", n_queries=q, budget=plan.budget,
                survivor_budget=plan.survivor_budget,
                scored_mean=float(n.mean()))
        return sc, ids, n

    def last_compile_ms(self) -> float | None:
        """First-contact compile+execute wall of this thread's most recent
        ``execute`` call, or None when the shape was already warm."""
        return getattr(self._tls, "compile_ms", None)

    # -- internals ----------------------------------------------------------

    def _rescore(self, zq, wq, sc, ids, k: int):
        """Gather the scan's candidate rows from the fp32 source and
        re-rank them exactly.  The gather is (Q, R, F) with R a small
        multiple of k, so the cost is independent of the lake size."""
        ids_np = np.asarray(ids)
        # clip to live rows, not the bucket-padded corpus: the fp32 source
        # may be an unpadded view (invalid slots are -1 → row 0, already
        # excluded by the scan's non-finite score)
        safe = np.clip(ids_np, 0, self.n_live - 1)
        dyn = (jnp.asarray(zq, jnp.float32), jnp.asarray(wq),
               jnp.asarray(np.asarray(self._fp32_rows(safe), np.float32)),
               jnp.asarray(self._w_np[safe]), self._gbdt,
               jnp.asarray(np.asarray(sc)), jnp.asarray(ids_np))
        return self._call("_rescore_exact", _rescore_exact, dyn,
                          dict(k=k), extra=(int(ids_np.shape[1]),))

    # -- AOT dispatch -------------------------------------------------------

    def _exe_key(self, name: str, q: int, statics: dict, extra=(),
                 n_columns: int | None = None) -> tuple:
        # the corpus dimension is part of the executable's identity: a
        # successor inheriting ``_compiled`` across a bucket crossing must
        # not dispatch an old-bucket executable (same statics, different
        # corpus avals), and next-bucket pre-warm entries must land under
        # keys the post-crossing successor actually looks up
        c = self.n_columns if n_columns is None else int(n_columns)
        return (name, int(q), int(c), tuple(sorted(statics.items())),
                tuple(extra))

    def _call(self, name, fn, dyn, statics: dict, extra=()):
        """Dispatch one pipeline call: the AOT-compiled executable when
        warmup registered this exact shape (statics are baked in, only the
        dynamic args are passed), else the plain jitted fallback."""
        exe = self._compiled.get(
            self._exe_key(name, dyn[0].shape[0], statics, extra))
        if exe is not None:
            self._dispatch_stats["aot"] += 1
            return exe(*dyn)
        self._dispatch_stats["fallback"] += 1
        return fn(*dyn, **statics)

    def dispatch_stats(self) -> dict:
        """AOT vs jit-fallback dispatch counts — a warmed engine serving
        only ladder shapes must show zero fallbacks (test-gated)."""
        return dict(self._dispatch_stats)

    def _local_spec(self, plan: QueryPlan, n_columns: int | None = None):
        """(name, fn, statics) of the local pipeline ``plan`` runs — one
        resolution shared by the serving dispatch and AOT warmup, so their
        dispatch keys agree byte-for-byte.  ``n_columns`` overrides the
        clamp dimension for next-bucket pre-warm."""
        c = self.n_columns if n_columns is None else int(n_columns)
        # quantized scans hand an over-fetched top set to the exact fp32
        # re-rank in execute(); fp32 scans keep k as-is
        k = (plan.k if self._fp32_rows is None
             else max(plan.k, RESCORE_MULT * plan.k))
        if plan.candidates == "all":
            return ("_local_all", _local_all,
                    dict(k=min(k, c), block=self.score_block))
        budget = min(plan.budget, c)
        if plan.candidates == "tiered":
            surv = min(max(plan.survivor_budget, budget), c)
            return ("_local_tiered", _local_tiered,
                    dict(k=min(k, budget, surv), budget=min(budget, surv),
                         survivor_budget=surv, block_c=self.survivor_block,
                         interpret=_interpret()))
        return ("_local_pruned", _local_pruned,
                dict(kind=plan.candidates, k=min(k, budget), budget=budget,
                     interpret=_interpret()))

    def _local_dyn(self, plan: QueryPlan, zq, wq, tq, qid, qkeys, qcoarse,
                   n_columns: int | None = None):
        """Dynamic-argument tuple of the local pipeline, in call order.
        With ``n_columns`` set, corpus arrays become shape stand-ins at
        that size (next-bucket pre-warm lowers against the future corpus
        shapes without materializing them)."""
        if n_columns is None:
            z, w = self._z, self._w
            cids, tids = self._cids, self._tids
            ckeys, coarse = self._ckeys, self._coarse
        else:
            S = jax.ShapeDtypeStruct
            c = int(n_columns)
            z = S((c, int(self._z_np.shape[1])), self._z_np.dtype)
            w = S((c, int(self._w_np.shape[1])), self._w_np.dtype)
            cids = S((c,), np.int32)
            tids = S((c,), np.int32)
            ckeys = (S((c, int(self._ckeys_np.shape[1])), np.uint32)
                     if self._ckeys_np is not None else None)
            coarse = (S((c, int(self._coarse_np.shape[1])), np.uint32)
                      if self._coarse_np is not None else None)
        if plan.candidates == "all":
            return (zq, wq, tq, qid, z, self._zscale, w, cids, tids,
                    self._gbdt)
        if plan.candidates == "tiered":
            return (zq, wq, qkeys, qcoarse, tq, qid, z, self._zscale,
                    w, ckeys, coarse, cids, tids, self._gbdt)
        return (zq, wq, qkeys, tq, qid, z, self._zscale, w,
                ckeys, cids, tids, self._gbdt)

    def _sharded_statics(self, plan: QueryPlan) -> dict:
        """Identity of a sharded pipeline for dispatch/cache keys — the
        ``_pipeline`` cache key, spelled as a statics mapping."""
        _, axes, qaxes = self._plan_mesh_axes(plan)
        return dict(candidates=plan.candidates, k=plan.k,
                    budget_per_shard=(plan.budget_per_shard
                                      if plan.candidates != "all" else 0),
                    axes=axes, grid=plan.grid if qaxes else ())

    def _execute_local(self, plan, zq, wq, tq, qid, qkeys, qcoarse=None):
        zq, wq = jnp.asarray(zq, jnp.float32), jnp.asarray(wq)
        tq = jnp.asarray(tq, jnp.int32)
        qid = jnp.asarray(qid, jnp.int32)
        qkeys = jnp.asarray(qkeys) if qkeys is not None else None
        qcoarse = jnp.asarray(qcoarse) if qcoarse is not None else None
        name, fn, statics = self._local_spec(plan)
        dyn = self._local_dyn(plan, zq, wq, tq, qid, qkeys, qcoarse)
        out = self._call(name, fn, dyn, statics)
        if plan.candidates == "tiered":
            sc, ids, n, n_hits, n_surv = out
            self._tls.tier_stats = (np.asarray(n_hits), np.asarray(n_surv))
            return sc, ids, n
        return out

    def _execute_sharded(self, plan, zq, wq, tq, qid, qkeys):
        corpus = self._corpus(plan)
        mesh, _, qaxes = self._plan_mesh_axes(plan)
        # pad the batch to a multiple of the query-axis size; duplicate
        # results are sliced off below
        if qkeys is not None:
            (zq, wq, tq, qid, qkeys), q = pad_rows(
                (zq, wq, tq, qid, qkeys), plan.grid[0])
        else:
            (zq, wq, tq, qid), q = pad_rows((zq, wq, tq, qid),
                                            plan.grid[0])
        qsharding = NamedSharding(mesh, P(qaxes) if qaxes else P())
        put = lambda a, dt=None: jax.device_put(
            np.asarray(a, dt) if dt else np.asarray(a), qsharding)
        if plan.candidates == "all":
            args = (corpus["z"], corpus["w"], corpus["cids"],
                    corpus["tids"], put(zq, np.float32), put(wq),
                    put(tq, np.int32), put(qid, np.int32))
        else:
            args = (corpus["z"], corpus["w"], corpus["cids"],
                    corpus["tids"], corpus["ckeys"],
                    put(zq, np.float32), put(wq),
                    put(qkeys, np.uint32), put(tq, np.int32),
                    put(qid, np.int32))
        key = self._exe_key("sharded", np.asarray(zq).shape[0],
                            self._sharded_statics(plan))
        exe = self._compiled.get(key)
        if exe is not None:
            self._dispatch_stats["aot"] += 1
            sc, ids, n = exe(*args)
        else:
            self._dispatch_stats["fallback"] += 1
            sc, ids, n = self._pipeline(plan)(*args)
        return sc[:q], ids[:q], n[:q]
