"""Unified query-execution layer for join discovery.

Every discovery query — the offline ``core.discovery`` entry points and
the online ``service.DiscoveryEngine`` alike — decomposes into the same
three composable stages:

* **candidate generation** (``stages.candidate_priorities``): full-scan
  mask, LSH bucket probe (Pallas kernel), or hybrid profile-proximity;
* **scoring** (``stages.score_columns`` / ``score_streamed``): GBDT over
  distance features, locally or per (Q-shard, C-shard) tile of a 2-D
  (query × data) device grid via ``shard_map``;
* **top-k merge** (``stages.merge_topk`` + ``merge_topk_sharded`` +
  ``assemble_query_shards``): local ``top_k``, or the two-phase grid
  merge — per-device top-k reduced over the data axis, then one small
  query-axis ``all_gather`` reassembling the batch.

The :class:`Planner` resolves (mode, lake size, batch size, mesh,
candidate budget) into a :class:`QueryPlan` — including the
``grid=(q_shards, d_shards)`` placement dimension — using the analytic
per-stage cost model in ``launch.costmodel`` (injectable), and the
:class:`Executor` runs any plan against one corpus view, caching corpus
placements per grid geometry.

AOT warmup (``Planner.plan_set`` → ``Executor.aot_compile``) pre-compiles
the admissible (bucket × grid × plan kind) executable set before traffic,
backed by the persistent on-disk :class:`ExecutableCache` so a restarted
engine warms from serialized executables instead of re-tracing.
"""
from repro.exec.aot import ExecutableCache, environment_signature
from repro.exec.executor import Executor, pad_rows, pad_topk
from repro.exec.plan import (DEFAULT_BATCH_BUCKETS, MODES, Planner,
                             PlannerConfig, QueryPlan)
from repro.exec.sharded import build_sharded_pipeline, place_sharded_corpus
from repro.exec.stages import CANDIDATE_KINDS

__all__ = [
    "Executor", "pad_rows", "pad_topk",
    "DEFAULT_BATCH_BUCKETS", "MODES", "Planner", "PlannerConfig", "QueryPlan",
    "build_sharded_pipeline", "place_sharded_corpus",
    "CANDIDATE_KINDS", "ExecutableCache", "environment_signature",
]
