"""Unified query-execution layer for join discovery.

Every discovery query — the offline ``core.discovery`` entry points and
the online ``service.DiscoveryEngine`` alike — decomposes into the same
three composable stages:

* **candidate generation** (``stages.candidate_priorities``): full-scan
  mask, LSH bucket probe (Pallas kernel), or hybrid profile-proximity;
* **scoring** (``stages.score_columns`` / ``score_streamed``): GBDT over
  distance features, locally or ``shard_map``-sharded over the mesh;
* **top-k merge** (``stages.merge_topk`` / ``merge_topk_sharded``): local
  ``top_k``, or per-device top-k + one small ``all_gather``.

The :class:`Planner` resolves (mode, lake size, mesh availability,
candidate budget) into a :class:`QueryPlan` using the analytic per-stage
cost model in ``launch.costmodel`` (injectable), and the
:class:`Executor` runs any plan against one corpus view.
"""
from repro.exec.executor import Executor, pad_topk
from repro.exec.plan import MODES, Planner, PlannerConfig, QueryPlan
from repro.exec.sharded import build_sharded_pipeline, place_sharded_corpus
from repro.exec.stages import CANDIDATE_KINDS

__all__ = [
    "Executor", "pad_topk",
    "MODES", "Planner", "PlannerConfig", "QueryPlan",
    "build_sharded_pipeline", "place_sharded_corpus",
    "CANDIDATE_KINDS",
]
