"""Mesh-sharded pipeline builders: per-device stages + one small all_gather.

Column-axis tensors (profiles, word features, global column ids, table
ids, LSH band keys) are sharded over the mesh's batch-like axes with
``shard_map``; query-side tensors and GBDT parameters are replicated.
Every device runs the *same* stage functions as the local pipelines
(``stages.py``) on its shard:

* ``all``    — streamed full scan of the local columns (brute baseline);
* ``lsh`` / ``hybrid`` — the ``lsh_probe`` Pallas kernel over the local
  (C/devices, B) band-key shard, hybrid priority fill, and scoring of at
  most ``ceil(budget / devices)`` local candidates — distributed LSH:
  ``mode="lsh"`` on lakes bigger than one device;

then contributes k rows to a single tiled ``all_gather`` and re-ranks the
k·devices union — collective bytes O(Q·k·devices), independent of lake
size (the ``rank_sharded`` merge pattern, now shared by every plan).

``n_scored`` is the **global** count of candidate columns actually scored
(per-device counts ``psum``-ed over the shard axes), so candidate-fraction
and recall accounting stay honest under sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import features as FT
from repro.exec import stages
from repro.kernels.lsh_probe import PAD_CORPUS


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad, constant_values=fill)


def place_sharded_corpus(mesh: Mesh, shard_axes, z: np.ndarray, w: np.ndarray,
                         table_ids: np.ndarray | None = None,
                         band_keys: np.ndarray | None = None) -> dict:
    """Pad the column axis to a multiple of the shard count and device_put
    the corpus tensors for a sharded pipeline.

    Returns ``{"z", "w", "cids", "rep"[, "tids"][, "ckeys"]}`` — ``cids``
    are global column ids (-1 on padding), ``tids`` pad with -2 (matches no
    real table and no disabled-query sentinel), ``ckeys`` pad with the
    probe kernel's corpus sentinel, ``rep`` is the replicated sharding for
    the query-side tensors.
    """
    n = z.shape[0]
    n_shards = int(np.prod([mesh.shape[a] for a in shard_axes]))
    n_pad = -(-n // n_shards) * n_shards
    shard = NamedSharding(mesh, P(tuple(shard_axes)))
    out = {
        "z": jax.device_put(_pad_to(z.astype(np.float32), n_pad, 0.0), shard),
        "w": jax.device_put(_pad_to(w, n_pad, FT.HASH_SENTINEL), shard),
        "cids": jax.device_put(
            _pad_to(np.arange(n, dtype=np.int32), n_pad, -1), shard),
        "rep": NamedSharding(mesh, P()),
    }
    if table_ids is not None:
        out["tids"] = jax.device_put(
            _pad_to(np.asarray(table_ids, np.int32), n_pad, -2), shard)
    if band_keys is not None:
        out["ckeys"] = jax.device_put(
            _pad_to(np.asarray(band_keys, np.uint32), n_pad, PAD_CORPUS),
            shard)
    return out


def build_sharded_pipeline(mesh: Mesh, gbdt_tuple, *, candidates: str = "all",
                           k: int, budget_per_shard: int | None = None,
                           shard_axes=("data",), block: int = 4096,
                           interpret: bool = True):
    """Jitted sharded candidate→score→merge pipeline over ``mesh``.

    ``candidates="all"``: fn(z, w, cids, tids, zq, wq, tq, qid);
    otherwise:            fn(z, w, cids, tids, ckeys, zq, wq, qkeys, tq, qid).
    Both return replicated (scores (Q, k'), global ids (Q, k'),
    n_scored (Q,)) with k' = min(k, columns visible to the merge).
    """
    from jax.experimental.shard_map import shard_map

    axes = tuple(shard_axes)

    def _merge(s_local, cand_ids, n_local_per_q):
        ls, lids = stages.merge_topk(s_local, cand_ids, k)
        gs, gi = stages.merge_topk_sharded(ls, lids, k, axes)
        n_scored = n_local_per_q
        for ax in axes:
            n_scored = jax.lax.psum(n_scored, ax)
        return gs, gi, n_scored

    if candidates == "all":
        def local_fn(z, w, cids, tids, zq, wq, tq, qid):
            s = stages.score_streamed(zq, wq, z, w, gbdt_tuple, block=block)
            s = jnp.where(stages.exclusion_mask(cids, tids, tq, qid),
                          -jnp.inf, s)
            n_live = jnp.sum((cids >= 0).astype(jnp.int32))
            n_per_q = jnp.full((zq.shape[0],), n_live, jnp.int32)
            return _merge(s, cids, n_per_q)

        in_specs = (P(axes), P(axes), P(axes), P(axes), P(), P(), P(), P())
    else:
        if budget_per_shard is None:
            raise ValueError("pruned sharded pipeline needs budget_per_shard")

        def local_fn(z, w, cids, tids, ckeys, zq, wq, qkeys, tq, qid):
            prio = stages.candidate_priorities(
                candidates, zq, qkeys, z, ckeys, cids, tids, tq, qid,
                interpret=interpret)
            m = min(budget_per_shard, z.shape[0])
            pos, valid = stages.gather_candidates(prio, m)
            s = stages.score_columns(zq, wq, z[pos], w[pos], gbdt_tuple)
            s = jnp.where(valid, s, -jnp.inf)
            return _merge(s, cids[pos], valid.sum(axis=1).astype(jnp.int32))

        in_specs = (P(axes), P(axes), P(axes), P(axes), P(axes),
                    P(), P(), P(), P(), P())

    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=(P(), P(), P()), check_rep=False)
    return jax.jit(fn)
