"""Grid-sharded pipeline builders: per-tile stages + a two-phase merge.

The mesh is a 2-D **(query × data) device grid**: column-axis tensors
(profiles, word features, global column ids, table ids, LSH band keys)
shard over the ``data``-like axes with ``shard_map``, and the query batch
shards over the ``query`` axes — each device runs the *same* stage
functions as the local pipelines (``stages.py``) on its
(Q-shard, C-shard) tile:

* ``all``    — streamed full scan of the local columns (brute baseline);
* ``lsh`` / ``hybrid`` — the ``lsh_probe`` Pallas kernel over the local
  (Q/q_shards, B) × (C/d_shards, B) key tile, hybrid priority fill, and
  scoring of at most ``ceil(budget / d_shards)`` local candidates per
  local query — distributed LSH on both axes.

The merge is two-phase: ``merge_topk_sharded`` reduces each query shard's
rows over the DATA axes (one tiled ``all_gather`` of k-row tiles,
collective bytes O(Q_local·k·d_shards)), then ``assemble_query_shards``
re-assembles the batch over the QUERY axes (O(Q·k), lake-size free).
``query_axes=()`` degrades to the 1-D data-sharded pipeline of earlier
revisions: the query batch is replicated and phase 2 is a no-op — the
same code path serves every grid geometry, which is what the
mesh-geometry parity suite (``tests/test_grid.py``) locks in.

``n_scored`` is the **global** count of candidate columns actually scored
per query: per-device counts ``psum`` over the DATA axes only (summing
over the query axes would double-count every query by q_shards), then
ride the phase-2 gather back to batch order — candidate-fraction and
recall accounting stay honest on any grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import features as FT
from repro.exec import stages
from repro.kernels.lsh_probe import PAD_CORPUS


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad, constant_values=fill)


def place_sharded_corpus(mesh: Mesh, shard_axes, z: np.ndarray, w: np.ndarray,
                         table_ids: np.ndarray | None = None,
                         band_keys: np.ndarray | None = None,
                         cids: np.ndarray | None = None) -> dict:
    """Pad the column axis to a multiple of the data-shard count and
    device_put the corpus tensors for a sharded pipeline.

    Returns ``{"z", "w", "cids"[, "tids"][, "ckeys"]}`` — ``cids`` are
    global column ids (-1 on padding; pass ``cids`` explicitly when the
    caller's rows are already bucket-padded with sentinel rows, so
    arange does not assign real ids to them), ``tids`` pad with -2
    (matches no real table and no disabled-query sentinel), ``ckeys``
    pad with the probe kernel's corpus sentinel. On a grid mesh,
    ``P(shard_axes)`` replicates each column shard across the query (and
    model) axes automatically; query-side tensors are placed by the
    executor with the plan's own query-axis sharding.
    """
    n = z.shape[0]
    n_shards = int(np.prod([mesh.shape[a] for a in shard_axes]))
    n_pad = -(-n // n_shards) * n_shards
    shard = NamedSharding(mesh, P(tuple(shard_axes)))
    if cids is None:
        cids = np.arange(n, dtype=np.int32)
    out = {
        "z": jax.device_put(_pad_to(z.astype(np.float32), n_pad, 0.0), shard),
        "w": jax.device_put(_pad_to(w, n_pad, FT.HASH_SENTINEL), shard),
        "cids": jax.device_put(
            _pad_to(np.asarray(cids, np.int32), n_pad, -1), shard),
    }
    if table_ids is not None:
        out["tids"] = jax.device_put(
            _pad_to(np.asarray(table_ids, np.int32), n_pad, -2), shard)
    if band_keys is not None:
        out["ckeys"] = jax.device_put(
            _pad_to(np.asarray(band_keys, np.uint32), n_pad, PAD_CORPUS),
            shard)
    return out


def build_sharded_pipeline(mesh: Mesh, gbdt_tuple, *, candidates: str = "all",
                           k: int, budget_per_shard: int | None = None,
                           shard_axes=("data",), query_axes=(),
                           block: int = 4096, interpret: bool = True):
    """Jitted grid-sharded candidate→score→merge pipeline over ``mesh``.

    ``candidates="all"``: fn(z, w, cids, tids, zq, wq, tq, qid);
    otherwise:            fn(z, w, cids, tids, ckeys, zq, wq, qkeys, tq, qid).
    Corpus tensors shard over ``shard_axes``; query-side tensors shard
    over ``query_axes`` (replicated when empty — the 1-D pipeline). Both
    forms return replicated (scores (Q, k'), global ids (Q, k'),
    n_scored (Q,)) with k' = min(k, columns visible to the merge); the
    query batch must be divisible by the query-axis size (the executor
    pads).
    """
    from jax.experimental.shard_map import shard_map

    axes = tuple(shard_axes)
    qaxes = tuple(query_axes)
    qspec = P(qaxes) if qaxes else P()

    def _merge(s_local, cand_ids, n_local_per_q):
        ls, lids = stages.merge_topk(s_local, cand_ids, k)
        gs, gi = stages.merge_topk_sharded(ls, lids, k, axes)
        n_scored = n_local_per_q
        for ax in axes:                      # DATA axes only — the query
            n_scored = jax.lax.psum(n_scored, ax)   # axis would double-count
        return stages.assemble_query_shards(gs, gi, n_scored, qaxes)

    if candidates == "all":
        def local_fn(z, w, cids, tids, zq, wq, tq, qid):
            s = stages.score_streamed(zq, wq, z, w, gbdt_tuple, block=block)
            s = jnp.where(stages.exclusion_mask(cids, tids, tq, qid),
                          -jnp.inf, s)
            n_live = jnp.sum((cids >= 0).astype(jnp.int32))
            n_per_q = jnp.full((zq.shape[0],), n_live, jnp.int32)
            return _merge(s, cids, n_per_q)

        in_specs = (P(axes), P(axes), P(axes), P(axes),
                    qspec, qspec, qspec, qspec)
    else:
        if budget_per_shard is None:
            raise ValueError("pruned sharded pipeline needs budget_per_shard")

        def local_fn(z, w, cids, tids, ckeys, zq, wq, qkeys, tq, qid):
            prio = stages.candidate_priorities(
                candidates, zq, qkeys, z, ckeys, cids, tids, tq, qid,
                interpret=interpret)
            m = min(budget_per_shard, z.shape[0])
            pos, valid = stages.gather_candidates(prio, m)
            s = stages.score_columns(zq, wq, z[pos], w[pos], gbdt_tuple)
            s = jnp.where(valid, s, -jnp.inf)
            return _merge(s, cids[pos], valid.sum(axis=1).astype(jnp.int32))

        in_specs = (P(axes), P(axes), P(axes), P(axes), P(axes),
                    qspec, qspec, qspec, qspec, qspec)

    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=(P(), P(), P()), check_rep=False)
    return jax.jit(fn)
