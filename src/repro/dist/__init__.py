"""Distributed utilities: logical-axis sharding rules + gradient compression."""
