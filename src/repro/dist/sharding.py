"""MaxText-style logical-axis sharding rules.

Every ``init_*`` in ``repro.models`` returns a spec pytree whose leaves are
tuples of *logical* axis names (``"embed"``, ``"heads"``, ``"mlp"``, ...;
see ``models/layers.py``). This module owns the single mapping from logical
axes to physical mesh axes, switched by a process-global *mode*:

* ``tp``   — tensor parallel: head/mlp/vocab/expert axes over ``model``;
* ``fsdp`` — tp + the ``embed`` axis sharded over the batch axes
  (parameter-sharded data parallelism);
* ``dp``   — pure data parallel: parameters fully replicated.

``batch_axes`` names the mesh axes that carry the batch (``data``, plus
``pod`` on multi-pod meshes); optimizer moments get an extra ZeRO-1 shard
over those axes via ``zero1_shardings``.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MODE = "tp"
_MODES = ("tp", "fsdp", "dp")

# logical axes that ride the model axis under tensor parallelism
_MODEL_AXES = ("heads", "kv", "mlp", "vocab", "expert", "conv", "state")


def set_mode(mode: str) -> None:
    global _MODE
    if mode not in _MODES:
        raise ValueError(f"unknown sharding mode {mode!r}; want one of {_MODES}")
    _MODE = mode


def get_mode() -> str:
    return _MODE


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that carry the global batch (pod-major on multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _is_spec(v) -> bool:
    return isinstance(v, tuple)


def _physical(logical: str, mesh: Mesh):
    """Mesh axis (or axes tuple) for one logical axis under the current mode."""
    if _MODE == "dp":
        return None
    if logical in _MODEL_AXES and "model" in mesh.axis_names:
        return "model"
    if logical == "embed" and _MODE == "fsdp":
        ba = batch_axes(mesh)
        return ba if ba else None
    return None


def spec_of(spec: tuple, mesh: Mesh) -> P:
    """Logical spec tuple -> PartitionSpec under the current mode."""
    return P(*[_physical(s, mesh) for s in spec])


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    axes = phys if isinstance(phys, tuple) else (phys,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit_spec(spec: tuple, shape, mesh: Mesh) -> P:
    """spec_of with a divisibility check: a dimension that does not divide
    evenly over its mesh axes falls back to replication (small/reduced
    configs on big meshes)."""
    axes = []
    for logical, dim in zip(spec, shape):
        phys = _physical(logical, mesh)
        n = _axis_size(mesh, phys)
        axes.append(phys if n > 1 and dim % n == 0 and dim >= n else None)
    return P(*axes)


def param_shardings(specs, mesh: Mesh, params=None):
    """NamedSharding pytree for parameters.

    ``params`` (arrays or ShapeDtypeStructs) enables the divisibility
    fallback; without it the raw mode rules apply.
    """
    if params is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, spec_of(s, mesh)), specs,
            is_leaf=_is_spec)
    return jax.tree.map(
        lambda s, p: NamedSharding(mesh, _fit_spec(s, p.shape, mesh)),
        specs, params, is_leaf=_is_spec)


def zero1_shardings(specs, params, mesh: Mesh):
    """Optimizer-moment shardings: the param sharding plus a ZeRO-1 shard of
    the largest still-replicated dimension over the batch axes."""
    ba = batch_axes(mesh)
    nba = _axis_size(mesh, ba)

    def one(spec, p):
        axes = list(_fit_spec(spec, p.shape, mesh))
        if nba > 1:
            order = sorted(range(len(axes)), key=lambda i: -p.shape[i])
            for i in order:
                if axes[i] is None and p.shape[i] % nba == 0 and p.shape[i] >= nba:
                    axes[i] = ba
                    break
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(one, specs, params, is_leaf=_is_spec)
