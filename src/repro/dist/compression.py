"""int8 error-feedback gradient all-reduce.

Each device quantizes ``g + ef`` to int8 with a per-tensor scale, all-reduces
the int8 payload (summed in int32, averaged), and keeps the quantization
residual in the error-feedback buffer — the classic EF-SGD construction: the
per-step quantization error is bounded by ``scale/2`` and the accumulated
bias cancels across steps because the residual is re-injected.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_int8_ef_allreduce(mesh: Mesh, axes: tuple[str, ...]):
    """Returns ``(init, compress)``.

    ``init(grads)`` builds the zero error-feedback state.
    ``compress(grads, ef)`` -> ``(grads_hat, ef_new)`` where ``grads_hat`` is
    the dequantized, all-reduced (mean over ``axes``) gradient.
    Inputs/outputs are replicated; the int8 wire format lives inside the
    shard_map body (on hardware the all-reduce moves 1/4 of the f32 bytes).
    """
    axes = tuple(axes)
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))

    def init(grads):
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def _one(g, ef):
        e = g.astype(jnp.float32) + ef
        scale = jnp.maximum(jnp.max(jnp.abs(e)), 1e-30) / 127.0
        q = jnp.clip(jnp.round(e / scale), -127, 127).astype(jnp.int8)
        # all-reduce the int8 payload (int32 accumulator), mean over devices;
        # scales are tiny and all-reduced in f32
        qs = jax.lax.psum(q.astype(jnp.int32), axes)
        ss = jax.lax.psum(scale, axes)
        g_hat = qs.astype(jnp.float32) * (ss / n_dev) / n_dev
        ef_new = e - q.astype(jnp.float32) * scale
        return g_hat, ef_new

    def body(grads, ef):
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        outs = [_one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
                jax.tree.unflatten(treedef, [o[1] for o in outs]))

    rep = P()

    @jax.jit
    def compress(grads, ef):
        specs_in = (jax.tree.map(lambda _: rep, grads),
                    jax.tree.map(lambda _: rep, ef))
        fn = shard_map(body, mesh=mesh, in_specs=specs_in,
                       out_specs=specs_in, check_rep=False)
        return fn(grads, ef)

    return init, compress
