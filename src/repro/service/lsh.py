"""Banded-MinHash LSH candidate generation over catalog signatures.

Classic banding: split each (P,)-permutation MinHash signature into B bands
of r = P/B rows, hash every band to a 32-bit bucket key, and call a column a
*candidate* for a query iff they share a bucket in at least one band. Two
columns with set Jaccard J collide with probability ``1 - (1 - J^r)^B`` —
the (B, r) knob trades recall against pruning, and ``measure_tradeoff``
reports both so the operator can pick a point on the curve.

The probe itself is the device-side batched kernel ``kernels/lsh_probe``:
(Q, B) query keys against the resident (C, B) catalog keys in one pass —
uint32 equality compares instead of GBDT trees, which is why generating
candidates for *every* concurrent query costs less than fully scoring one.

On a mesh, the (C, B) key matrix is sharded over the column axis exactly
like the profiles (``repro.exec.sharded.place_sharded_corpus`` pads with
the kernel's corpus sentinel): every device probes its own shard, so the
candidate stage scales with the lake alongside the scorer.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.lsh_probe import PAD_CORPUS, PAD_QUERY

_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    n_bands: int = 64          # bands; rows per band = n_perm // n_bands

    def rows_per_band(self, n_perm: int) -> int:
        r = n_perm // self.n_bands
        if r < 1:
            raise ValueError(
                f"n_bands={self.n_bands} exceeds signature width {n_perm}")
        return r


def band_keys(signatures: np.ndarray, n_bands: int) -> np.ndarray:
    """(C, P) uint32 MinHash signatures -> (C, B) uint32 bucket keys.

    FNV-1a over the r rows of each band, folded to 32 bits; keys are kept
    clear of the probe-kernel padding sentinels.
    """
    c, p = signatures.shape
    cfg = LSHConfig(n_bands=n_bands)
    r = cfg.rows_per_band(p)
    s = signatures[:, :n_bands * r].reshape(c, n_bands, r).astype(np.uint64)
    h = np.full((c, n_bands), _FNV_OFFSET, np.uint64)
    for i in range(r):
        h = (h ^ s[:, :, i]) * _FNV_PRIME
    k = ((h >> np.uint64(32)) ^ (h & np.uint64(0xFFFFFFFF))).astype(np.uint32)
    return np.where(k >= PAD_CORPUS, k - np.uint32(7), k)


@dataclasses.dataclass
class LSHIndex:
    """Bucket keys for the resident catalog + the device probe."""

    config: LSHConfig
    keys: np.ndarray               # (C, B) uint32

    @classmethod
    def build(cls, signatures: np.ndarray, config: LSHConfig = LSHConfig()):
        return cls(config=config,
                   keys=band_keys(signatures, config.n_bands))

    @property
    def n_columns(self) -> int:
        return int(self.keys.shape[0])

    def query_keys(self, signatures_q: np.ndarray) -> np.ndarray:
        return band_keys(signatures_q, self.config.n_bands)

    def hit_mask(self, qkeys: np.ndarray) -> jnp.ndarray:
        """(Q, B) query keys -> (Q, C) int32 candidate mask (device)."""
        return ops.lsh_probe(qkeys, self.keys)

    def candidate_fraction(self, qkeys: np.ndarray) -> float:
        """Mean fraction of the lake a query's candidate set covers."""
        m = np.asarray(self.hit_mask(qkeys))
        return float(m.mean()) if m.size else 0.0


def measure_tradeoff(signatures: np.ndarray, full_topk_ids: np.ndarray,
                     query_rows: np.ndarray, band_choices=(16, 32, 64, 128)):
    """Recall-vs-pruning curve: for each band count, the fraction of the
    brute-force top-k retained in the candidate set vs the fraction of the
    lake probed. ``query_rows`` indexes the querying columns; rows of
    ``full_topk_ids`` < 0 are padding."""
    out = []
    for nb in band_choices:
        if nb > signatures.shape[1]:
            continue
        idx = LSHIndex.build(signatures, LSHConfig(n_bands=nb))
        mask = np.asarray(idx.hit_mask(idx.keys[query_rows]))
        hit, tot = 0, 0
        for qi, row in enumerate(full_topk_ids):
            valid = row[row >= 0]
            hit += int(mask[qi, valid].sum())
            tot += int(valid.size)
        out.append({"n_bands": nb,
                    "rows_per_band": signatures.shape[1] // nb,
                    "recall": hit / max(tot, 1),
                    "candidate_fraction": float(mask.mean())})
    return out
