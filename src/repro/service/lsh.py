"""Banded-MinHash LSH candidate generation over catalog signatures.

Classic banding: split each (P,)-permutation MinHash signature into B bands
of r = P/B rows, hash every band to a 32-bit bucket key, and call a column a
*candidate* for a query iff they share a bucket in at least one band. Two
columns with set Jaccard J collide with probability ``1 - (1 - J^r)^B`` —
the (B, r) knob trades recall against pruning, and ``measure_tradeoff``
reports both so the operator can pick a point on the curve.

The probe itself is the device-side batched kernel ``kernels/lsh_probe``:
(Q, B) query keys against the resident (C, B) catalog keys in one pass —
uint32 equality compares instead of GBDT trees, which is why generating
candidates for *every* concurrent query costs less than fully scoring one.

On a mesh, the (C, B) key matrix is sharded over the column axis exactly
like the profiles (``repro.exec.sharded.place_sharded_corpus`` pads with
the kernel's corpus sentinel): every device probes its own shard, so the
candidate stage scales with the lake alongside the scorer.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.lsh_probe import PAD_CORPUS, PAD_QUERY

_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)

# geometries we have already warned about (``(n_perm, n_bands)`` pairs
# where the signature width does not divide evenly into bands)
_REMAINDER_WARNED: set[tuple[int, int]] = set()


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    n_bands: int = 64          # fine bands; rows per band = n_perm // n_bands
    n_coarse_bands: int = 16   # single-row super-bands for the coarse tier

    def rows_per_band(self, n_perm: int) -> int:
        r = n_perm // self.n_bands
        if r < 1:
            raise ValueError(
                f"n_bands={self.n_bands} exceeds signature width {n_perm}")
        return r


def _fold32(h: np.ndarray) -> np.ndarray:
    k = ((h >> np.uint64(32)) ^ (h & np.uint64(0xFFFFFFFF))).astype(np.uint32)
    return np.where(k >= PAD_CORPUS, k - np.uint32(7), k)


def band_keys(signatures: np.ndarray, n_bands: int) -> np.ndarray:
    """(C, P) uint32 MinHash signatures -> (C, B) uint32 bucket keys.

    FNV-1a over the r rows of each band, folded to 32 bits; keys are kept
    clear of the probe-kernel padding sentinels.  When ``P % B != 0`` the
    ``P - B*r`` trailing permutation rows are folded into the *last* band
    (with a one-time warning) rather than silently discarded, so every
    signature bit contributes to some bucket.
    """
    c, p = signatures.shape
    cfg = LSHConfig(n_bands=n_bands)
    r = cfg.rows_per_band(p)
    used = n_bands * r
    s = signatures[:, :used].reshape(c, n_bands, r).astype(np.uint64)
    h = np.full((c, n_bands), _FNV_OFFSET, np.uint64)
    for i in range(r):
        h = (h ^ s[:, :, i]) * _FNV_PRIME
    if p != used:
        key = (p, n_bands)
        if key not in _REMAINDER_WARNED:
            _REMAINDER_WARNED.add(key)
            warnings.warn(
                f"band_keys: signature width {p} does not divide into "
                f"{n_bands} bands of {r} rows; folding the {p - used} "
                f"trailing permutation rows into the last band",
                RuntimeWarning, stacklevel=2)
        tail = signatures[:, used:].astype(np.uint64)    # (C, p-used)
        for i in range(p - used):
            h[:, -1] = (h[:, -1] ^ tail[:, i]) * _FNV_PRIME
    return _fold32(h)


def coarse_band_keys(signatures: np.ndarray, n_coarse_bands: int) -> np.ndarray:
    """(C, P) signatures -> (C, S) single-row *super-band* digest keys.

    The coarse tier samples S evenly-spaced permutation rows and hashes
    each on its own (rows-per-band = 1).  A single-row band collides with
    probability J (the raw Jaccard) — far more permissive per band than a
    multi-row fine band's J^r — so a small S already catches essentially
    every pair the fine tier would keep, while probing only S uint32
    lanes per column instead of B fine keys plus the proxy matmul.
    """
    c, p = signatures.shape
    if n_coarse_bands > p:
        raise ValueError(
            f"n_coarse_bands={n_coarse_bands} exceeds signature width {p}")
    rows = (np.arange(n_coarse_bands) * p) // n_coarse_bands
    s = signatures[:, rows].astype(np.uint64)            # (C, S)
    h = (_FNV_OFFSET ^ s) * _FNV_PRIME
    return _fold32(h)


@dataclasses.dataclass
class LSHIndex:
    """Bucket keys for the resident catalog + the device probe.

    Two tiers live side by side: the fine (C, B) band keys the classic
    probe uses, and a small (C, S) coarse super-band digest the tiered
    candidate path scans first to pick survivor blocks.
    """

    config: LSHConfig
    keys: np.ndarray               # (C, B) uint32 fine band keys
    coarse: np.ndarray | None = None   # (C, S) uint32 super-band digest

    @classmethod
    def build(cls, signatures: np.ndarray, config: LSHConfig = LSHConfig()):
        coarse = None
        if 0 < config.n_coarse_bands <= signatures.shape[1]:
            coarse = coarse_band_keys(signatures, config.n_coarse_bands)
        return cls(config=config,
                   keys=band_keys(signatures, config.n_bands),
                   coarse=coarse)

    @property
    def n_columns(self) -> int:
        return int(self.keys.shape[0])

    def extend(self, new_signatures: np.ndarray) -> "LSHIndex":
        """Index with ``new_signatures``'s rows appended — byte-identical
        to a fresh :meth:`build` over the concatenated signature matrix.

        Both key functions are pure per row (the remainder fold touches
        only each row's own trailing permutations), so an append-only
        ingest delta costs O(delta), not O(lake): only the new rows are
        hashed and the resident key matrices are reused as-is.
        """
        new_signatures = np.asarray(new_signatures)
        if new_signatures.shape[0] == 0:
            return self
        new_keys = band_keys(new_signatures, self.config.n_bands)
        coarse = self.coarse
        if coarse is not None:
            coarse = np.concatenate(
                [coarse, coarse_band_keys(new_signatures,
                                          self.config.n_coarse_bands)])
        return LSHIndex(config=self.config,
                        keys=np.concatenate([self.keys, new_keys]),
                        coarse=coarse)

    def retract(self, keep_mask: np.ndarray) -> "LSHIndex":
        """Index restricted to the rows where ``keep_mask`` is True —
        byte-identical to a fresh :meth:`build` over the kept signatures
        (per-row purity again: dropping rows never perturbs survivors)."""
        keep = np.asarray(keep_mask, bool)
        if keep.shape != (self.n_columns,):
            raise ValueError(
                f"keep_mask shape {keep.shape} != ({self.n_columns},)")
        return LSHIndex(config=self.config, keys=self.keys[keep],
                        coarse=None if self.coarse is None
                        else self.coarse[keep])

    def query_keys(self, signatures_q: np.ndarray) -> np.ndarray:
        return band_keys(signatures_q, self.config.n_bands)

    def coarse_query_keys(self, signatures_q: np.ndarray) -> np.ndarray:
        """(Q, P) query signatures -> (Q, S) super-band digest keys."""
        if self.coarse is None:
            raise ValueError("index was built without a coarse digest")
        return coarse_band_keys(signatures_q, self.config.n_coarse_bands)

    def hit_mask(self, qkeys: np.ndarray) -> jnp.ndarray:
        """(Q, B) query keys -> (Q, C) int32 candidate mask (device)."""
        return ops.lsh_probe(qkeys, self.keys)

    def coarse_hit_mask(self, qkeys_coarse: np.ndarray) -> jnp.ndarray:
        """(Q, S) coarse keys -> (Q, C) int32 survivor mask (device)."""
        if self.coarse is None:
            raise ValueError("index was built without a coarse digest")
        return ops.lsh_probe(qkeys_coarse, self.coarse)

    def candidate_fraction(self, qkeys: np.ndarray) -> float:
        """Mean fraction of the lake a query's candidate set covers."""
        m = np.asarray(self.hit_mask(qkeys))
        return float(m.mean()) if m.size else 0.0

    def coarse_fraction(self, qkeys_coarse: np.ndarray) -> float:
        """Mean fraction of the lake surviving the coarse pass."""
        m = np.asarray(self.coarse_hit_mask(qkeys_coarse))
        return float(m.mean()) if m.size else 0.0


def measure_tradeoff(signatures: np.ndarray, full_topk_ids: np.ndarray,
                     query_rows: np.ndarray, band_choices=(16, 32, 64, 128)):
    """Recall-vs-pruning curve: for each band count, the fraction of the
    brute-force top-k retained in the candidate set vs the fraction of the
    lake probed. ``query_rows`` indexes the querying columns; rows of
    ``full_topk_ids`` < 0 are padding."""
    out = []
    for nb in band_choices:
        if nb > signatures.shape[1]:
            continue
        idx = LSHIndex.build(signatures, LSHConfig(n_bands=nb))
        mask = np.asarray(idx.hit_mask(idx.keys[query_rows]))
        hit, tot = 0, 0
        for qi, row in enumerate(full_topk_ids):
            valid = row[row >= 0]
            hit += int(mask[qi, valid].sum())
            tot += int(valid.size)
        out.append({"n_bands": nb,
                    "rows_per_band": signatures.shape[1] // nb,
                    "recall": hit / max(tot, 1),
                    "candidate_fraction": float(mask.mean())})
    return out
