"""Persistent on-disk column catalog — the serving-grade lake index.

The paper's point is that a column's footprint in the index is a few KB of
profile; this module makes that index *durable and incremental* so a lake
can grow (or shrink) without reprofiling:

* every ``add_table`` profiles the new columns on-device, MinHashes their
  values, and writes one immutable **delta segment** (plain ``.npy`` files +
  a JSON sidecar) — the running service never rewrites old segments;
* ``drop_table`` is a manifest tombstone (O(1));
* ``compact()`` merges live segments into one and clears tombstones;
  passing ``n_perm=`` / ``minhash_seed=`` **re-signs** every live column
  from the per-segment value sketches (``values.npy``) instead of silently
  keeping stale signatures, so the LSH geometry can be retuned without
  re-ingesting the lake;
* ``snapshot()`` materializes the live columns (profiles, signatures,
  table/column metadata) for the query engine; segment arrays are read with
  ``mmap_mode`` so a snapshot touches only the bytes it concatenates.

Layout::

    <root>/MANIFEST.json
    <root>/seg-00000001/{numeric,words,n_rows,sigs,table_ids}.npy
    <root>/seg-00000001/values.npy     # folded value hashes (re-sign source)
    <root>/seg-00000001/meta.json      # column names, table name -> id

The manifest is the single source of truth and is replaced atomically;
a crash mid-``add_table`` leaves at worst an orphaned segment directory
that the manifest never references.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Iterable, Sequence

import numpy as np

from repro.core import features as FT
from repro.core.ingest import ColumnBatch, ingest_string_columns
from repro.core.profiles import LakeProfiles, compute_profiles_batch
from repro.kernels import ops

MANIFEST = "MANIFEST.json"
_PROFILE_PAD_C = 8     # pad column counts so repeated adds reuse compiles


def profile_and_sign(batch: ColumnBatch, n_perm: int, seed: int,
                     pad_c: int = _PROFILE_PAD_C):
    """Profile + MinHash a batch on-device -> (numeric, words, sigs).

    The single implementation both the catalog ingest path and the engine's
    external-query path use, so uploaded columns are profiled exactly like
    resident ones. Column count is padded to a multiple of ``pad_c`` and
    rows to the next power of two so repeated small batches hit the same
    compiled shapes.
    """
    import jax.numpy as jnp
    c, r = batch.values32.shape
    cp = -(-c // pad_c) * pad_c
    rp = max(1 << (max(r, 1) - 1).bit_length(), 16)
    v = np.full((cp, rp), FT.HASH_SENTINEL, np.uint32)
    cl = np.zeros((cp, rp), np.float32)
    wc = np.zeros((cp, rp), np.float32)
    nr = np.zeros((cp,), np.int32)
    v[:c, :r] = batch.values32
    cl[:c, :r] = batch.char_len
    wc[:c, :r] = batch.word_cnt
    nr[:c] = batch.n_rows
    num, words = compute_profiles_batch(jnp.asarray(v), jnp.asarray(cl),
                                        jnp.asarray(wc), jnp.asarray(nr))
    sigs = ops.minhash(v, n_perm=n_perm, seed=seed)
    return (np.asarray(num[:c], np.float32),
            np.asarray(words[:c], np.uint32),
            np.asarray(sigs[:c], np.uint32))


def _slice_batch(batch: ColumnBatch, idx: np.ndarray) -> ColumnBatch:
    return ColumnBatch(
        values32=batch.values32[idx], char_len=batch.char_len[idx],
        word_cnt=batch.word_cnt[idx], n_rows=batch.n_rows[idx],
        names=[batch.names[i] for i in idx],
        table_ids=batch.table_ids[idx])


@dataclasses.dataclass
class CatalogSnapshot:
    """Materialized live view of the catalog (what the engine serves from)."""

    profiles: LakeProfiles          # zscored lazily via lake-wide mean/std
    signatures: np.ndarray          # (C, P) uint32 MinHash signatures
    table_ids: np.ndarray           # (C,) int32
    names: list[str]                # column names
    table_names: dict[int, str]     # table id -> name
    version: int                    # manifest version (engine cache epoch)
    minhash_seed: int = 0           # permutation seed for external queries

    @property
    def n_columns(self) -> int:
        return int(self.signatures.shape[0])


class ColumnCatalog:
    """Open (or create) the catalog rooted at ``root``."""

    def __init__(self, root: str, *, n_perm: int = 128, minhash_seed: int = 0):
        self.root = root
        os.makedirs(root, exist_ok=True)
        path = os.path.join(root, MANIFEST)
        if os.path.exists(path):
            with open(path) as f:
                self.manifest = json.load(f)
        else:
            self.manifest = {
                "version": 0, "n_perm": int(n_perm),
                "minhash_seed": int(minhash_seed),
                "next_table_id": 0, "next_segment": 1,
                "segments": [], "tables": {}, "dropped_ids": [],
            }
            self._write_manifest()

    # -- properties ---------------------------------------------------------

    @property
    def n_perm(self) -> int:
        return int(self.manifest["n_perm"])

    @property
    def version(self) -> int:
        return int(self.manifest["version"])

    def tables(self) -> dict[str, int]:
        return dict(self.manifest["tables"])

    # -- mutation -----------------------------------------------------------

    def add_table(self, name: str,
                  columns: Sequence[tuple[str, Iterable[str | None]]] | None = None,
                  *, batch: ColumnBatch | None = None,
                  row_budget: int | None = None) -> int:
        """Register a table from raw string columns (``columns``) or an
        already-packed ``ColumnBatch``. Writes one delta segment. Returns
        the assigned table id."""
        if name in self.manifest["tables"]:
            raise ValueError(f"table {name!r} already in catalog")
        if (columns is None) == (batch is None):
            raise ValueError("pass exactly one of columns= or batch=")
        if batch is None:
            batch, _ = ingest_string_columns(columns, row_budget=row_budget)
        if batch.n_columns == 0:
            raise ValueError(f"table {name!r} has no columns")

        numeric, words, sigs = self._profile_and_sign(batch)
        tid = int(self.manifest["next_table_id"])
        seg = f"seg-{int(self.manifest['next_segment']):08d}"
        seg_dir = os.path.join(self.root, seg)
        os.makedirs(seg_dir, exist_ok=True)
        np.save(os.path.join(seg_dir, "numeric.npy"), numeric)
        np.save(os.path.join(seg_dir, "words.npy"), words)
        np.save(os.path.join(seg_dir, "n_rows.npy"), batch.n_rows.astype(np.int32))
        np.save(os.path.join(seg_dir, "sigs.npy"), sigs)
        # the re-sign source for signature maintenance at compact()
        np.save(os.path.join(seg_dir, "values.npy"), batch.values32)
        np.save(os.path.join(seg_dir, "table_ids.npy"),
                np.full((batch.n_columns,), tid, np.int32))
        with open(os.path.join(seg_dir, "meta.json"), "w") as f:
            json.dump({"names": list(batch.names),
                       "tables": {name: tid}}, f)

        self.manifest["tables"][name] = tid
        self.manifest["next_table_id"] = tid + 1
        self.manifest["next_segment"] = int(self.manifest["next_segment"]) + 1
        self.manifest["segments"].append(seg)
        self.manifest["version"] = self.version + 1
        self._write_manifest()
        return tid

    def drop_table(self, name: str) -> None:
        """Tombstone a table; its columns disappear from snapshots and its
        bytes are reclaimed at the next ``compact()``."""
        tid = self.manifest["tables"].pop(name, None)
        if tid is None:
            raise KeyError(f"table {name!r} not in catalog")
        self.manifest["dropped_ids"].append(int(tid))
        self.manifest["version"] = self.version + 1
        self._write_manifest()

    def compact(self, *, n_perm: int | None = None,
                minhash_seed: int | None = None,
                resign_chunk: int = 256) -> None:
        """Merge live segments into one; drop tombstoned columns; delete the
        old segment directories.

        Signature maintenance: passing ``n_perm`` and/or ``minhash_seed``
        re-MinHashes every live column from the stored per-segment value
        sketches (``values.npy``, in column chunks of ``resign_chunk``) and
        updates the manifest, so snapshots after the compaction carry the
        new signature geometry. Segments written before value storage
        existed cannot be re-signed and raise ``ValueError``.
        """
        cur_seed = int(self.manifest["minhash_seed"])
        new_perm = self.n_perm if n_perm is None else int(n_perm)
        new_seed = cur_seed if minhash_seed is None else int(minhash_seed)
        resign = new_perm != self.n_perm or new_seed != cur_seed

        parts = [self._load_segment(s) for s in self.manifest["segments"]]
        dropped = set(self.manifest["dropped_ids"])
        old_segs = list(self.manifest["segments"])

        # segments written before value storage (or carrying columns merged
        # from such segments) cannot be re-signed; their rows are tracked by
        # a validity mask so a plain compact() never discards the re-sign
        # source of the segments that DO have one
        def _part_valid(part, keep):
            if "values" not in part:
                return np.zeros((int(keep.sum()),), bool)
            if "values_valid" in part:
                return np.asarray(part["values_valid"])[keep]
            return np.ones((int(keep.sum()),), bool)

        keeps = [~np.isin(p["table_ids"], list(dropped)) for p in parts]
        if resign:
            legacy = [s for s, p, keep in zip(old_segs, parts, keeps)
                      if not _part_valid(p, keep).all()]
            if legacy:
                raise ValueError(
                    f"cannot change n_perm/minhash_seed: segment(s) "
                    f"{legacy} predate value storage (no complete "
                    f"values.npy); re-ingest those tables to enable "
                    f"signature maintenance")

        merged = {k: [] for k in ("numeric", "words", "n_rows", "sigs",
                                  "table_ids")}
        values_parts: list[np.ndarray] = []
        valid_parts: list[np.ndarray] = []
        names: list[str] = []
        tables: dict[str, int] = {}
        for part, keep in zip(parts, keeps):
            for k in merged:
                merged[k].append(part[k][keep])
            if "values" in part:
                values_parts.append(np.asarray(part["values"][keep]))
            else:
                values_parts.append(
                    np.full((int(keep.sum()), 1), FT.HASH_SENTINEL,
                            np.uint32))
            valid_parts.append(_part_valid(part, keep))
            names.extend([n for n, ok in zip(part["names"], keep) if ok])
            tables.update({t: i for t, i in part["tables"].items()
                           if i not in dropped})

        cat = {k: (np.concatenate(v) if v else
                   self._empty_arrays()[k]) for k, v in merged.items()}
        budget = max((v.shape[1] for v in values_parts), default=1)
        values_parts = [
            np.pad(v, ((0, 0), (0, budget - v.shape[1])),
                   constant_values=FT.HASH_SENTINEL)
            for v in values_parts]
        values = (np.concatenate(values_parts) if values_parts else
                  np.full((0, 1), FT.HASH_SENTINEL, np.uint32))
        values_valid = (np.concatenate(valid_parts) if valid_parts else
                        np.zeros((0,), bool))
        if resign:
            cat["sigs"] = self._resign(values, new_perm, new_seed,
                                       chunk=resign_chunk)

        seg = f"seg-{int(self.manifest['next_segment']):08d}"
        seg_dir = os.path.join(self.root, seg)
        os.makedirs(seg_dir, exist_ok=True)
        for k, arr in cat.items():
            np.save(os.path.join(seg_dir, f"{k}.npy"), arr)
        np.save(os.path.join(seg_dir, "values.npy"), values)
        if not values_valid.all():         # all-True is implied when absent
            np.save(os.path.join(seg_dir, "values_valid.npy"), values_valid)
        with open(os.path.join(seg_dir, "meta.json"), "w") as f:
            json.dump({"names": names, "tables": tables}, f)

        self.manifest["segments"] = [seg]
        self.manifest["next_segment"] = int(self.manifest["next_segment"]) + 1
        self.manifest["dropped_ids"] = []
        self.manifest["n_perm"] = new_perm
        self.manifest["minhash_seed"] = new_seed
        self.manifest["version"] = self.version + 1
        self._write_manifest()
        for s in old_segs:
            shutil.rmtree(os.path.join(self.root, s), ignore_errors=True)

    @staticmethod
    def _resign(values: np.ndarray, n_perm: int, seed: int,
                chunk: int = 256) -> np.ndarray:
        """Re-MinHash stored value sketches -> (C, n_perm) signatures."""
        c = values.shape[0]
        if c == 0:
            return np.zeros((0, n_perm), np.uint32)
        out = []
        for i in range(0, c, chunk):
            v = np.ascontiguousarray(values[i:i + chunk])
            out.append(np.asarray(ops.minhash(v, n_perm=n_perm, seed=seed),
                                  np.uint32))
        return np.concatenate(out)

    # -- reads --------------------------------------------------------------

    def snapshot(self) -> CatalogSnapshot:
        dropped = set(self.manifest["dropped_ids"])
        parts = [self._load_segment(s) for s in self.manifest["segments"]]
        acc = {k: [] for k in ("numeric", "words", "n_rows", "sigs",
                               "table_ids")}
        names: list[str] = []
        table_names: dict[int, str] = {}
        for part in parts:
            keep = ~np.isin(part["table_ids"], list(dropped))
            for k in acc:
                acc[k].append(part[k][keep])
            names.extend([n for n, ok in zip(part["names"], keep) if ok])
            table_names.update({i: t for t, i in part["tables"].items()
                                if i not in dropped})

        empty = self._empty_arrays()
        cat = {k: (np.concatenate(v) if v else empty[k])    # copies off mmap
               for k, v in acc.items()}
        numeric = cat["numeric"].astype(np.float32)
        c = numeric.shape[0]
        mean = numeric.mean(axis=0) if c else np.zeros((FT.F_NUM,), np.float32)
        std = numeric.std(axis=0) if c else np.ones((FT.F_NUM,), np.float32)
        std = np.where(std < 1e-6, 1.0, std).astype(np.float32)
        profiles = LakeProfiles(numeric=numeric, words=cat["words"],
                                n_rows=cat["n_rows"],
                                mean=mean.astype(np.float32), std=std)
        return CatalogSnapshot(profiles=profiles, signatures=cat["sigs"],
                               table_ids=cat["table_ids"], names=names,
                               table_names=table_names, version=self.version,
                               minhash_seed=int(self.manifest["minhash_seed"]))

    # -- internals ----------------------------------------------------------

    def _empty_arrays(self) -> dict[str, np.ndarray]:
        return {"numeric": np.zeros((0, FT.F_NUM), np.float32),
                "words": np.zeros((0, FT.F_WORDS), np.uint32),
                "n_rows": np.zeros((0,), np.int32),
                "sigs": np.zeros((0, self.n_perm), np.uint32),
                "table_ids": np.zeros((0,), np.int32)}

    def _load_segment(self, seg: str) -> dict:
        seg_dir = os.path.join(self.root, seg)
        out = {k: np.load(os.path.join(seg_dir, f"{k}.npy"), mmap_mode="r")
               for k in ("numeric", "words", "n_rows", "sigs", "table_ids")}
        vpath = os.path.join(seg_dir, "values.npy")
        if os.path.exists(vpath):    # absent in pre-maintenance segments
            out["values"] = np.load(vpath, mmap_mode="r")
            mpath = os.path.join(seg_dir, "values_valid.npy")
            if os.path.exists(mpath):
                out["values_valid"] = np.load(mpath, mmap_mode="r")
        with open(os.path.join(seg_dir, "meta.json")) as f:
            meta = json.load(f)
        out["names"] = meta["names"]
        out["tables"] = meta["tables"]
        return out

    def _profile_and_sign(self, batch: ColumnBatch):
        return profile_and_sign(batch, self.n_perm,
                                int(self.manifest["minhash_seed"]))

    def _write_manifest(self) -> None:
        path = os.path.join(self.root, MANIFEST)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.manifest, f, indent=1)
        os.replace(tmp, path)                       # atomic on POSIX


def add_lake(catalog: ColumnCatalog, lake, prefix: str = "table") -> list[int]:
    """Ingest every table of a ``core.lakegen`` synthetic lake (one delta
    segment per table — exercising the incremental path at scale)."""
    tids = []
    for t in np.unique(lake.batch.table_ids):
        idx = np.flatnonzero(lake.batch.table_ids == t)
        sub = _slice_batch(lake.batch, idx)
        tids.append(catalog.add_table(f"{prefix}{int(t)}", batch=sub))
    return tids
