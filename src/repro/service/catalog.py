"""Persistent on-disk column catalog — the serving-grade lake index.

The paper's point is that a column's footprint in the index is a few KB of
profile; this module makes that index *durable, incremental and
multi-writer* so a lake can grow (or shrink) under concurrent ingest
without reprofiling:

* :class:`CatalogStore` — the writer half. Every ``add_table`` profiles
  the new columns on-device, MinHashes their values, and writes one
  immutable **delta segment** (plain ``.npy`` files + a JSON sidecar); the
  manifest advance is a **compare-and-swap** on a chain of immutable
  per-version manifest files, so several ingest workers append delta
  segments concurrently — a lost race re-reads the head and retries
  (rewriting only the tid-dependent sidecar files, never re-profiling);
* ``drop_table`` is a manifest tombstone (O(1));
* ``compact()`` merges the segments live at a **pinned** version into one
  and CAS-publishes the swap — segments appended by concurrent writers
  after the pin are retained via manifest replay, and an advisory
  :class:`WriterLease` keeps compactors mutually exclusive.  Passing
  ``n_perm=`` / ``minhash_seed=`` **re-signs** every live column from the
  per-segment value sketches (``values.npy``) so the LSH geometry can be
  retuned without re-ingesting the lake; ``retain_versions=N`` defers
  deletion of replaced segments until the head passes the swap by N
  versions, keeping the last N manifest versions materializable for
  pinned/lagging followers;
* :class:`CatalogReader` — the follower half: tails the manifest chain
  (``poll()`` — a single ``os.stat`` of the pointer hint when nothing
  changed) and materializes immutable :class:`CatalogSnapshot`\\ s
  keyed by version, so read replicas observe every version in order and
  queries can pin one version for their whole pipeline;
* **lazy snapshots** (``snapshot(lazy=True)``) keep the segment arrays as
  read-only ``np.memmap`` views instead of copying them, and recover the
  lake-wide z-score stats from per-segment **moments** stored in each
  segment's ``meta.json`` — opening a compacted million-column catalog is
  O(manifest), not O(lake), and resident memory grows only with the bytes
  a query actually touches.  POSIX unlink semantics keep a pinned lazy
  snapshot valid across a concurrent compaction that deletes its segment
  files: the mapping holds the data alive until the last reader drops it.

Layout::

    <root>/MANIFEST.json            # pointer to the newest version (hint)
    <root>/MANIFEST-00000007.json   # immutable per-version manifests (CAS)
    <root>/LEASE.json               # advisory writer lease (compaction)
    <root>/seg-00000001-3fa9c1/{numeric,words,n_rows,sigs,table_ids}.npy
    <root>/seg-00000001-3fa9c1/values.npy  # folded value hashes (re-sign src)
    <root>/seg-00000001-3fa9c1/meta.json   # column names, table name -> id

The CAS primitive is ``os.link`` of a fully-written temp file onto
``MANIFEST-{v+1}`` — creation fails atomically if another writer already
published that version.  ``MANIFEST.json`` is a best-effort pointer
updated after each publish; readers resolve the true head by probing the
chain forward from it, so a stale pointer costs a few extra ``stat``\\ s,
never a wrong answer.  A crash mid-``add_table`` leaves at worst an
orphaned segment directory that no manifest references.
"""
from __future__ import annotations

import copy
import dataclasses
import json
import os
import shutil
import threading
import time
from typing import Iterable, Sequence

import numpy as np

from repro.core import features as FT
from repro.core.ingest import ColumnBatch, ingest_string_columns
from repro.core.profiles import LakeProfiles, compute_profiles_batch
from repro.kernels import ops

MANIFEST = "MANIFEST.json"
LEASE = "LEASE.json"
_PROFILE_PAD_C = 8     # pad column counts so repeated adds reuse compiles


def profile_and_sign(batch: ColumnBatch, n_perm: int, seed: int,
                     pad_c: int = _PROFILE_PAD_C):
    """Profile + MinHash a batch on-device -> (numeric, words, sigs).

    The single implementation both the catalog ingest path and the engine's
    external-query path use, so uploaded columns are profiled exactly like
    resident ones. Column count is padded to a multiple of ``pad_c`` and
    rows to the next power of two so repeated small batches hit the same
    compiled shapes.
    """
    import jax.numpy as jnp
    c, r = batch.values32.shape
    cp = -(-c // pad_c) * pad_c
    rp = max(1 << (max(r, 1) - 1).bit_length(), 16)
    v = np.full((cp, rp), FT.HASH_SENTINEL, np.uint32)
    cl = np.zeros((cp, rp), np.float32)
    wc = np.zeros((cp, rp), np.float32)
    nr = np.zeros((cp,), np.int32)
    v[:c, :r] = batch.values32
    cl[:c, :r] = batch.char_len
    wc[:c, :r] = batch.word_cnt
    nr[:c] = batch.n_rows
    num, words = compute_profiles_batch(jnp.asarray(v), jnp.asarray(cl),
                                        jnp.asarray(wc), jnp.asarray(nr))
    sigs = ops.minhash(v, n_perm=n_perm, seed=seed)
    return (np.asarray(num[:c], np.float32),
            np.asarray(words[:c], np.uint32),
            np.asarray(sigs[:c], np.uint32))


def _slice_batch(batch: ColumnBatch, idx: np.ndarray) -> ColumnBatch:
    return ColumnBatch(
        values32=batch.values32[idx], char_len=batch.char_len[idx],
        word_cnt=batch.word_cnt[idx], n_rows=batch.n_rows[idx],
        names=[batch.names[i] for i in idx],
        table_ids=batch.table_ids[idx])


@dataclasses.dataclass
class CatalogSnapshot:
    """Materialized live view of the catalog at one manifest version.

    Immutable once built.  Eager snapshots copy every array off the
    segment mmaps; **lazy** snapshots (``lazy=True``) keep the read-only
    memmap views and recover the z-score stats from stored per-segment
    moments — O(manifest) open cost.  Both isolate a pinned query
    pipeline from every concurrent add / drop / compaction, including
    segment deletion after a swap: a copy trivially, a memmap because
    POSIX unlink leaves the mapped bytes readable until the mapping is
    dropped.
    """

    profiles: LakeProfiles          # zscored lazily via lake-wide mean/std
    signatures: np.ndarray          # (C, P) uint32 MinHash signatures
    table_ids: np.ndarray           # (C,) int32
    names: list[str]                # column names
    table_names: dict[int, str]     # table id -> name
    version: int                    # manifest version (engine cache epoch)
    minhash_seed: int = 0           # permutation seed for external queries
    lazy: bool = False              # arrays are segment memmaps, not copies

    @property
    def n_columns(self) -> int:
        return int(self.signatures.shape[0])


# ---------------------------------------------------------------------------
# manifest chain I/O (shared by store and reader)
# ---------------------------------------------------------------------------

def _manifest_name(version: int) -> str:
    return f"MANIFEST-{int(version):08d}.json"


def _read_json(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def read_manifest_version(root: str, version: int) -> dict | None:
    """The immutable manifest at ``version`` (pointer fallback for catalogs
    written before the CAS chain existed)."""
    m = _read_json(os.path.join(root, _manifest_name(version)))
    if m is None:
        ptr = _read_json(os.path.join(root, MANIFEST))
        if ptr is not None and int(ptr["version"]) == int(version):
            return ptr
    return m


def read_latest_manifest(root: str) -> dict | None:
    """Resolve the head of the manifest chain: start from the pointer hint
    and probe forward until the next version is missing."""
    m = _read_json(os.path.join(root, MANIFEST))
    v = int(m["version"]) if m is not None else -1
    while True:
        nxt = _read_json(os.path.join(root, _manifest_name(v + 1)))
        if nxt is None:
            return m
        m, v = nxt, v + 1


def _empty_arrays(n_perm: int) -> dict[str, np.ndarray]:
    return {"numeric": np.zeros((0, FT.F_NUM), np.float32),
            "words": np.zeros((0, FT.F_WORDS), np.uint32),
            "n_rows": np.zeros((0,), np.int32),
            "sigs": np.zeros((0, n_perm), np.uint32),
            "table_ids": np.zeros((0,), np.int32)}


def _load_segment(root: str, seg: str) -> dict:
    seg_dir = os.path.join(root, seg)
    out = {k: np.load(os.path.join(seg_dir, f"{k}.npy"), mmap_mode="r")
           for k in ("numeric", "words", "n_rows", "sigs", "table_ids")}
    vpath = os.path.join(seg_dir, "values.npy")
    if os.path.exists(vpath):    # absent in pre-maintenance segments
        out["values"] = np.load(vpath, mmap_mode="r")
        mpath = os.path.join(seg_dir, "values_valid.npy")
        if os.path.exists(mpath):
            out["values_valid"] = np.load(mpath, mmap_mode="r")
    with open(os.path.join(seg_dir, "meta.json")) as f:
        meta = json.load(f)
    out["names"] = meta["names"]
    out["tables"] = meta["tables"]
    out["moments"] = meta.get("moments")   # absent in pre-lazy segments
    return out


def _numeric_moments(numeric: np.ndarray) -> dict:
    """Per-segment z-score moments stored in ``meta.json`` so a lazy open
    recovers the lake-wide mean/std without reading the profile bytes."""
    x = np.asarray(numeric, np.float64)
    return {"count": int(x.shape[0]),
            "sum": x.sum(axis=0).tolist() if x.shape[0] else
            [0.0] * x.shape[1],
            "sumsq": (x * x).sum(axis=0).tolist() if x.shape[0] else
            [0.0] * x.shape[1]}


def _stats_from_moments(moments: Iterable[dict]):
    """Combine per-segment moments -> lake-wide (mean, std)."""
    n = 0
    s = np.zeros((FT.F_NUM,), np.float64)
    s2 = np.zeros((FT.F_NUM,), np.float64)
    for m in moments:
        n += int(m["count"])
        s += np.asarray(m["sum"], np.float64)
        s2 += np.asarray(m["sumsq"], np.float64)
    if n == 0:
        return (np.zeros((FT.F_NUM,), np.float32),
                np.ones((FT.F_NUM,), np.float32))
    mean = s / n
    var = np.maximum(s2 / n - mean * mean, 0.0)
    std = np.sqrt(var)
    std = np.where(std < 1e-6, 1.0, std)
    return mean.astype(np.float32), std.astype(np.float32)


def manifest_delta(old_m: dict | None, new_m: dict | None) -> list[str] | None:
    """Appended segments when ``new_m`` is a pure append-only advance of
    ``old_m``, else None.

    Append-only means: same MinHash geometry, the *identical* tombstone
    list (not merely both empty — equal drops filter the shared prefix
    identically), and ``old_m``'s segment list a prefix of ``new_m``'s.
    Under those conditions :func:`materialize_snapshot` concatenates
    segments in manifest order with the same per-segment filtering, so
    the new snapshot's first ``old.n_columns`` rows are byte-identical
    to the old snapshot's — the contract the engine's delta-refresh path
    (``EngineConfig.incremental``) builds on.  Drops, compactions and
    re-signs all return None → full rebuild."""
    if old_m is None or new_m is None:
        return None
    if (int(old_m["n_perm"]) != int(new_m["n_perm"])
            or int(old_m["minhash_seed"]) != int(new_m["minhash_seed"])):
        return None
    if list(old_m.get("dropped_ids", ())) != \
            list(new_m.get("dropped_ids", ())):
        return None
    old_segs = list(old_m.get("segments", ()))
    new_segs = list(new_m.get("segments", ()))
    if new_segs[:len(old_segs)] != old_segs:
        return None
    return new_segs[len(old_segs):]


def moments_from_stats(mean: np.ndarray, std: np.ndarray,
                       count: int) -> dict:
    """Reconstruct accumulated float64 moments from (mean, std, count) —
    the inverse of :func:`_stats_from_moments` (up to the <1e-6 std
    clamp).  Lets a freshly built engine state seed its moment
    accumulator without an O(lake) pass over the profile bytes."""
    m = np.asarray(mean, np.float64)
    s = np.asarray(std, np.float64)
    n = int(count)
    return {"count": n, "sum": m * n, "sumsq": (s * s + m * m) * n}


def fold_moments(acc: dict, delta: dict) -> dict:
    """Accumulate ``delta``'s float64 moments into a copy of ``acc`` —
    the O(delta) stats update an incremental refresh performs."""
    return {"count": int(acc["count"]) + int(delta["count"]),
            "sum": np.asarray(acc["sum"], np.float64)
            + np.asarray(delta["sum"], np.float64),
            "sumsq": np.asarray(acc["sumsq"], np.float64)
            + np.asarray(delta["sumsq"], np.float64)}


def materialize_snapshot(root: str, manifest: dict, *,
                         lazy: bool = False) -> CatalogSnapshot:
    """Materialize the live columns of ``manifest`` into an immutable
    :class:`CatalogSnapshot` (segment arrays are read with ``mmap_mode`` so
    this touches only the bytes it concatenates).

    ``lazy=True`` requests the zero-copy fast path: when the manifest is a
    single segment with no pending tombstones and stored moments (the
    steady state after a compaction), the snapshot keeps the read-only
    memmaps and the combined moments — no profile byte is read at open.
    A manifest that still needs filtering or concatenation falls back to
    the eager copy (``snapshot.lazy`` reports which path was taken)."""
    dropped = set(manifest["dropped_ids"])
    parts = [_load_segment(root, s) for s in manifest["segments"]]

    if (lazy and len(parts) == 1 and not dropped
            and parts[0]["moments"] is not None):
        part = parts[0]
        mean, std = _stats_from_moments([part["moments"]])
        profiles = LakeProfiles(numeric=part["numeric"],
                                words=part["words"],
                                n_rows=part["n_rows"],
                                mean=mean, std=std)
        return CatalogSnapshot(
            profiles=profiles, signatures=part["sigs"],
            table_ids=part["table_ids"], names=list(part["names"]),
            table_names={i: t for t, i in part["tables"].items()},
            version=int(manifest["version"]),
            minhash_seed=int(manifest["minhash_seed"]), lazy=True)
    acc = {k: [] for k in ("numeric", "words", "n_rows", "sigs",
                           "table_ids")}
    names: list[str] = []
    table_names: dict[int, str] = {}
    for part in parts:
        keep = ~np.isin(part["table_ids"], list(dropped))
        for k in acc:
            acc[k].append(part[k][keep])
        names.extend([n for n, ok in zip(part["names"], keep) if ok])
        table_names.update({i: t for t, i in part["tables"].items()
                            if i not in dropped})

    empty = _empty_arrays(int(manifest["n_perm"]))
    cat = {k: (np.concatenate(v) if v else empty[k])    # copies off mmap
           for k, v in acc.items()}
    numeric = cat["numeric"].astype(np.float32)
    c = numeric.shape[0]
    mean = numeric.mean(axis=0) if c else np.zeros((FT.F_NUM,), np.float32)
    std = numeric.std(axis=0) if c else np.ones((FT.F_NUM,), np.float32)
    std = np.where(std < 1e-6, 1.0, std).astype(np.float32)
    profiles = LakeProfiles(numeric=numeric, words=cat["words"],
                            n_rows=cat["n_rows"],
                            mean=mean.astype(np.float32), std=std)
    return CatalogSnapshot(profiles=profiles, signatures=cat["sigs"],
                           table_ids=cat["table_ids"], names=names,
                           table_names=table_names,
                           version=int(manifest["version"]),
                           minhash_seed=int(manifest["minhash_seed"]))


# spare-capacity factor for extended-snapshot buffers: each append-only
# advance writes its new rows into the previous buffer's tail when room
# remains, so steady-state snapshot materialization copies only the
# delta; the O(lake) copy recurs only on capacity growth (amortized)
_SNAP_GROWTH = 1.5


def extend_snapshot(root: str, prev: CatalogSnapshot, prev_manifest: dict,
                    manifest: dict) -> CatalogSnapshot | None:
    """Delta-materialize ``manifest`` by appending its new segments onto
    an already-materialized predecessor snapshot — O(delta) disk reads
    and (steady-state) O(delta) host copies, instead of re-reading and
    re-concatenating every live segment.

    Returns ``None`` when the advance is not append-only per
    :func:`manifest_delta` (drops, compactions, geometry changes) — those
    take the full :func:`materialize_snapshot` path.

    The arrays of the returned snapshot are views over capacity buffers
    carrying ``_SNAP_GROWTH`` headroom (stashed on the snapshot as
    ``_capacity``).  Writing a successor's rows into a predecessor's
    spare tail never mutates any published view: every view is bounded
    by its own version's column count, and concurrent extensions of the
    same predecessor write byte-identical rows (the bytes are a pure
    function of the on-disk segments), so the race is benign.  Z-score
    stats are recomputed over the concatenated matrix with the same
    reduction as the eager path, keeping the result bit-identical to a
    fresh materialization."""
    new_segs = manifest_delta(prev_manifest, manifest)
    if new_segs is None:
        return None
    version = int(manifest["version"])
    caps_in = getattr(prev, "_capacity", {})
    if not new_segs:
        snap = dataclasses.replace(prev, version=version)
        snap._capacity = caps_in
        return snap
    dropped = set(manifest["dropped_ids"])
    acc: dict[str, list] = {k: [] for k in ("numeric", "words", "n_rows",
                                            "sigs", "table_ids")}
    names = list(prev.names)
    table_names = dict(prev.table_names)
    for seg in new_segs:
        part = _load_segment(root, seg)
        keep = ~np.isin(part["table_ids"], list(dropped))
        for k in acc:
            acc[k].append(part[k][keep])
        names.extend([n for n, ok in zip(part["names"], keep) if ok])
        table_names.update({i: t for t, i in part["tables"].items()
                            if i not in dropped})

    caps_out: dict[str, np.ndarray] = {}

    def ext(key: str, prev_arr: np.ndarray, dtype=None) -> np.ndarray:
        parts = [np.asarray(p, dtype) if dtype is not None else np.asarray(p)
                 for p in acc[key]]
        c0 = int(prev_arr.shape[0])
        c1 = c0 + sum(int(p.shape[0]) for p in parts)
        cap = caps_in.get(key)
        if cap is None or cap.shape[0] < c1 \
                or not np.shares_memory(cap[:c0], prev_arr):
            tail = prev_arr.shape[1:]
            cap = np.empty((max(int(c1 * _SNAP_GROWTH), c1),) + tail,
                           parts[0].dtype if dtype is None and parts
                           else (dtype or prev_arr.dtype))
            cap[:c0] = prev_arr
        o = c0
        for p in parts:
            cap[o:o + p.shape[0]] = p
            o += p.shape[0]
        caps_out[key] = cap
        return cap[:c1]

    prof = prev.profiles
    numeric = ext("numeric", np.asarray(prof.numeric), np.float32)
    c = numeric.shape[0]
    mean = numeric.mean(axis=0) if c else np.zeros((FT.F_NUM,), np.float32)
    std = numeric.std(axis=0) if c else np.ones((FT.F_NUM,), np.float32)
    std = np.where(std < 1e-6, 1.0, std).astype(np.float32)
    profiles = LakeProfiles(numeric=numeric,
                            words=ext("words", np.asarray(prof.words)),
                            n_rows=ext("n_rows", np.asarray(prof.n_rows)),
                            mean=mean.astype(np.float32), std=std)
    snap = CatalogSnapshot(profiles=profiles,
                           signatures=ext("sigs",
                                          np.asarray(prev.signatures)),
                           table_ids=ext("table_ids",
                                         np.asarray(prev.table_ids)),
                           names=names, table_names=table_names,
                           version=version,
                           minhash_seed=int(manifest["minhash_seed"]))
    snap._capacity = caps_out
    return snap


# ---------------------------------------------------------------------------
# writer lease
# ---------------------------------------------------------------------------

class LeaseHeldError(RuntimeError):
    """Another writer holds a live lease over this catalog."""


class WriterLease:
    """Advisory time-bounded lease over a catalog root.

    Used to keep compactors mutually exclusive (delta appends need no lease
    — the manifest CAS already serializes them).  Acquisition atomically
    creates ``LEASE.json``; an expired lease is stolen via atomic replace
    and the steal verified by re-reading the token.  The lease is advisory:
    it bounds concurrent *compaction work*, while manifest correctness is
    always guaranteed by the CAS chain alone.
    """

    def __init__(self, root: str, *, owner: str | None = None,
                 ttl_s: float = 60.0, clock=time.time):
        self.root = root
        self.owner = owner or f"pid-{os.getpid()}"
        self.ttl_s = float(ttl_s)
        # injectable wall clock: expiry tests advance a fake clock past
        # the ttl instead of sleeping (or hacking negative ttls).  Every
        # participant judging the same lease must share the clock
        self._clock = clock
        self.token = os.urandom(8).hex()
        self._held = False

    @property
    def path(self) -> str:
        return os.path.join(self.root, LEASE)

    def _read(self) -> dict | None:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def _write_tmp(self) -> str:
        rec = {"owner": self.owner, "token": self.token,
               "expires": self._clock() + self.ttl_s}
        tmp = os.path.join(self.root, f".lease-{self.token}.tmp")
        with open(tmp, "w") as f:
            json.dump(rec, f)
        return tmp

    def acquire(self) -> "WriterLease":
        tmp = self._write_tmp()
        try:
            os.link(tmp, self.path)
            self._held = True
            return self
        except FileExistsError:
            pass
        finally:
            os.unlink(tmp)
        cur = self._read()
        now = self._clock()
        if (cur is not None and cur.get("token") != self.token
                and float(cur.get("expires", 0)) > now):
            raise LeaseHeldError(
                f"catalog lease held by {cur.get('owner')!r} for another "
                f"{float(cur['expires']) - now:.1f}s")
        # expired (or unreadable) lease: unlink the record we judged
        # expired iff it is still the one on disk, then race a fresh
        # create-if-absent — exactly one stealer's link succeeds (a blind
        # replace would let every stealer pass its own verification)
        cur2 = self._read()
        if (cur is not None and cur2 is not None
                and cur2.get("token") == cur.get("token")):
            try:
                os.unlink(self.path)
            except FileNotFoundError:
                pass
        tmp = self._write_tmp()
        try:
            os.link(tmp, self.path)
        except FileExistsError:
            raise LeaseHeldError("lost the race stealing an expired lease")
        finally:
            os.unlink(tmp)
        self._held = True
        return self

    def renew(self) -> None:
        if not self._held:
            raise RuntimeError("cannot renew a lease that is not held")
        tmp = self._write_tmp()
        os.replace(tmp, self.path)

    def release(self) -> None:
        if not self._held:
            return
        self._held = False
        cur = self._read()
        if cur is not None and cur.get("token") == self.token:
            try:
                os.unlink(self.path)
            except FileNotFoundError:
                pass

    def __enter__(self) -> "WriterLease":
        if not self._held:
            self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


# ---------------------------------------------------------------------------
# store (writer half)
# ---------------------------------------------------------------------------

class CatalogStore:
    """Open (or create) the catalog rooted at ``root``.

    Safe for several concurrent writers (threads or processes, each with
    its own store handle): every mutation is a CAS loop over the manifest
    chain.  ``self.manifest`` is this handle's last-confirmed view of the
    head; reads that must be fresh go through :meth:`_refresh`.
    """

    def __init__(self, root: str, *, n_perm: int = 128, minhash_seed: int = 0,
                 events=None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._mlock = threading.Lock()
        # optional event sink (any object with .publish(type, **payload),
        # e.g. service.events.EventBus): every successful CAS advance
        # publishes manifest_advanced
        self.events = events
        self.stats = {"cas_retries": 0, "publishes": 0, "compactions": 0}
        m = read_latest_manifest(root)
        if m is None:
            m = {
                "version": 0, "n_perm": int(n_perm),
                "minhash_seed": int(minhash_seed),
                "next_table_id": 0, "next_segment": 1,
                "segments": [], "tables": {}, "dropped_ids": [],
            }
            if not self._publish(m):        # lost the creation race
                m = read_latest_manifest(root)
        else:
            self._ensure_chain(m)
        self._set_manifest(m)

    # -- properties ---------------------------------------------------------

    @property
    def n_perm(self) -> int:
        return int(self.manifest["n_perm"])

    @property
    def version(self) -> int:
        return int(self.manifest["version"])

    def tables(self) -> dict[str, int]:
        return dict(self._refresh()["tables"])

    # -- manifest chain -----------------------------------------------------

    def _set_manifest(self, m: dict) -> None:
        with self._mlock:
            if (not hasattr(self, "manifest")
                    or int(m["version"]) >= self.version):
                self.manifest = m

    def _refresh(self) -> dict:
        m = read_latest_manifest(self.root)
        self._set_manifest(m)
        return m

    def _publish(self, m: dict) -> bool:
        """CAS-advance the chain to ``m['version']``.  False = lost race."""
        final = os.path.join(self.root, _manifest_name(m["version"]))
        tmp = os.path.join(self.root,
                           f".manifest-{os.urandom(6).hex()}.tmp")
        with open(tmp, "w") as f:
            json.dump(m, f, indent=1)
        try:
            os.link(tmp, final)             # atomic create-if-absent
        except FileExistsError:
            return False
        finally:
            os.unlink(tmp)
        self.stats["publishes"] += 1
        self._update_pointer(m)
        if self.events is not None:
            self.events.publish("manifest_advanced",
                                version=int(m["version"]),
                                n_segments=len(m.get("segments", ())),
                                follower=False)
        return True

    def _update_pointer(self, m: dict) -> None:
        """Best-effort MANIFEST.json hint (readers probe forward from it)."""
        ptr = os.path.join(self.root, MANIFEST)
        cur = _read_json(ptr)
        if cur is not None and int(cur["version"]) >= int(m["version"]):
            return
        tmp = ptr + f".{os.urandom(4).hex()}.tmp"
        with open(tmp, "w") as f:
            json.dump(m, f, indent=1)
        os.replace(tmp, ptr)                # atomic on POSIX

    def _ensure_chain(self, m: dict) -> None:
        """Backfill the chain file for a pre-CAS catalog's head version."""
        final = os.path.join(self.root, _manifest_name(m["version"]))
        if os.path.exists(final):
            return
        tmp = os.path.join(self.root,
                           f".manifest-{os.urandom(6).hex()}.tmp")
        with open(tmp, "w") as f:
            json.dump(m, f, indent=1)
        try:
            os.link(tmp, final)
        except FileExistsError:
            pass
        finally:
            os.unlink(tmp)

    # -- mutation -----------------------------------------------------------

    def add_table(self, name: str,
                  columns: Sequence[tuple[str, Iterable[str | None]]] | None = None,
                  *, batch: ColumnBatch | None = None,
                  row_budget: int | None = None) -> int:
        """Register a table from raw string columns (``columns``) or an
        already-packed ``ColumnBatch``. Writes one delta segment and
        CAS-publishes the manifest advance; a lost race retries against the
        new head, re-signing only if the LSH geometry changed underneath us
        and rewriting only the tid-dependent sidecar files. Returns the
        assigned table id."""
        if (columns is None) == (batch is None):
            raise ValueError("pass exactly one of columns= or batch=")
        if batch is None:
            batch, _ = ingest_string_columns(columns, row_budget=row_budget)
        if batch.n_columns == 0:
            raise ValueError(f"table {name!r} has no columns")

        signed: dict[tuple[int, int], tuple] = {}   # geometry -> arrays
        seg = seg_dir = None
        seg_tid = seg_geom = None
        try:
            while True:
                m = copy.deepcopy(self._refresh())
                if name in m["tables"]:
                    raise ValueError(f"table {name!r} already in catalog")
                geom = (int(m["n_perm"]), int(m["minhash_seed"]))
                if geom not in signed:
                    signed[geom] = profile_and_sign(batch, *geom)
                numeric, words, sigs = signed[geom]
                tid = int(m["next_table_id"])
                if seg is None:
                    seg = (f"seg-{int(m['next_segment']):08d}-"
                           f"{os.urandom(3).hex()}")
                    seg_dir = os.path.join(self.root, seg)
                    self._write_segment(
                        seg_dir, batch, numeric, words, sigs,
                        np.full((batch.n_columns,), tid, np.int32),
                        {name: tid})
                    seg_tid, seg_geom = tid, geom
                else:
                    if geom != seg_geom:    # concurrent re-sign compaction
                        np.save(os.path.join(seg_dir, "sigs.npy"), sigs)
                        seg_geom = geom
                    if tid != seg_tid:      # another writer took our tid
                        np.save(os.path.join(seg_dir, "table_ids.npy"),
                                np.full((batch.n_columns,), tid, np.int32))
                        with open(os.path.join(seg_dir, "meta.json"),
                                  "w") as f:
                            json.dump({"names": list(batch.names),
                                       "tables": {name: tid},
                                       "moments":
                                           _numeric_moments(numeric)}, f)
                        seg_tid = tid

                m["tables"][name] = tid
                m["next_table_id"] = tid + 1
                m["next_segment"] = int(m["next_segment"]) + 1
                m["segments"].append(seg)
                m["version"] = int(m["version"]) + 1
                if self._publish(m):
                    self._set_manifest(m)
                    return tid
                self.stats["cas_retries"] += 1
        except BaseException:
            if seg_dir is not None:         # never leak an orphan segment
                shutil.rmtree(seg_dir, ignore_errors=True)
            raise

    @staticmethod
    def _write_segment(seg_dir: str, batch: ColumnBatch, numeric, words,
                       sigs, table_ids: np.ndarray,
                       tables: dict[str, int]) -> None:
        os.makedirs(seg_dir, exist_ok=True)
        np.save(os.path.join(seg_dir, "numeric.npy"), numeric)
        np.save(os.path.join(seg_dir, "words.npy"), words)
        np.save(os.path.join(seg_dir, "n_rows.npy"),
                batch.n_rows.astype(np.int32))
        np.save(os.path.join(seg_dir, "sigs.npy"), sigs)
        # the re-sign source for signature maintenance at compact()
        np.save(os.path.join(seg_dir, "values.npy"), batch.values32)
        np.save(os.path.join(seg_dir, "table_ids.npy"),
                np.asarray(table_ids, np.int32))
        with open(os.path.join(seg_dir, "meta.json"), "w") as f:
            json.dump({"names": list(batch.names), "tables": tables,
                       "moments": _numeric_moments(numeric)}, f)

    def add_batch(self, batch: ColumnBatch,
                  table_names: Sequence[str], *,
                  profile_chunk: int = 8192) -> dict[str, int]:
        """Bulk-register many tables from one packed batch as **one**
        delta segment (the segment format already carries per-column
        table ids and a multi-table name map).

        ``batch.table_ids`` hold *local* ids indexing ``table_names``;
        they are remapped onto catalog-assigned ids at publish time.
        This is the scale ingest path: a 10^5-column synthetic lake lands
        in one segment + one manifest CAS instead of one of each per
        table — and leaves the catalog in the single-segment steady state
        the lazy snapshot fast path wants.  Profiling/MinHashing runs in
        ``profile_chunk``-column slices to bound device memory.  Returns
        ``{table name: assigned id}``."""
        if batch.n_columns == 0:
            raise ValueError("batch has no columns")
        local = np.asarray(batch.table_ids, np.int64)
        if local.min() < 0 or local.max() >= len(table_names):
            raise ValueError(
                f"batch table_ids must index table_names "
                f"(0..{len(table_names) - 1}); got range "
                f"[{int(local.min())}, {int(local.max())}]")
        if len(set(table_names)) != len(table_names):
            raise ValueError("duplicate names in table_names")

        def _sign(geom):
            outs = ([], [], [])
            for i in range(0, batch.n_columns, profile_chunk):
                idx = np.arange(i, min(i + profile_chunk, batch.n_columns))
                for acc, arr in zip(outs, profile_and_sign(
                        _slice_batch(batch, idx), *geom)):
                    acc.append(arr)
            return tuple(np.concatenate(a) for a in outs)

        signed: dict[tuple[int, int], tuple] = {}
        seg = seg_dir = None
        seg_base = seg_geom = None
        try:
            while True:
                m = copy.deepcopy(self._refresh())
                taken = [t for t in table_names if t in m["tables"]]
                if taken:
                    raise ValueError(f"table(s) {taken!r} already in "
                                     f"catalog")
                geom = (int(m["n_perm"]), int(m["minhash_seed"]))
                if geom not in signed:
                    signed[geom] = _sign(geom)
                numeric, words, sigs = signed[geom]
                base = int(m["next_table_id"])
                tids = (base + local).astype(np.int32)
                tables = {t: base + i for i, t in enumerate(table_names)}
                if seg is None:
                    seg = (f"seg-{int(m['next_segment']):08d}-"
                           f"{os.urandom(3).hex()}")
                    seg_dir = os.path.join(self.root, seg)
                    self._write_segment(seg_dir, batch, numeric, words,
                                        sigs, tids, tables)
                    seg_base, seg_geom = base, geom
                else:
                    if geom != seg_geom:
                        np.save(os.path.join(seg_dir, "sigs.npy"), sigs)
                        seg_geom = geom
                    if base != seg_base:
                        np.save(os.path.join(seg_dir, "table_ids.npy"),
                                tids)
                        with open(os.path.join(seg_dir, "meta.json"),
                                  "w") as f:
                            json.dump({"names": list(batch.names),
                                       "tables": tables,
                                       "moments":
                                           _numeric_moments(numeric)}, f)
                        seg_base = base
                m["tables"].update(tables)
                m["next_table_id"] = base + len(table_names)
                m["next_segment"] = int(m["next_segment"]) + 1
                m["segments"].append(seg)
                m["version"] = int(m["version"]) + 1
                if self._publish(m):
                    self._set_manifest(m)
                    return tables
                self.stats["cas_retries"] += 1
        except BaseException:
            if seg_dir is not None:
                shutil.rmtree(seg_dir, ignore_errors=True)
            raise

    def drop_table(self, name: str) -> None:
        """Tombstone a table; its columns disappear from snapshots and its
        bytes are reclaimed at the next ``compact()``."""
        while True:
            m = copy.deepcopy(self._refresh())
            if name not in m["tables"]:
                raise KeyError(f"table {name!r} not in catalog")
            tid = m["tables"].pop(name)
            m["dropped_ids"].append(int(tid))
            m["version"] = int(m["version"]) + 1
            if self._publish(m):
                self._set_manifest(m)
                return
            self.stats["cas_retries"] += 1

    # -- compaction ---------------------------------------------------------

    def compact(self, *, n_perm: int | None = None,
                minhash_seed: int | None = None,
                resign_chunk: int = 256,
                lease_ttl_s: float = 60.0,
                retain_versions: int = 0,
                on_built=None) -> None:
        """Merge the segments live at a pinned version into one; drop
        tombstoned columns; CAS-publish the swap; delete the replaced
        segment directories.

        ``retain_versions=N`` keeps replaced segments on disk until the
        manifest head has advanced ``N`` versions past the swap that
        retired them (tracked via the manifest's ``retired`` list, GC'd
        by later compactions), so the last ``N`` manifest versions stay
        **materializable** — a pinned historical ``reader.snapshot(v)``
        or a lagging follower inside the window never hits a deleted
        segment.  The default ``0`` deletes immediately (and purges any
        window left by earlier compactions); already-materialized
        snapshots are plain numpy copies and outlive deletion either way.

        Runs under the advisory :class:`WriterLease` (raises
        :class:`LeaseHeldError` if another compactor holds it).  Concurrent
        ``add_table`` / ``drop_table`` are safe: segments appended after
        the pin are **retained via manifest replay** at publish time, and
        tombstones laid after the pin stay tombstoned.  ``on_built`` (a
        zero-arg callable) fires after the compacted segment is built and
        before the publish — the hook concurrency tests synchronize on.

        Signature maintenance: passing ``n_perm`` and/or ``minhash_seed``
        re-MinHashes every live column from the stored per-segment value
        sketches (``values.npy``, in column chunks of ``resign_chunk``) and
        updates the manifest, so snapshots after the compaction carry the
        new signature geometry.  A re-sign cannot replay concurrent adds
        (their segments carry old-geometry signatures), so it restarts from
        the new head instead.  Segments written before value storage
        existed cannot be re-signed and raise ``ValueError``.
        """
        lease = WriterLease(self.root, ttl_s=lease_ttl_s).acquire()
        try:
            while True:
                pinned = copy.deepcopy(self._refresh())
                built = self._build_compacted(pinned, n_perm, minhash_seed,
                                              resign_chunk,
                                              renew=lease.renew)
                lease.renew()           # a long build must not outlive ttl
                if on_built is not None:
                    on_built()
                nm, due = self._publish_compacted(pinned, built,
                                                  retain_versions)
                if nm is not None:
                    self._set_manifest(nm)
                    self.stats["compactions"] += 1
                    for s in due:
                        shutil.rmtree(os.path.join(self.root, s),
                                      ignore_errors=True)
                    return
                # unpublishable build (re-sign raced a concurrent write, or
                # another compactor swapped our inputs out): rebuild from
                # the head
                shutil.rmtree(os.path.join(self.root, built["seg"]),
                              ignore_errors=True)
        finally:
            lease.release()

    def _build_compacted(self, pinned: dict, n_perm, minhash_seed,
                         resign_chunk: int, renew=None) -> dict:
        """Merge ``pinned``'s live segments into one new on-disk segment.

        ``renew`` (zero-arg, optional) is called once per merged segment
        and once per re-sign chunk, so a build longer than the lease ttl
        keeps its mutual exclusion."""
        cur_seed = int(pinned["minhash_seed"])
        cur_perm = int(pinned["n_perm"])
        new_perm = cur_perm if n_perm is None else int(n_perm)
        new_seed = cur_seed if minhash_seed is None else int(minhash_seed)
        resign = new_perm != cur_perm or new_seed != cur_seed

        parts = [_load_segment(self.root, s) for s in pinned["segments"]]
        dropped = set(pinned["dropped_ids"])
        old_segs = list(pinned["segments"])

        # segments written before value storage (or carrying columns merged
        # from such segments) cannot be re-signed; their rows are tracked by
        # a validity mask so a plain compact() never discards the re-sign
        # source of the segments that DO have one
        def _part_valid(part, keep):
            if "values" not in part:
                return np.zeros((int(keep.sum()),), bool)
            if "values_valid" in part:
                return np.asarray(part["values_valid"])[keep]
            return np.ones((int(keep.sum()),), bool)

        keeps = [~np.isin(p["table_ids"], list(dropped)) for p in parts]
        if resign:
            legacy = [s for s, p, keep in zip(old_segs, parts, keeps)
                      if not _part_valid(p, keep).all()]
            if legacy:
                raise ValueError(
                    f"cannot change n_perm/minhash_seed: segment(s) "
                    f"{legacy} predate value storage (no complete "
                    f"values.npy); re-ingest those tables to enable "
                    f"signature maintenance")

        merged = {k: [] for k in ("numeric", "words", "n_rows", "sigs",
                                  "table_ids")}
        values_parts: list[np.ndarray] = []
        valid_parts: list[np.ndarray] = []
        names: list[str] = []
        tables: dict[str, int] = {}
        for part, keep in zip(parts, keeps):
            if renew is not None:
                renew()
            for k in merged:
                merged[k].append(part[k][keep])
            if "values" in part:
                values_parts.append(np.asarray(part["values"][keep]))
            else:
                values_parts.append(
                    np.full((int(keep.sum()), 1), FT.HASH_SENTINEL,
                            np.uint32))
            valid_parts.append(_part_valid(part, keep))
            names.extend([n for n, ok in zip(part["names"], keep) if ok])
            tables.update({t: i for t, i in part["tables"].items()
                           if i not in dropped})

        cat = {k: (np.concatenate(v) if v else
                   _empty_arrays(cur_perm)[k]) for k, v in merged.items()}
        budget = max((v.shape[1] for v in values_parts), default=1)
        values_parts = [
            np.pad(v, ((0, 0), (0, budget - v.shape[1])),
                   constant_values=FT.HASH_SENTINEL)
            for v in values_parts]
        values = (np.concatenate(values_parts) if values_parts else
                  np.full((0, 1), FT.HASH_SENTINEL, np.uint32))
        values_valid = (np.concatenate(valid_parts) if valid_parts else
                        np.zeros((0,), bool))
        if resign:
            cat["sigs"] = self._resign(values, new_perm, new_seed,
                                       chunk=resign_chunk, renew=renew)

        seg = (f"seg-{int(pinned['next_segment']):08d}-"
               f"{os.urandom(3).hex()}")
        seg_dir = os.path.join(self.root, seg)
        os.makedirs(seg_dir, exist_ok=True)
        for k, arr in cat.items():
            np.save(os.path.join(seg_dir, f"{k}.npy"), arr)
        np.save(os.path.join(seg_dir, "values.npy"), values)
        if not values_valid.all():         # all-True is implied when absent
            np.save(os.path.join(seg_dir, "values_valid.npy"), values_valid)
        with open(os.path.join(seg_dir, "meta.json"), "w") as f:
            json.dump({"names": names, "tables": tables,
                       "moments": _numeric_moments(cat["numeric"])}, f)

        return {"seg": seg, "replaced": old_segs,
                "applied_drops": set(pinned["dropped_ids"]),
                "n_perm": new_perm, "minhash_seed": new_seed,
                "resign": resign}

    def _publish_compacted(self, pinned: dict, built: dict,
                           retain_versions: int = 0):
        """CAS-publish the compaction swap, replaying concurrent writes.

        Returns ``(manifest, due_segments)`` — the published manifest plus
        the retired segments now past the ``retain_versions`` window (the
        caller deletes those, and only those) — or ``(None, None)`` when a
        re-sign must restart (its new geometry cannot absorb
        concurrently-added segments)."""
        replaced = set(built["replaced"])
        retain = max(int(retain_versions), 0)
        while True:
            cur = read_latest_manifest(self.root)
            live = set(cur["segments"])
            new_segs = [s for s in cur["segments"] if s not in replaced]
            geom_moved = (int(cur["n_perm"]), int(cur["minhash_seed"])) != \
                (int(pinned["n_perm"]), int(pinned["minhash_seed"]))
            # a segment we merged is gone from the head: another compactor
            # already swapped it out — publishing would serve every one of
            # its columns twice (once in ours, once in theirs). Restart.
            if geom_moved or (built["resign"] and new_segs) or \
                    not replaced <= live:
                return None, None
            v_new = int(cur["version"]) + 1
            # retirement window: a segment replaced by the publish at
            # version v stays on disk until the head passes v + retain,
            # so the last `retain` manifest versions stay materializable
            retired = [[int(v), s] for v, s in cur.get("retired", [])]
            retired += [[v_new, s] for s in built["replaced"]]
            due = [s for v, s in retired if v <= v_new - retain]
            nm = {
                "version": v_new,
                "n_perm": built["n_perm"],
                "minhash_seed": built["minhash_seed"],
                "next_table_id": int(cur["next_table_id"]),
                "next_segment": int(cur["next_segment"]) + 1,
                "segments": [built["seg"]] + new_segs,
                "tables": dict(cur["tables"]),
                # tombstones laid after the pin survive the swap; the ones
                # the compacted segment already applied are cleared
                "dropped_ids": [d for d in cur["dropped_ids"]
                                if d not in built["applied_drops"]],
                "retired": [[v, s] for v, s in retired
                            if v > v_new - retain],
            }
            if self._publish(nm):
                return nm, due
            self.stats["cas_retries"] += 1

    @staticmethod
    def _resign(values: np.ndarray, n_perm: int, seed: int,
                chunk: int = 256, renew=None) -> np.ndarray:
        """Re-MinHash stored value sketches -> (C, n_perm) signatures."""
        c = values.shape[0]
        if c == 0:
            return np.zeros((0, n_perm), np.uint32)
        out = []
        for i in range(0, c, chunk):
            if renew is not None:
                renew()
            v = np.ascontiguousarray(values[i:i + chunk])
            out.append(np.asarray(ops.minhash(v, n_perm=n_perm, seed=seed),
                                  np.uint32))
        return np.concatenate(out)

    # -- reads --------------------------------------------------------------

    def snapshot(self, *, lazy: bool = False) -> CatalogSnapshot:
        """Materialize the current head (writers see their own writes).
        ``lazy=True`` requests the zero-copy memmap fast path (see
        :func:`materialize_snapshot`)."""
        return materialize_snapshot(self.root, self._refresh(), lazy=lazy)


# Back-compat alias: the pre-MVCC single-writer class name.
ColumnCatalog = CatalogStore


# ---------------------------------------------------------------------------
# reader (follower half)
# ---------------------------------------------------------------------------

class CatalogReader:
    """Read-only follower over a catalog root.

    Tails the manifest chain (:meth:`poll`) and serves immutable
    :class:`CatalogSnapshot`\\ s keyed by version, caching the most
    recently materialized ones.  A follower observes **every** published
    version in order — it never skips from v to v+2 without reporting v+1
    — which is what the replication tests assert.

    Old versions stay materializable only until a compaction deletes their
    segments; snapshots already materialized (cached or held by an engine)
    remain valid forever — eager ones are plain numpy copies, lazy ones
    hold open memmaps whose bytes POSIX unlink cannot reclaim while the
    mapping lives.
    """

    def __init__(self, root: str, *, max_cached_snapshots: int = 4,
                 deep_poll_every: int = 128, events=None,
                 lazy: bool = False):
        self.root = root
        # default materialization mode for snapshot(); lazy=True serves
        # zero-copy memmap snapshots whenever the manifest allows it
        self.lazy = bool(lazy)
        # optional event sink; DiscoveryEngine.follow() injects its bus
        # here so follower-observed manifest_advanced events (follower=
        # True) land on the serving engine's stream
        self.events = events
        # stat the pointer BEFORE resolving the head: a publish landing in
        # between moves the pointer afterwards, so the next poll goes deep
        self._ptr_stat = self._stat_pointer()
        m = read_latest_manifest(root)
        if m is None:
            raise FileNotFoundError(f"no catalog manifest under {root!r}")
        self._max_cached = int(max_cached_snapshots)
        self._deep_every = max(int(deep_poll_every), 1)
        self._manifests: dict[int, dict] = {int(m["version"]): m}
        self._version = int(m["version"])
        self._snaps: "dict[tuple[int, bool], CatalogSnapshot]" = {}
        self._lock = threading.Lock()
        self.stats = {"polls": 0, "fast_polls": 0, "deep_polls": 0}

    @property
    def version(self) -> int:
        """Latest version this follower has observed."""
        return self._version

    def _stat_pointer(self):
        try:
            s = os.stat(os.path.join(self.root, MANIFEST))
        except FileNotFoundError:
            return None
        return (s.st_mtime_ns, s.st_ino, s.st_size)

    def poll(self) -> list[int]:
        """Probe the chain forward; returns newly observed versions in
        order (empty when the head has not moved).

        Fast path: every publish rewrites the ``MANIFEST.json`` pointer
        hint (``os.replace`` — new inode, new mtime), so an unchanged
        pointer stat means nothing moved and the poll is a **single
        ``os.stat``** — no JSON read/parse per probe.  The pointer is
        best-effort (a writer could crash between the chain CAS and the
        pointer rewrite), so every ``deep_poll_every``-th poll probes the
        chain regardless; correctness never depends on the hint."""
        new: list[int] = []
        with self._lock:
            self.stats["polls"] += 1
            st = self._stat_pointer()
            if (st is not None and st == self._ptr_stat
                    and self.stats["polls"] % self._deep_every != 0):
                self.stats["fast_polls"] += 1
                return []
            self.stats["deep_polls"] += 1
            # cache the PRE-probe stat: a publish racing the probe below
            # either lands in it, or moves the pointer after this stat
            # and the next poll goes deep again
            self._ptr_stat = st
            v = self._version
            while True:
                m = read_manifest_version(self.root, v + 1)
                if m is None:
                    break
                v += 1
                self._manifests[v] = m
                new.append(v)
            self._version = v
            # keep a bounded manifest tail
            for old in sorted(self._manifests):
                if len(self._manifests) <= 64:
                    break
                del self._manifests[old]
        if self.events is not None:       # publish outside the poll lock
            for v_ in new:
                self.events.publish("manifest_advanced", version=v_,
                                    follower=True)
        return new

    def manifest(self, version: int | None = None) -> dict:
        if version is None:
            version = self._version
        version = int(version)
        m = self._manifests.get(version) or \
            read_manifest_version(self.root, version)
        if m is None:
            raise KeyError(f"catalog version {version} not found under "
                           f"{self.root!r}")
        return m

    def snapshot(self, version: int | None = None, *,
                 lazy: bool | None = None) -> CatalogSnapshot:
        """Immutable snapshot at ``version`` (default: latest, after an
        implicit :meth:`poll`).  ``lazy`` overrides the reader's default
        materialization mode for this call.

        The latest-snapshot path is race-proof against compaction: if a
        swap publishes and deletes our target's segments between the poll
        and the materialize, the reader re-polls and retries at the new
        head (the deletion itself proves a newer version exists).  An
        *explicitly* pinned historical version whose segments were
        compacted away raises ``KeyError`` instead — the caller asked for
        that version, not whatever is newest."""
        lazy = self.lazy if lazy is None else bool(lazy)
        if version is not None:
            try:
                return self._snapshot_at(int(version), lazy)
            except FileNotFoundError as e:
                raise KeyError(
                    f"catalog version {int(version)} is no longer "
                    f"materializable (its segments were compacted away); "
                    f"only snapshots materialized before the swap remain "
                    f"valid") from e
        self.poll()
        while True:
            head = self._version
            try:
                return self._snapshot_at(head, lazy)
            except FileNotFoundError:
                if not self.poll():     # head did not move: a real error
                    raise

    def _snapshot_at(self, version: int, lazy: bool) -> CatalogSnapshot:
        key = (version, lazy)
        with self._lock:
            if key in self._snaps:
                return self._snaps[key]
            # newest cached predecessor: an append-only advance extends it
            # with only the new segments (O(delta)) instead of re-reading
            # the lake.  A multi-segment lazy request already falls back
            # to the eager copy, so extension never loses lazy behavior.
            prev_key = max((k for k in self._snaps if k[0] < version),
                           default=None)
            prev = self._snaps.get(prev_key)
        snap = None
        if prev is not None:
            try:
                snap = extend_snapshot(self.root, prev,
                                       self.manifest(prev_key[0]),
                                       self.manifest(version))
            except KeyError:      # predecessor manifest aged out of the tail
                snap = None
        if snap is None:
            snap = materialize_snapshot(self.root, self.manifest(version),
                                        lazy=lazy)
        with self._lock:
            self._snaps[key] = snap
            while len(self._snaps) > self._max_cached:
                del self._snaps[min(self._snaps)]
        return snap


def add_lake(catalog: CatalogStore, lake, prefix: str = "table") -> list[int]:
    """Ingest every table of a ``core.lakegen`` synthetic lake (one delta
    segment per table — exercising the incremental path at scale)."""
    tids = []
    for t in np.unique(lake.batch.table_ids):
        idx = np.flatnonzero(lake.batch.table_ids == t)
        sub = _slice_batch(lake.batch, idx)
        tids.append(catalog.add_table(f"{prefix}{int(t)}", batch=sub))
    return tids
