"""Background compaction for the catalog store.

Compaction is the only catalog operation whose cost grows with the lake,
so it must never block ingest or queries.  :class:`BackgroundCompactor`
runs :meth:`~repro.service.catalog.CatalogStore.compact` on a single
worker thread: the compacted segment is built against a **pinned**
manifest version, concurrent ``add_table`` / ``drop_table`` proceed
normally (their delta segments are retained via manifest replay at
publish time), and readers keep serving whichever snapshot they pinned —
the swap is one CAS manifest advance, never a torn read.

Typical serving-loop wiring::

    store = CatalogStore(root)
    with BackgroundCompactor(store, min_segments=16) as compactor:
        for batch in ingest_stream:
            store.add_table(...)
            compactor.maybe_compact()     # non-blocking; coalesces
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor

from repro.service.catalog import CatalogStore


class BackgroundCompactor:
    """Off-thread, coalescing driver for ``store.compact()``.

    At most one compaction is in flight; :meth:`submit` while one runs
    returns the in-flight future instead of queueing another (compacting a
    head the running swap is about to replace would be wasted work).
    """

    def __init__(self, store: CatalogStore, *, min_segments: int = 8,
                 events=None):
        self.store = store
        self.min_segments = int(min_segments)
        # event sink: explicit, else the store's (so compaction lifecycle
        # events land on the same stream as its manifest advances)
        self.events = events if events is not None \
            else getattr(store, "events", None)
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="freyja-compact")
        self._lock = threading.Lock()
        self._inflight: Future | None = None
        self._closed = False

    # -- scheduling ---------------------------------------------------------

    def submit(self, **compact_kw) -> Future:
        """Schedule one compaction; returns its future (or the in-flight
        one — submissions during a running compaction coalesce)."""
        with self._lock:
            if self._closed:
                raise RuntimeError("compactor is closed")
            if self._inflight is not None and not self._inflight.done():
                return self._inflight
            self._inflight = self._pool.submit(
                self._run_compaction, compact_kw)
            return self._inflight

    def _run_compaction(self, compact_kw: dict):
        """Worker-thread body: the store's compact() bracketed by
        lifecycle events (compaction_published carries the new head
        version; a no-op or lost-race compact publishes started only)."""
        if self.events is not None:
            self.events.publish("compaction_started",
                                version=self.store.version)
        out = self.store.compact(**compact_kw)
        if self.events is not None:
            self.events.publish("compaction_published",
                                version=self.store.version)
        return out

    def maybe_compact(self, min_segments: int | None = None,
                      **compact_kw) -> Future | None:
        """Trigger a compaction iff the live segment count reached the
        threshold; None when below it (the common, free case)."""
        threshold = self.min_segments if min_segments is None \
            else int(min_segments)
        # count segments at the refreshed head, not this handle's last view:
        # deltas appended through OTHER writer handles must trigger too
        if len(self.store._refresh()["segments"]) < threshold:
            return None
        return self.submit(**compact_kw)

    # -- lifecycle ----------------------------------------------------------

    @property
    def busy(self) -> bool:
        with self._lock:
            return self._inflight is not None and not self._inflight.done()

    def wait(self, timeout: float | None = None) -> None:
        """Block until the in-flight compaction (if any) finishes,
        re-raising its exception."""
        with self._lock:
            fut = self._inflight
        if fut is not None:
            fut.result(timeout=timeout)

    def close(self, wait: bool = True) -> None:
        with self._lock:
            self._closed = True
        self._pool.shutdown(wait=wait)

    def __enter__(self) -> "BackgroundCompactor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
