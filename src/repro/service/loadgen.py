"""Open-loop (Poisson-arrival) load driver for the request scheduler.

Closed-loop measurements (time N back-to-back batches) hide queueing: the
benchmark only ever offers the next request once the last one finished.
An **open-loop** driver offers requests on a Poisson arrival process at a
fixed rate regardless of completion — so queue wait, deadline misses, and
load shedding become visible.  This is the shared measurement core behind
``benchmarks/bench_service.py --open-loop`` and
``launch/discover.py --open-loop``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.service.scheduler import (DeadlineExpired, RequestScheduler,
                                     SchedulerConfig, SchedulerOverloadError)


def run_open_loop(engine, pool, offered_qps: float, duration_s: float,
                  deadline_ms: float, *,
                  scheduler_config: SchedulerConfig | None = None,
                  seed: int = 0, max_arrivals: int | None = None) -> dict:
    """Offer a Poisson request stream to a fresh scheduler over ``engine``.

    ``pool`` is a list of :class:`DiscoveryRequest`\\ s cycled round-robin
    (reused objects are safe: requests are read-only on the serve path).
    Returns achieved QPS, goodput under the deadline, latency-incl-queue
    percentiles, shed and expiration rates, and the scheduler's formed-
    batch statistics.  ``max_arrivals`` bounds the submit loop (the run
    shortens rather than the rate dropping).
    """
    rng = np.random.default_rng(seed)
    n = max(int(offered_qps * duration_s), 16)
    if max_arrivals is not None:
        n = min(n, int(max_arrivals))
    arrivals = np.cumsum(rng.exponential(1.0 / offered_qps, size=n))
    scheduler = RequestScheduler(engine, scheduler_config)
    try:
        futures, shed = [], 0
        t0 = time.perf_counter()
        for i in range(n):
            gap = arrivals[i] - (time.perf_counter() - t0)
            if gap > 0:
                time.sleep(gap)
            try:
                futures.append(scheduler.submit(pool[i % len(pool)],
                                                deadline_ms=deadline_ms))
            except SchedulerOverloadError:
                shed += 1
        lats, expired = [], 0
        for f in futures:
            try:
                lats.append(f.result(timeout=300).latency_ms)
            except DeadlineExpired:
                expired += 1
        wall = time.perf_counter() - t0      # submit + drain
        stats = scheduler.stats()
    finally:
        scheduler.close()
    completed = len(lats)
    good = sum(1 for l in lats if l <= deadline_ms)
    return {
        "offered_qps": n / max(float(arrivals[-1]), 1e-9),
        "n_offered": n,
        "duration_s": wall,
        "qps": completed / max(wall, 1e-9),
        "goodput_qps": good / max(wall, 1e-9),
        "p50_ms": float(np.percentile(lats, 50)) if lats else None,
        "p99_ms": float(np.percentile(lats, 99)) if lats else None,
        "shed": shed, "shed_rate": shed / n,
        "expired": expired, "expired_rate": expired / n,
        "batches": stats["batches"],
        "batch_size_hist": stats["batch_size_hist"],
        "bucket_hits": stats["bucket_hits"],
        "buckets": stats["buckets"],
        "max_queue_depth": stats["max_queue_depth"],
    }
