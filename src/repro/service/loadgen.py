"""Open-loop (Poisson-arrival) load driver for the request scheduler.

Closed-loop measurements (time N back-to-back batches) hide queueing: the
benchmark only ever offers the next request once the last one finished.
An **open-loop** driver offers requests on a Poisson arrival process at a
fixed rate regardless of completion — so queue wait, deadline misses, and
load shedding become visible.  This is the shared measurement core behind
``benchmarks/bench_service.py --open-loop`` and
``launch/discover.py --open-loop``.

Every completion is retained individually (``completions``: per-request
finish timestamp + latency + trace spans), so a run's client-side latency
histogram can be cross-checked against the server-side metrics registry
(``ServiceMetrics``) — the two measure the same requests through
different instruments and must agree.  ``trace_phases`` aggregates the
per-request phase spans into per-phase p50/p99, and
``max_trace_sum_err_ms`` is the worst |sum(spans) - latency_ms| over the
run — the traces' exactness guarantee, measured.
"""
from __future__ import annotations

import time

import numpy as np

from repro.service.metrics import DEFAULT_LATENCY_BUCKETS_MS
from repro.service.scheduler import (DeadlineExpired, RequestScheduler,
                                     SchedulerConfig, SchedulerOverloadError)


def latency_histogram(lats_ms, buckets=DEFAULT_LATENCY_BUCKETS_MS) -> dict:
    """Cumulative bucket counts over ``lats_ms``, same boundaries (and
    same cumulative ``le`` semantics) as the server-side histogram — so
    client-observed latencies are directly comparable to a scrape."""
    lats = np.asarray(sorted(lats_ms), dtype=np.float64)
    out = {f"{float(b):g}": int(np.searchsorted(lats, float(b), "right"))
           for b in buckets}
    out["+Inf"] = int(lats.size)
    return out


def _trace_phase_stats(traces: list[list[dict]]) -> dict:
    by_phase: dict[str, list[float]] = {}
    for tr in traces:
        for span in tr:
            by_phase.setdefault(span["phase"], []).append(span["ms"])
    return {
        phase: {"n": len(ms),
                "p50_ms": float(np.percentile(ms, 50)),
                "p99_ms": float(np.percentile(ms, 99)),
                "total_ms": float(np.sum(ms))}
        for phase, ms in by_phase.items()
    }


def run_open_loop(engine, pool, offered_qps: float, duration_s: float,
                  deadline_ms: float, *,
                  scheduler_config: SchedulerConfig | None = None,
                  seed: int = 0, max_arrivals: int | None = None) -> dict:
    """Offer a Poisson request stream to a fresh scheduler over ``engine``.

    ``pool`` is a list of :class:`DiscoveryRequest`\\ s cycled round-robin
    (reused objects are safe: requests are read-only on the serve path).
    Returns achieved QPS, goodput under the deadline, latency-incl-queue
    percentiles, shed and expiration rates, the scheduler's formed-batch
    statistics, plus the per-request ``completions`` record and trace
    aggregates described in the module docstring.  ``max_arrivals``
    bounds the submit loop (the run shortens rather than the rate
    dropping).
    """
    rng = np.random.default_rng(seed)
    n = max(int(offered_qps * duration_s), 16)
    if max_arrivals is not None:
        n = min(n, int(max_arrivals))
    arrivals = np.cumsum(rng.exponential(1.0 / offered_qps, size=n))
    scheduler = RequestScheduler(engine, scheduler_config)
    try:
        futures, shed = [], 0
        t0 = time.perf_counter()
        for i in range(n):
            gap = arrivals[i] - (time.perf_counter() - t0)
            if gap > 0:
                time.sleep(gap)
            try:
                futures.append(scheduler.submit(pool[i % len(pool)],
                                                deadline_ms=deadline_ms))
            except SchedulerOverloadError:
                shed += 1
        completions, expired = [], 0
        for f in futures:
            try:
                r = f.result(timeout=300)
            except DeadlineExpired:
                expired += 1
                continue
            # completion timestamp is taken as results are drained — for
            # already-resolved futures it trails the true finish slightly,
            # but it is monotone in finish order, which is what throughput-
            # over-time plots need
            completions.append({
                "t_done_s": time.perf_counter() - t0,
                "latency_ms": r.latency_ms,
                "queue_ms": r.queue_ms,
                "compute_ms": r.compute_ms,
                "cached": r.cached,
                "trace_id": r.trace_id,
                "trace": r.trace,
            })
        wall = time.perf_counter() - t0      # submit + drain
        stats = scheduler.stats()
    finally:
        scheduler.close()
    lats = [c["latency_ms"] for c in completions]
    completed = len(lats)
    good = sum(1 for l in lats if l <= deadline_ms)
    trace_err = [abs(sum(s["ms"] for s in c["trace"]) - c["latency_ms"])
                 for c in completions if c["trace"]]
    return {
        "offered_qps": n / max(float(arrivals[-1]), 1e-9),
        "n_offered": n,
        "duration_s": wall,
        "qps": completed / max(wall, 1e-9),
        "goodput_qps": good / max(wall, 1e-9),
        "p50_ms": float(np.percentile(lats, 50)) if lats else None,
        "p99_ms": float(np.percentile(lats, 99)) if lats else None,
        "shed": shed, "shed_rate": shed / n,
        "expired": expired, "expired_rate": expired / n,
        "batches": stats["batches"],
        "batch_size_hist": stats["batch_size_hist"],
        "bucket_hits": stats["bucket_hits"],
        "buckets": stats["buckets"],
        "max_queue_depth": stats["max_queue_depth"],
        "completions": completions,
        "latency_hist": latency_histogram(lats),
        "trace_phases": _trace_phase_stats(
            [c["trace"] for c in completions if c["trace"]]),
        "max_trace_sum_err_ms": max(trace_err) if trace_err else None,
    }
