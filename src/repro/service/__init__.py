"""Online join-discovery service on top of the FREYJA core.

Layers (bottom-up):

* ``catalog``   — persistent on-disk column catalog split into an MVCC
  writer/reader pair: :class:`CatalogStore` (immutable delta segments,
  versioned manifest chain advanced via compare-and-swap, advisory
  :class:`WriterLease` for compaction) and :class:`CatalogReader` (tails
  the chain, serves immutable snapshots keyed by version);
* ``compactor`` — :class:`BackgroundCompactor`: off-thread compaction
  against a pinned version, CAS-published swap, concurrent adds retained
  via manifest replay;
* ``lsh``       — banded-MinHash band keys over the catalog's signatures
  (the candidate-stage input of the execution layer);
* ``engine``    — ``DiscoveryEngine``: batches concurrent queries, pins
  one snapshot version per batch (refcounted release of retired
  versions), plans each micro-batch through the unified
  candidate→score→merge executor (``repro.exec``), and fronts it with a
  version-namespaced cost-aware LRU result cache + per-plan stats();
  ``engine.follow(reader)`` turns it into a read replica;
* ``scheduler`` — :class:`RequestScheduler`: the continuous-batching
  request runtime — a future-based ``submit(request, deadline_ms=,
  priority=)`` front door whose background worker coalesces queued
  arrivals into bucket-snapped micro-batches, expires past-deadline
  requests, and sheds load via bounded-queue admission;
* ``api``       — request/response dataclasses and the ``serve_discovery``
  compatibility adapter (request-order draining over the scheduler);
* ``events``    — the observability spine: a bounded multi-consumer
  :class:`EventBus` every serving component publishes typed events into
  (non-blocking publish, drop-oldest overflow, per-consumer dropped
  accounting) plus ``mint_trace_id`` for the per-request trace ids;
* ``metrics``   — Prometheus-style :class:`MetricsRegistry`,
  :class:`ServiceMetrics` (the standard counters/gauges/histograms fed
  by an event-bus consumer + direct latency instrumentation), and
  :class:`MetricsServer` (stdlib ``GET /metrics`` endpoint);
* ``fleet``     — :class:`EngineFleet`: N engine replicas (each a
  catalog follower pinned to its own device slice) behind the pure
  deterministic :class:`FleetRouter`, with a warm→serve→drain→evict
  replica lifecycle, health-check eviction, and in-flight batch
  re-dispatch; ``RequestScheduler(fleet)`` is a drop-in upgrade from a
  single engine.  :class:`FaultInjector` is the testing hook that kills
  or hangs replicas at named points.
"""
from repro.service.api import (ColumnMatch, DiscoveryRequest,
                               DiscoveryResponse, serve_discovery)
from repro.service.catalog import (CatalogReader, CatalogSnapshot,
                                   CatalogStore, ColumnCatalog,
                                   LeaseHeldError, WriterLease, add_lake,
                                   materialize_snapshot)
from repro.service.compactor import BackgroundCompactor
from repro.service.engine import DiscoveryEngine, EngineConfig, measure_recall
from repro.service.events import Event, EventBus, EventCursor, mint_trace_id
from repro.service.fleet import (EngineFleet, EngineReplica, FaultInjector,
                                 FleetConfig, FleetRouter, ReplicaKilled,
                                 ReplicaSnapshot)
from repro.service.lsh import (LSHConfig, LSHIndex, band_keys,
                               coarse_band_keys)
from repro.service.metrics import (MetricsRegistry, MetricsServer,
                                   ServiceMetrics, parse_exposition)
from repro.service.scheduler import (DeadlineExpired, RequestScheduler,
                                     SchedulerConfig, SchedulerOverloadError)

__all__ = [
    "ColumnMatch", "DiscoveryRequest", "DiscoveryResponse", "serve_discovery",
    "CatalogReader", "CatalogSnapshot", "CatalogStore", "ColumnCatalog",
    "LeaseHeldError", "WriterLease", "add_lake", "materialize_snapshot",
    "BackgroundCompactor",
    "DiscoveryEngine", "EngineConfig", "measure_recall",
    "Event", "EventBus", "EventCursor", "mint_trace_id",
    "EngineFleet", "EngineReplica", "FaultInjector", "FleetConfig",
    "FleetRouter", "ReplicaKilled", "ReplicaSnapshot",
    "LSHConfig", "LSHIndex", "band_keys", "coarse_band_keys",
    "MetricsRegistry", "MetricsServer", "ServiceMetrics", "parse_exposition",
    "DeadlineExpired", "RequestScheduler", "SchedulerConfig",
    "SchedulerOverloadError",
]
