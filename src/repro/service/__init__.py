"""Online join-discovery service on top of the FREYJA core.

Layers (bottom-up):

* ``catalog``  — persistent on-disk column catalog: profile / signature /
  metadata segments with incremental add/drop and compaction;
* ``lsh``      — banded-MinHash band keys over the catalog's signatures
  (the candidate-stage input of the execution layer);
* ``engine``   — ``DiscoveryEngine``: batches concurrent queries, plans
  each micro-batch through the unified candidate→score→merge executor
  (``repro.exec``: full-scan / LSH / hybrid × local / mesh-sharded), and
  fronts it with a cost-aware LRU result cache + per-plan stats();
* ``api``      — request/response dataclasses and the ``serve_discovery``
  entry point.
"""
from repro.service.api import (ColumnMatch, DiscoveryRequest,
                               DiscoveryResponse, serve_discovery)
from repro.service.catalog import CatalogSnapshot, ColumnCatalog, add_lake
from repro.service.engine import DiscoveryEngine, EngineConfig, measure_recall
from repro.service.lsh import LSHConfig, LSHIndex, band_keys

__all__ = [
    "ColumnMatch", "DiscoveryRequest", "DiscoveryResponse", "serve_discovery",
    "CatalogSnapshot", "ColumnCatalog", "add_lake",
    "DiscoveryEngine", "EngineConfig", "measure_recall",
    "LSHConfig", "LSHIndex", "band_keys",
]
