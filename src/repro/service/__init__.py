"""Online join-discovery service on top of the FREYJA core.

Layers (bottom-up):

* ``catalog``  — persistent on-disk column catalog: profile / signature /
  metadata segments with incremental add/drop and compaction;
* ``lsh``      — banded-MinHash candidate generation over the catalog's
  signatures (device-side batched bucket probe);
* ``engine``   — ``DiscoveryEngine``: batches concurrent queries through the
  two-stage pipeline (LSH candidates -> GBDT re-rank) with an LRU result
  cache, plus full-scan and mesh-sharded fallbacks;
* ``api``      — request/response dataclasses and the ``serve_discovery``
  entry point.
"""
from repro.service.api import (ColumnMatch, DiscoveryRequest,
                               DiscoveryResponse, serve_discovery)
from repro.service.catalog import CatalogSnapshot, ColumnCatalog, add_lake
from repro.service.engine import DiscoveryEngine, EngineConfig, measure_recall
from repro.service.lsh import LSHConfig, LSHIndex, band_keys

__all__ = [
    "ColumnMatch", "DiscoveryRequest", "DiscoveryResponse", "serve_discovery",
    "CatalogSnapshot", "ColumnCatalog", "add_lake",
    "DiscoveryEngine", "EngineConfig", "measure_recall",
    "LSHConfig", "LSHIndex", "band_keys",
]
