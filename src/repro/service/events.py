"""Structured event bus for the serving plane.

Every serving component publishes typed events into one
:class:`EventBus` — a **bounded, multi-consumer ring buffer**:

* ``publish`` is **non-blocking**: it appends under a short lock and
  returns; a slow (or absent) consumer can never stall the scheduler
  worker, the compactor thread, or a submitter.  When the ring wraps,
  the **oldest** events are overwritten (drop-oldest) — the publisher
  never waits and never fails;
* each consumer holds its own :class:`EventCursor`: cursors advance
  independently, so the metrics aggregator, a debug tail, and a test
  assertion can all read the same stream at their own pace;
* overflow is **accounted per consumer**: a cursor that fell behind the
  ring reports exactly how many events it missed (``cursor.dropped``),
  so "the operator's counters are complete" is a checkable claim, not
  an assumption.

Event taxonomy (the ``type`` strings components publish):

==========================  =================================================
``request_admitted``        scheduler accepted a submission (trace_id, name)
``request_shed``            bounded-queue admission dropped it (queue full)
``request_expired``         deadline passed while queued (waited_ms)
``batch_formed``            worker staged a micro-batch (n, trace_ids)
``cache_hit`` / ``cache_miss``  engine result-cache outcome per batch
``compile_begin`` / ``compile_end``  executor first contact with a
                            (plan kind, grid, batch shape) — the jit spike
``snapshot_pinned``         a query batch pinned an MVCC version
``snapshot_retired``        last reference released; executor closed
``compaction_started`` / ``compaction_published``  background compactor
``manifest_advanced``       catalog manifest chain grew a version
``coarse_pass``             tiered candidate stage: super-band digest swept
                            the lake (survivor counts + fraction)
``fine_probe``              tiered candidate stage: banded probe + scoring
                            ran on the gathered survivors
``warmup_begin``            engine AOT warmup started (scope, buckets,
                            n_plans)
``warmup_end``              warmup finished (executables, hits/misses,
                            wall_ms)
``executable_cache_hit``    warmup loaded one executable from the
                            persistent cache (``remaining`` counts down)
``executable_cache_miss``   warmup compiled one executable fresh (a
                            ``compile_begin``/``end`` pair brackets it)
``replica_state``           a fleet replica changed lifecycle state
                            (replica, state ∈ warming/serving/draining/
                            evicted, reason)
``batch_routed``            the fleet router placed a formed batch on a
                            replica (replica, n, queue_depth)
``batch_redispatched``      a batch was re-dispatched off a failed/evicted
                            replica (replica, n, attempts)
``refresh_begin``           engine snapshot refresh started
                            (version_from, version_to)
``refresh_end``             refresh swapped (version_from, version_to,
                            incremental, delta_columns, bytes_uploaded,
                            recompiles, coalesced, ms)
==========================  =================================================

Payloads are free-form keyword dicts; the constants below are the
canonical type names (components may publish additional types — the bus
does not validate, it transports).
"""
from __future__ import annotations

import itertools
import os
import threading
import time
import typing

REQUEST_ADMITTED = "request_admitted"
REQUEST_SHED = "request_shed"
REQUEST_EXPIRED = "request_expired"
BATCH_FORMED = "batch_formed"
CACHE_HIT = "cache_hit"
CACHE_MISS = "cache_miss"
COMPILE_BEGIN = "compile_begin"
COMPILE_END = "compile_end"
SNAPSHOT_PINNED = "snapshot_pinned"
SNAPSHOT_RETIRED = "snapshot_retired"
COMPACTION_STARTED = "compaction_started"
COMPACTION_PUBLISHED = "compaction_published"
MANIFEST_ADVANCED = "manifest_advanced"
COARSE_PASS = "coarse_pass"
FINE_PROBE = "fine_probe"
WARMUP_BEGIN = "warmup_begin"
WARMUP_END = "warmup_end"
EXECUTABLE_CACHE_HIT = "executable_cache_hit"
EXECUTABLE_CACHE_MISS = "executable_cache_miss"
REPLICA_STATE = "replica_state"
BATCH_ROUTED = "batch_routed"
BATCH_REDISPATCHED = "batch_redispatched"
REFRESH_BEGIN = "refresh_begin"
REFRESH_END = "refresh_end"

EVENT_TYPES = (
    REQUEST_ADMITTED, REQUEST_SHED, REQUEST_EXPIRED, BATCH_FORMED,
    CACHE_HIT, CACHE_MISS, COMPILE_BEGIN, COMPILE_END,
    SNAPSHOT_PINNED, SNAPSHOT_RETIRED,
    COMPACTION_STARTED, COMPACTION_PUBLISHED, MANIFEST_ADVANCED,
    COARSE_PASS, FINE_PROBE,
    WARMUP_BEGIN, WARMUP_END, EXECUTABLE_CACHE_HIT, EXECUTABLE_CACHE_MISS,
    REPLICA_STATE, BATCH_ROUTED, BATCH_REDISPATCHED,
    REFRESH_BEGIN, REFRESH_END,
)

# trace ids: cheap, process-unique, monotonic within a session — NOT
# uuids (minting happens on the submit hot path)
_TRACE_PREFIX = os.urandom(3).hex()
_trace_counter = itertools.count()


def mint_trace_id() -> str:
    """A process-unique trace id, e.g. ``"3fa9c1-0000002a"``."""
    return f"{_TRACE_PREFIX}-{next(_trace_counter):08x}"


class Event(typing.NamedTuple):
    """One published event.  Immutable; shared by every consumer.

    A NamedTuple rather than a (frozen) dataclass: construction happens
    once per publish on the serving hot path, and the tuple C path is
    several times cheaper than per-field ``object.__setattr__``.
    """

    seq: int                 # bus-assigned, dense, monotonically increasing
    type: str
    t: float                 # wall-clock seconds (time.time())
    payload: dict


class EventCursor:
    """One consumer's position in the bus's ring.

    ``poll`` returns the events published since the last poll (up to
    ``max_events``); when the consumer fell more than the ring capacity
    behind, the overwritten events are skipped and counted in
    ``dropped`` — the stream never blocks and never duplicates.
    """

    def __init__(self, bus: "EventBus", name: str):
        self._bus = bus
        self.name = name
        self.next_seq = bus._next      # subscribe at the current tail
        self.dropped = 0
        self.delivered = 0

    def poll(self, max_events: int | None = None) -> list[Event]:
        return self._bus._poll(self, max_events)

    def close(self) -> None:
        self._bus._unsubscribe(self)


class EventBus:
    """Bounded multi-consumer ring buffer with non-blocking publish."""

    def __init__(self, capacity: int = 8192):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = int(capacity)
        self._ring: list[Event | None] = [None] * self.capacity
        self._next = 0                   # seq the next publish gets
        self._lock = threading.Lock()
        self._published: dict[str, int] = {}
        self._cursors: list[EventCursor] = []

    # -- publishing ----------------------------------------------------------

    def publish(self, type: str, **payload) -> int:
        """Append one event; returns its seq.  Never blocks on consumers:
        the only wait is the ring's own short lock, and overflow
        overwrites the oldest slot instead of stalling the caller."""
        t = time.time()
        with self._lock:
            seq = self._next
            self._ring[seq % self.capacity] = Event(seq=seq, type=type,
                                                    t=t, payload=payload)
            self._next = seq + 1
            self._published[type] = self._published.get(type, 0) + 1
        return seq

    # -- consuming -----------------------------------------------------------

    def subscribe(self, name: str | None = None) -> EventCursor:
        """New consumer cursor, positioned at the current tail (it sees
        only events published after this call)."""
        with self._lock:
            cur = EventCursor(self, name or f"consumer-{len(self._cursors)}")
            self._cursors.append(cur)
            return cur

    def _poll(self, cursor: EventCursor,
              max_events: int | None = None) -> list[Event]:
        with self._lock:
            head = self._next
            lo = max(cursor.next_seq, head - self.capacity)
            cursor.dropped += lo - cursor.next_seq
            hi = head if max_events is None else min(head, lo + max_events)
            out = [self._ring[i % self.capacity] for i in range(lo, hi)]
            cursor.next_seq = hi
            cursor.delivered += len(out)
        return out

    def _unsubscribe(self, cursor: EventCursor) -> None:
        with self._lock:
            if cursor in self._cursors:
                self._cursors.remove(cursor)

    # -- observability -------------------------------------------------------

    def stats(self) -> dict:
        """Publisher-side totals per type plus per-consumer delivered /
        dropped accounting (the metrics layer exports these)."""
        with self._lock:
            return {
                "capacity": self.capacity,
                "published": int(self._next),
                "published_by_type": dict(self._published),
                "consumers": {
                    c.name: {"delivered": c.delivered,
                             "dropped": c.dropped,
                             "lag": int(self._next - c.next_seq)}
                    for c in self._cursors
                },
            }
