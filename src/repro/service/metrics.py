"""Prometheus-style metrics for the serving plane.

Three layers:

* a minimal metric **registry** (:class:`MetricsRegistry`) holding
  counters, gauges, and fixed-bucket histograms — rendered in the
  Prometheus text exposition format (``render``) and as a plain nested
  dict for bench JSON snapshots (``collect``);
* :class:`ServiceMetrics` — the standard serving wiring: one event-bus
  consumer (:meth:`drain`) folds the structured event stream
  (``request_admitted``, ``batch_formed``, ``cache_hit`` …) into
  counters, plus **direct instrumentation** for the per-request latency
  split (``observe_response`` feeds the queue / compute / end-to-end
  histograms the event stream is too coarse for);
* :class:`MetricsServer` — an optional stdlib-HTTP endpoint thread
  serving ``GET /metrics`` (enable with ``discover --metrics-port`` or
  by constructing one around ``engine.metrics``).

The registry is deliberately dependency-free (no prometheus_client):
the point is the *contract* — a text exposition any scraper parses —
not the client library.  ``parse_exposition`` is the inverse used by
the CI smoke gate and the golden tests.
"""
from __future__ import annotations

import bisect
import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.service import events as EV

# fixed bucket ladders (milliseconds; +Inf is implicit)
DEFAULT_LATENCY_BUCKETS_MS = (0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
                              100.0, 200.0, 500.0, 1000.0, 2500.0, 5000.0)
BATCH_SIZE_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)
COMPILE_BUCKETS_MS = (10.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0,
                      5000.0, 10000.0, 30000.0)
SURVIVOR_FRACTION_BUCKETS = (0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0)
REFRESH_BUCKETS_MS = (1.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                      1000.0, 2500.0, 5000.0, 15000.0, 60000.0)
DELTA_COLUMNS_BUCKETS = (16, 64, 256, 1024, 4096, 16384, 65536)


def _fmt(v: float) -> str:
    """Prometheus-style number: integers bare, floats repr'd."""
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    esc = lambda v: str(v).replace("\\", "\\\\").replace('"', '\\"')
    inner = ",".join(f'{k}="{esc(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry"):
        self.name = name
        self.help = help
        self._lock = registry._lock     # one registry-wide lock: a render
        self._children: dict = {}       # is one consistent snapshot

    def _child_key(self, labels: dict) -> tuple:
        return tuple(sorted(labels.items()))


class Counter(_Metric):
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError("counters only go up")
        k = self._child_key(labels)
        with self._lock:
            self._children[k] = self._children.get(k, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._children.get(self._child_key(labels), 0.0))

    def _render(self) -> list[str]:
        return [f"{self.name}{_label_str(dict(k))} {_fmt(v)}"
                for k, v in sorted(self._children.items())] or \
            [f"{self.name} 0"]

    def _collect(self):
        return {_label_str(dict(k)) or "": v
                for k, v in self._children.items()} or {"": 0.0}


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._children[self._child_key(labels)] = float(value)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._children.get(self._child_key(labels), 0.0))

    _render = Counter._render
    _collect = Counter._collect


class Histogram(_Metric):
    """Fixed-bucket histogram: cumulative ``_bucket{le=...}`` series plus
    ``_sum`` / ``_count``, exactly the Prometheus contract."""

    kind = "histogram"

    def __init__(self, name, help, registry, buckets):
        super().__init__(name, help, registry)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self._counts = [0] * (len(self.buckets) + 1)   # +Inf last
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self._observe_locked(v)

    def _observe_locked(self, value: float) -> None:
        # caller holds the registry lock (hot paths batch several
        # observations into one lock round)
        self._counts[bisect.bisect_left(self.buckets, value)] += 1
        self._sum += value
        self._count += 1

    def _render(self) -> list[str]:
        out, cum = [], 0
        for le, n in zip(self.buckets + (math.inf,), self._counts):
            cum += n
            out.append(f'{self.name}_bucket{{le="{_fmt(le)}"}} {cum}')
        out.append(f"{self.name}_sum {_fmt(self._sum)}")
        out.append(f"{self.name}_count {self._count}")
        return out

    def _collect(self):
        cum, buckets = 0, {}
        for le, n in zip(self.buckets + (math.inf,), self._counts):
            cum += n
            buckets[_fmt(le)] = cum
        return {"buckets": buckets, "sum": self._sum, "count": self._count}


class MetricsRegistry:
    """Named metrics with idempotent registration and atomic snapshots."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name, help, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(f"metric {name!r} already registered "
                                     f"as a {m.kind}")
                return m
            m = cls(name, help, self, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_LATENCY_BUCKETS_MS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def render(self) -> str:
        """Text exposition (one consistent snapshot under the registry
        lock: a scrape during a concurrent batch can't interleave a
        counter from one batch with a histogram from another)."""
        lines = []
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {m.kind}")
                lines.extend(m._render())
        return "\n".join(lines) + "\n"

    def collect(self) -> dict:
        """Nested plain-dict snapshot (bench JSON)."""
        with self._lock:
            return {name: {"type": m.kind, "values": m._collect()}
                    for name, m in sorted(self._metrics.items())}


def parse_exposition(text: str) -> dict[str, dict[str, float]]:
    """Inverse of :meth:`MetricsRegistry.render`:
    ``{series_name: {label_string_or_empty: value}}`` — what the CI
    smoke gate asserts against the live endpoint."""
    out: dict[str, dict[str, float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        series, val = line.rsplit(" ", 1)
        if "{" in series:
            name, rest = series.split("{", 1)
            labels = "{" + rest
        else:
            name, labels = series, ""
        out.setdefault(name, {})[labels] = \
            math.inf if val == "+Inf" else float(val)
    return out


# ---------------------------------------------------------------------------
# standard serving wiring
# ---------------------------------------------------------------------------

class ServiceMetrics:
    """The serving plane's standard metric set over one event bus.

    Event-derived counters update on :meth:`drain` (the scheduler worker
    drains after every formed batch; a scrape drains too, so counters
    are current even with no traffic between scrapes).  The latency
    histograms are **direct instrumentation** — ``observe_response`` per
    served request — because one event per request would be the wrong
    trade on the hot path.
    """

    def __init__(self, bus: EV.EventBus,
                 registry: MetricsRegistry | None = None):
        self.bus = bus
        self.registry = registry or MetricsRegistry()
        self._cursor = bus.subscribe("metrics")
        self._scheduler = None
        r = self.registry
        self.requests_admitted = r.counter(
            "requests_admitted_total", "requests accepted by the scheduler")
        self.requests_shed = r.counter(
            "requests_shed_total", "requests dropped by bounded admission")
        self.requests_expired = r.counter(
            "requests_expired_total", "requests whose deadline lapsed queued")
        self.requests_completed = r.counter(
            "requests_completed_total", "responses delivered to futures")
        self.batches_formed = r.counter(
            "batches_formed_total", "micro-batches staged by the worker")
        self.batch_size = r.histogram(
            "batch_size", "formed micro-batch sizes",
            buckets=BATCH_SIZE_BUCKETS)
        self.cache_hits = r.counter(
            "cache_hits_total", "engine result-cache hits")
        self.cache_misses = r.counter(
            "cache_misses_total", "engine result-cache misses")
        self.compiles = r.counter(
            "compiles_total", "executor first-contact compiles")
        self.compile_ms = r.histogram(
            "compile_ms", "first-contact compile+execute wall (ms)",
            buckets=COMPILE_BUCKETS_MS)
        self.warmups = r.counter(
            "warmups_total", "engine AOT warmup passes completed")
        self.executable_cache_hits = r.counter(
            "executable_cache_hits_total",
            "warmup executables loaded from the persistent cache")
        self.executable_cache_misses = r.counter(
            "executable_cache_misses_total",
            "warmup executables compiled fresh (cache miss)")
        self.warmup_remaining = r.gauge(
            "warmup_remaining",
            "executables still to warm in the running warmup pass")
        self.snapshot_pins = r.counter(
            "snapshot_pins_total", "MVCC snapshot pins")
        self.snapshots_retired = r.counter(
            "snapshots_retired_total", "MVCC versions fully released")
        self.compactions_started = r.counter(
            "compactions_started_total", "background compactions begun")
        self.compactions_published = r.counter(
            "compactions_published_total", "compaction swaps CAS-published")
        self.manifest_version = r.gauge(
            "catalog_manifest_version", "newest observed manifest version")
        self.queue_depth = r.gauge(
            "scheduler_queue_depth", "requests waiting in the scheduler")
        self.events_published = r.gauge(
            "event_bus_published_total", "events published into the bus")
        self.events_dropped = r.gauge(
            "event_bus_dropped_total",
            "events a consumer missed to ring overflow")
        self.coarse_passes = r.counter(
            "coarse_passes_total",
            "tiered candidate stage coarse digest sweeps")
        self.fine_probes = r.counter(
            "fine_probes_total",
            "tiered candidate stage fine probes over gathered survivors")
        self.survivor_fraction = r.histogram(
            "coarse_survivor_fraction",
            "fraction of the lake surviving the coarse digest pass",
            buckets=SURVIVOR_FRACTION_BUCKETS)
        self.batches_routed = r.counter(
            "batches_routed_total",
            "formed batches placed on a replica by the fleet router")
        self.redispatches = r.counter(
            "redispatches_total",
            "batches re-dispatched off a failed or evicted replica")
        self.replica_state_changes = r.counter(
            "replica_state_changes_total",
            "fleet replica lifecycle transitions (labeled by new state)")
        self.router_queue_depth = r.gauge(
            "router_queue_depth",
            "per-replica request queue depth at the last routed placement")
        self.refresh_ms = r.histogram(
            "refresh_ms", "snapshot refresh wall time (ms)",
            buckets=REFRESH_BUCKETS_MS)
        self.refresh_delta_columns = r.histogram(
            "refresh_delta_columns",
            "columns (re)placed per refresh — the delta on incremental "
            "refreshes, the full lake on rebuilds",
            buckets=DELTA_COLUMNS_BUCKETS)
        self.refreshes_incremental = r.counter(
            "refreshes_incremental_total",
            "refreshes served by the delta path (no rebuild)")
        self.refreshes_full = r.counter(
            "refreshes_full_total", "refreshes that rebuilt from scratch")
        self.placement_bytes_uploaded = r.counter(
            "placement_bytes_uploaded_total",
            "host->device bytes moved by refresh placements")
        self.refresh_recompiles = r.counter(
            "refresh_recompiles_total",
            "executables compiled fresh during a refresh re-warm")
        self.refreshes_coalesced = r.counter(
            "refreshes_coalesced_total",
            "pending manifest advances folded into a single refresh")
        self.queue_ms = r.histogram(
            "request_queue_ms", "submit -> batch formation wait (ms)")
        self.compute_ms = r.histogram(
            "request_compute_ms", "engine pipeline share per request (ms)")
        self.latency_ms = r.histogram(
            "request_latency_ms", "end-to-end latency incl queue (ms)")

    # -- direct instrumentation ---------------------------------------------

    def bind_scheduler(self, scheduler) -> None:
        """Let gauge refreshes read live queue depth (latest bind wins)."""
        self._scheduler = scheduler

    def observe_response(self, response) -> None:
        # one lock round for the four per-response updates — this runs
        # in the scheduler worker's critical path once per served request
        q = float(response.queue_ms)
        c = float(response.compute_ms)
        l = float(response.latency_ms)
        comp = self.requests_completed._children
        with self.registry._lock:
            comp[()] = comp.get((), 0.0) + 1.0
            self.queue_ms._observe_locked(q)
            self.compute_ms._observe_locked(c)
            self.latency_ms._observe_locked(l)

    # -- event consumption ---------------------------------------------------

    _EVENT_COUNTERS = {
        EV.REQUEST_ADMITTED: "requests_admitted",
        EV.REQUEST_SHED: "requests_shed",
        EV.REQUEST_EXPIRED: "requests_expired",
        EV.SNAPSHOT_PINNED: "snapshot_pins",
        EV.SNAPSHOT_RETIRED: "snapshots_retired",
        EV.COMPACTION_STARTED: "compactions_started",
        EV.COMPACTION_PUBLISHED: "compactions_published",
    }

    def drain(self) -> int:
        """Fold pending events into the registry; returns the number
        consumed.  Cheap (dict increments), safe from any thread.

        The simple counter types are bulk-counted into a plain dict
        first and applied as one locked increment per *type* — at
        serving rates ``request_admitted`` alone arrives once per
        submission, so per-event locked increments would make the
        worker's post-batch drain a measurable GIL tax."""
        evs = self._cursor.poll()
        counts: dict[str, int] = {}
        lookup = self._EVENT_COUNTERS.get
        for ev in evs:
            simple = lookup(ev.type)
            if simple is not None:
                counts[simple] = counts.get(simple, 0) + 1
            elif ev.type == EV.BATCH_FORMED:
                self.batches_formed.inc()
                self.batch_size.observe(ev.payload.get("n", 0))
            elif ev.type == EV.CACHE_HIT:
                self.cache_hits.inc(ev.payload.get("n", 1))
            elif ev.type == EV.CACHE_MISS:
                self.cache_misses.inc(ev.payload.get("n", 1))
            elif ev.type == EV.COMPILE_END:
                self.compiles.inc()
                self.compile_ms.observe(ev.payload.get("ms", 0.0))
            elif ev.type == EV.WARMUP_BEGIN:
                self.warmup_remaining.set(ev.payload.get("n_plans", 0))
            elif ev.type == EV.WARMUP_END:
                self.warmups.inc()
                self.warmup_remaining.set(0)
            elif ev.type == EV.EXECUTABLE_CACHE_HIT:
                self.executable_cache_hits.inc()
                rem = ev.payload.get("remaining")
                if rem is not None:
                    self.warmup_remaining.set(rem)
            elif ev.type == EV.EXECUTABLE_CACHE_MISS:
                self.executable_cache_misses.inc()
                rem = ev.payload.get("remaining")
                if rem is not None:
                    self.warmup_remaining.set(rem)
            elif ev.type == EV.COARSE_PASS:
                self.coarse_passes.inc()
                frac = ev.payload.get("survivor_fraction")
                if frac is not None:
                    self.survivor_fraction.observe(frac)
            elif ev.type == EV.FINE_PROBE:
                self.fine_probes.inc()
            elif ev.type == EV.MANIFEST_ADVANCED:
                v = ev.payload.get("version")
                if v is not None:
                    self.manifest_version.set(
                        max(self.manifest_version.value(), float(v)))
            elif ev.type == EV.BATCH_ROUTED:
                self.batches_routed.inc()
                rep = ev.payload.get("replica")
                depth = ev.payload.get("queue_depth")
                if rep is not None and depth is not None:
                    self.router_queue_depth.set(float(depth),
                                                replica=str(rep))
            elif ev.type == EV.BATCH_REDISPATCHED:
                self.redispatches.inc()
            elif ev.type == EV.REFRESH_END:
                p = ev.payload
                self.refresh_ms.observe(p.get("ms", 0.0))
                self.refresh_delta_columns.observe(p.get("delta_columns", 0))
                if p.get("incremental"):
                    self.refreshes_incremental.inc()
                else:
                    self.refreshes_full.inc()
                self.placement_bytes_uploaded.inc(p.get("bytes_uploaded", 0))
                self.refresh_recompiles.inc(p.get("recompiles", 0))
                if p.get("coalesced"):
                    self.refreshes_coalesced.inc(p["coalesced"])
            elif ev.type == EV.REPLICA_STATE:
                self.replica_state_changes.inc(
                    state=str(ev.payload.get("state", "")))
        for name, k in counts.items():
            getattr(self, name).inc(k)
        return len(evs)

    def _refresh_gauges(self) -> None:
        bus = self.bus.stats()
        self.events_published.set(bus["published"])
        for name, c in bus["consumers"].items():
            self.events_dropped.set(c["dropped"], consumer=name)
        if self._scheduler is not None:
            self.queue_depth.set(self._scheduler.queue_depth)

    # -- snapshots -----------------------------------------------------------

    def render(self) -> str:
        self.drain()
        self._refresh_gauges()
        return self.registry.render()

    def collect(self) -> dict:
        self.drain()
        self._refresh_gauges()
        return self.registry.collect()


# ---------------------------------------------------------------------------
# HTTP endpoint
# ---------------------------------------------------------------------------

class MetricsServer:
    """Stdlib-HTTP metrics endpoint (``GET /metrics``) on a daemon thread.

    ``source`` is anything with a ``render() -> str`` (a
    :class:`ServiceMetrics` or a bare :class:`MetricsRegistry`).
    ``port=0`` binds an ephemeral port — read it back from ``.port``
    (what the tests and the CI smoke gate do).
    """

    def __init__(self, source, port: int = 0, host: str = "127.0.0.1"):
        self.source = source
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):
                if self.path.split("?")[0] not in ("/metrics", "/"):
                    self.send_error(404)
                    return
                body = outer.source.render().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):    # no per-scrape stderr noise
                pass

        self._httpd = ThreadingHTTPServer((host, int(port)), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True, name="freyja-metrics")
        self._thread.start()

    @property
    def port(self) -> int:
        return int(self._httpd.server_address[1])

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
