"""Request/response surface of the discovery service.

Requests enter the system through the continuous-batching runtime
(:class:`~repro.service.scheduler.RequestScheduler`): ``submit`` returns a
future per request, a background worker coalesces queued arrivals into
bucket-snapped micro-batches, and every response carries the split
``queue_ms`` / ``compute_ms`` latency.

``serve_discovery`` survives as a thin **compatibility adapter** over the
scheduler: it drains an iterable of requests and yields responses in
request order, exactly like the synchronous loop it replaced — the
batching underneath is now the scheduler's (coalescing window + bucket
ladder) rather than fixed ``max_batch`` chunks, which only changes *when*
device dispatches happen, never which response belongs to which request.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, Iterator, Sequence


@dataclasses.dataclass
class DiscoveryRequest:
    """One discovery-by-attribute query.

    Exactly one of:
    * ``column_id`` — a column already resident in the catalog snapshot
      (position in the snapshot ordering);
    * ``values``    — a raw string column to profile on the fly.
    """

    name: str = "query"
    column_id: int | None = None
    values: Sequence[str] | None = None
    k: int | None = None            # trim below the engine's k if smaller
    # caller-supplied trace id; None lets the scheduler (or the engine,
    # for direct calls) mint one at submit.  Carried through every event
    # and span this request generates.  NOTE: load drivers reuse request
    # objects, so the scheduler's per-submission id lives on the queue
    # item — this field only seeds it
    trace_id: str | None = None
    # stashed (geometry, numeric, words, sigs) profile of an uploaded
    # column — written by DiscoveryEngine.profile_request (the scheduler
    # calls it at submit time, in the submitter's thread) so the formed
    # batch's device path never profiles; keyed by signature geometry and
    # re-profiled on mismatch, z-scored per pinned snapshot at resolve
    _profile: tuple | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self):
        if (self.column_id is None) == (self.values is None):
            raise ValueError("pass exactly one of column_id= or values=")


@dataclasses.dataclass
class ColumnMatch:
    column_id: int
    column: str
    table: str
    score: float


@dataclasses.dataclass
class DiscoveryResponse:
    name: str
    matches: list[ColumnMatch]
    n_candidates: int               # columns actually scored for this query
    cached: bool = False
    queue_ms: float = 0.0           # submit -> batch formation (scheduler)
    compute_ms: float = 0.0         # engine resolve+plan+execute share
    latency_ms: float = 0.0         # queue_ms + compute_ms
    trace_id: str | None = None     # minted at submit, threaded end-to-end
    # per-phase spans [{"phase": str, "ms": float, ...}, ...] partitioning
    # latency_ms exactly: the scheduler contributes profile/queue, the
    # engine contributes pin/resolve/plan/candidates/execute/finalize
    # (batch-level walls divided by batch size, same normalization as
    # compute_ms; an execute span carries "compile_ms" when its bucket/
    # grid paid first contact).  sum(ms) == latency_ms to float precision
    trace: list = dataclasses.field(default_factory=list)


def serve_discovery(engine, requests: Iterable[DiscoveryRequest],
                    max_batch: int = 64,
                    scheduler=None) -> Iterator[DiscoveryResponse]:
    """Drain ``requests`` through ``engine``; yield responses in request
    order.

    Compatibility adapter over :class:`RequestScheduler`: each request is
    submitted as it is drawn from the iterable (with ``block=True``, so a
    full queue is backpressure on the producer, never a shed) and
    responses are yielded strictly in submission order regardless of the
    order batches complete in.  ``max_batch`` caps the scheduler's formed
    batches, preserving the old chunking bound.  Pass an existing
    ``scheduler`` to share one runtime across callers; otherwise a
    private one is created and closed on exhaustion.
    """
    from repro.service.scheduler import RequestScheduler, SchedulerConfig

    own = scheduler is None
    if own:
        scheduler = RequestScheduler(
            engine, SchedulerConfig(max_batch=int(max_batch)))
    pending: deque = deque()
    try:
        for req in requests:
            pending.append(scheduler.submit(req, block=True))
            while pending and pending[0].done():
                yield pending.popleft().result()
        while pending:
            yield pending.popleft().result()
    finally:
        if own:
            scheduler.close()
