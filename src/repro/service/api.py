"""Request/response surface of the discovery service.

``serve_discovery`` is the entry point a server loop (or the CLI driver in
``launch/discover.py``) feeds: it drains an iterable of requests in
micro-batches so concurrent queries share one device dispatch, and yields
responses in request order.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Sequence


@dataclasses.dataclass
class DiscoveryRequest:
    """One discovery-by-attribute query.

    Exactly one of:
    * ``column_id`` — a column already resident in the catalog snapshot
      (position in the snapshot ordering);
    * ``values``    — a raw string column to profile on the fly.
    """

    name: str = "query"
    column_id: int | None = None
    values: Sequence[str] | None = None
    k: int | None = None            # trim below the engine's k if smaller

    def __post_init__(self):
        if (self.column_id is None) == (self.values is None):
            raise ValueError("pass exactly one of column_id= or values=")


@dataclasses.dataclass
class ColumnMatch:
    column_id: int
    column: str
    table: str
    score: float


@dataclasses.dataclass
class DiscoveryResponse:
    name: str
    matches: list[ColumnMatch]
    n_candidates: int               # columns actually scored for this query
    cached: bool = False
    latency_ms: float = 0.0


def serve_discovery(engine, requests: Iterable[DiscoveryRequest],
                    max_batch: int = 64) -> Iterator[DiscoveryResponse]:
    """Drain ``requests`` through ``engine`` in micro-batches."""
    pending: list[DiscoveryRequest] = []

    def flush():
        out = engine.query_batch(pending)
        pending.clear()
        return out

    for req in requests:
        pending.append(req)
        if len(pending) >= max_batch:
            yield from flush()
    if pending:
        yield from flush()
