"""Continuous-batching request runtime for the discovery engine.

The synchronous serving surface (``serve_discovery`` draining an iterable
in fixed-size chunks) cannot coalesce arrivals across callers, has no
backpressure, and forms whatever batch size the iterable happened to
yield — mostly *not* the sizes the 2-D grid planner is fastest at.  This
module replaces it with an asynchronous scheduler:

* :meth:`RequestScheduler.submit` is the request entry point: it enqueues
  one :class:`~repro.service.api.DiscoveryRequest` and immediately
  returns a ``concurrent.futures.Future`` that resolves to the
  :class:`~repro.service.api.DiscoveryResponse` (or raises
  :class:`DeadlineExpired`).  Uploaded (``values=``) columns are profiled
  **in the submitter's thread** against the engine's current snapshot
  geometry, so the worker's formed-batch path is pure scoring dispatch;
* a single background worker forms **micro-batches** by coalescing the
  queued arrivals within a bounded wait window (``max_wait_ms``), in
  priority order (higher first, FIFO within a priority);
* formed batches are **snapped to a bucket ladder** (``batch_buckets``):
  the engine pads each batch up to the smallest bucket that fits, so
  only a handful of compiled executables — and the planner grid choices
  measured for exactly those sizes — ever exist, instead of one per odd
  batch size.  The ladder is installed on the engine's planner at
  scheduler construction (``launch.costmodel.derive_batch_buckets`` can
  derive it from a measured ``BENCH_service.json`` batch sweep);
* **deadline-aware admission**: a request submitted with ``deadline_ms=``
  is dropped at batch-formation time once its deadline has passed (its
  future raises :class:`DeadlineExpired`) — a queue that fell behind
  sheds dead work instead of computing answers nobody is waiting for.
  The coalescing window also **shrinks** to the earliest queued
  deadline: the worker never idles past a moment that would expire a
  request it could still serve (``stats()["window_shrunk"]`` counts the
  cut windows);
* **bounded-queue load shedding**: when ``max_queue`` requests are
  already waiting, ``submit`` raises :class:`SchedulerOverloadError`
  (or blocks for backpressure with ``block=True`` — what the
  ``serve_discovery`` compat adapter uses).

Each formed batch runs through ``engine.query_batch`` — one pinned MVCC
snapshot version end-to-end, exactly like a direct call — and every
response carries the split ``queue_ms`` / ``compute_ms`` latency.
Scheduler counters (formed-batch size histogram, bucket hits,
expirations, sheds, queue depth) surface through ``scheduler.stats()``
and, once attached, under ``engine.stats()["scheduler"]``.

Typical serving-loop wiring::

    engine = DiscoveryEngine.from_catalog(store, model, EngineConfig())
    with RequestScheduler(engine) as scheduler:
        fut = scheduler.submit(request, deadline_ms=50.0)
        ...                          # any thread, any number of callers
        response = fut.result()
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
import typing
from concurrent.futures import Future, InvalidStateError

from repro.exec.plan import DEFAULT_BATCH_BUCKETS
from repro.service import events as EV


class DeadlineExpired(TimeoutError):
    """The request's deadline passed while it waited in the queue."""


class SchedulerOverloadError(RuntimeError):
    """The bounded request queue is full; the request was shed."""


@dataclasses.dataclass
class SchedulerConfig:
    max_queue: int = 1024         # bounded admission: beyond this, shed
    max_wait_ms: float = 2.0      # batch-formation coalescing window
    # cap on the number of requests per formed batch; None = top bucket
    max_batch: int | None = None
    # padded-batch bucket ladder; None = the engine's configured ladder,
    # falling back to exec.plan.DEFAULT_BATCH_BUCKETS
    batch_buckets: tuple | None = None
    # hold batch dispatch while the engine's AOT warmup is running
    # (engine.warm_event cleared): admission keeps accepting, deadlines
    # keep counting, but no batch pays a first-contact compile mid-warmup.
    # False dispatches through a running warmup (legacy behaviour)
    wait_for_warm: bool = True
    # injectable time source (monotonic seconds): tests swap in a fake
    # clock (tests/_fixtures.FakeClock) to drive deadline expiry without
    # real sleeps.  The coalescing wait derives its timeout from this
    # clock, so a frozen fake clock must be paired with max_wait_ms=0
    clock: typing.Callable[[], float] = time.perf_counter


@dataclasses.dataclass(eq=False)
class _Item:
    request: object
    future: Future
    t_submit: float
    deadline: float | None        # absolute perf_counter second, or None
    # per-SUBMISSION identity: load drivers reuse request objects, so the
    # trace id lives on the queue item, not the request
    trace_id: str = ""
    profile_ms: float = 0.0       # submit-time upload profiling wall


def finalize_batch(items, responses, t_start: float, *, metrics=None) -> None:
    """Stamp scheduler-side latency fields on each response and resolve
    its future.  Shared by the inline worker path and the fleet replica
    delivery path (:mod:`repro.service.fleet`): ``t_start`` is the moment
    scoring began, so ``queue_ms`` covers coalescing *plus* any replica
    queue wait.  A future that already resolved (a re-dispatched batch
    whose abandoned first owner un-hung later) is left alone — the
    second resolution is swallowed, never raised into a worker thread."""
    for it, r in zip(items, responses):
        r.queue_ms = (t_start - it.t_submit) * 1e3
        r.latency_ms = r.queue_ms + r.compute_ms
        # prepend the scheduler-side spans: profile (measured at submit)
        # and queue (the remainder of queue_ms), so the full trace still
        # sums EXACTLY to latency_ms
        r.trace = ([{"phase": "profile", "ms": it.profile_ms},
                    {"phase": "queue", "ms": r.queue_ms - it.profile_ms}]
                   + r.trace)
        if metrics is not None:
            metrics.observe_response(r)
        try:
            it.future.set_result(r)
        except InvalidStateError:
            pass


def fail_batch(items, exc: BaseException) -> None:
    """Resolve every future in ``items`` with ``exc`` (cancelled or
    already-resolved futures are skipped).  Used by the fleet when a
    batch exhausts its re-dispatch budget — the caller gets a clean
    error, never a silently dropped request."""
    for it in items:
        try:
            it.future.set_exception(exc)
        except InvalidStateError:
            pass


class RequestScheduler:
    """Future-based async front door over a :class:`DiscoveryEngine`.

    One worker thread drives the engine; any number of threads submit.
    The engine's ``query_batch`` stays callable directly (it is
    reentrant) — the scheduler only owns arrival coalescing, batch
    formation, deadlines, and admission control.
    """

    def __init__(self, engine, config: SchedulerConfig | None = None):
        self.engine = engine
        self.config = config or SchedulerConfig()
        self._clock = self.config.clock
        ladder = (self.config.batch_buckets
                  or engine.config.batch_buckets
                  or DEFAULT_BATCH_BUCKETS)
        self.buckets = tuple(sorted(int(b) for b in ladder))
        self._bucket_set = frozenset(self.buckets)
        if self.buckets[0] < 1:
            raise ValueError(f"batch buckets must be >= 1; got {ladder!r}")
        # install the ladder on the engine so ITS padding (and therefore
        # the planner's per-bucket grid choice + compile cache) snaps to
        # the same sizes the scheduler forms.  Deliberately persistent:
        # direct query_batch callers keep snapping to the same shapes
        # after this scheduler closes (padding up is result-transparent —
        # padded rows are sliced off — and shape reuse is the point).
        # A fleet front end (`service.fleet.EngineFleet`) exposes
        # install_buckets to propagate the ladder to every replica
        install = getattr(engine, "install_buckets", None)
        if install is not None:
            install(self.buckets)
        else:
            engine.config.batch_buckets = self.buckets
            engine.planner.config.batch_buckets = self.buckets
        # formed-batch sink: an engine-compatible fleet exposes
        # dispatch_batch — the worker hands the staged batch to the
        # router instead of running it inline, and replica workers
        # resolve the futures (reporting back via note_completed)
        self._dispatch = getattr(engine, "dispatch_batch", None)
        self.max_batch = (int(self.config.max_batch)
                          if self.config.max_batch is not None
                          else self.buckets[-1])
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1; "
                             f"got {self.config.max_batch!r}")

        self._heap: list[tuple[int, int, _Item]] = []
        self._seq = itertools.count()
        self._cv = threading.Condition()
        self._closed = False
        self._stop = False
        self._counters = {"submitted": 0, "completed": 0, "failed": 0,
                          "shed": 0, "expired": 0, "batches": 0,
                          "bucket_hits": 0, "bucket_misses": 0,
                          "window_shrunk": 0, "max_queue_depth": 0,
                          "warm_held": 0}
        self._batch_hist: dict[int, int] = {}
        # observability plane: adopt the engine's bus/metrics when it has
        # one (EngineConfig.metrics=True); every publish site guards on
        # None so the disabled path stays event-free
        self.events = getattr(engine, "events", None)
        self.metrics = getattr(engine, "metrics", None)
        if self.metrics is not None:
            self.metrics.bind_scheduler(self)
        engine.attach_scheduler(self)
        self._worker = threading.Thread(target=self._loop, daemon=True,
                                        name="freyja-scheduler")
        self._worker.start()

    # -- submission ----------------------------------------------------------

    def submit(self, request, *, deadline_ms: float | None = None,
               priority: int = 0, block: bool = False) -> Future:
        """Enqueue ``request``; returns a future for its response.

        ``deadline_ms`` — relative deadline; once passed, the request is
        expired at batch-formation time and the future raises
        :class:`DeadlineExpired`.  ``priority`` — higher runs first
        (FIFO within a priority).  ``block=True`` turns a full queue
        into backpressure (wait for space) instead of an immediate
        :class:`SchedulerOverloadError`.
        """
        with self._cv:
            # cheap pre-check so a shed (or closed-scheduler) request
            # never pays the profiling below; the authoritative check
            # re-runs under the lock at enqueue time
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if len(self._heap) >= self.config.max_queue and not block:
                self._counters["shed"] += 1
                self._publish(EV.REQUEST_SHED, name=request.name,
                              queued=len(self._heap))
                raise SchedulerOverloadError(
                    f"request queue full ({self.config.max_queue} "
                    f"waiting); request {request.name!r} shed")
        # per-submission trace id: minted HERE (or seeded by the caller
        # via request.trace_id) and threaded through every event and span
        # this submission generates
        trace_id = getattr(request, "trace_id", None) or EV.mint_trace_id()
        # the clock starts BEFORE profiling: upload profiling is part of
        # the request's end-to-end latency and of its deadline budget
        now = self._clock()
        profile_ms = 0.0
        if getattr(request, "values", None) is not None:
            # profile the uploaded column HERE, in the submitter's
            # thread: the worker's formed-batch path never pays the
            # per-request device profiling
            self.engine.profile_request(request)
            profile_ms = (self._clock() - now) * 1e3
        item = _Item(request=request, future=Future(), t_submit=now,
                     deadline=(now + deadline_ms / 1e3
                               if deadline_ms is not None else None),
                     trace_id=trace_id, profile_ms=profile_ms)
        with self._cv:
            while True:
                if self._closed:
                    raise RuntimeError("scheduler is closed")
                if len(self._heap) < self.config.max_queue:
                    break
                if not block:
                    self._counters["shed"] += 1
                    self._publish(EV.REQUEST_SHED, name=request.name,
                                  trace_id=trace_id,
                                  queued=len(self._heap))
                    raise SchedulerOverloadError(
                        f"request queue full ({self.config.max_queue} "
                        f"waiting); request {request.name!r} shed")
                self._cv.wait()
            heapq.heappush(self._heap,
                           (-int(priority), next(self._seq), item))
            self._counters["submitted"] += 1
            self._counters["max_queue_depth"] = max(
                self._counters["max_queue_depth"], len(self._heap))
            self._cv.notify_all()
        self._publish(EV.REQUEST_ADMITTED, trace_id=trace_id,
                      name=request.name, priority=int(priority),
                      deadline_ms=deadline_ms, profile_ms=profile_ms)
        return item.future

    def _publish(self, type: str, **payload) -> None:
        if self.events is not None:
            self.events.publish(type, **payload)

    # -- worker -------------------------------------------------------------

    def _loop(self) -> None:
        while True:
            items = self._next_batch()
            if items is None:
                return
            if items:
                self._wait_for_warm()
                self._run_batch(items)

    def _wait_for_warm(self) -> None:
        """Hold batch dispatch while the engine's AOT warmup runs (its
        ``warm_event`` is cleared only for a warmup's duration — it starts
        set, so a never-warmed engine is never held).  Polled so a
        ``close()`` during warmup still shuts the worker down promptly."""
        if not self.config.wait_for_warm:
            return
        ev = getattr(self.engine, "warm_event", None)
        if ev is None or ev.is_set():
            return
        with self._cv:
            self._counters["warm_held"] += 1
        while not ev.wait(timeout=0.05):
            with self._cv:
                if self._stop:
                    return

    def _next_batch(self) -> list[_Item] | None:
        """Block for arrivals, coalesce within the wait window, then pop
        up to ``max_batch`` items in priority order.  None = shut down."""
        with self._cv:
            while not self._heap and not self._stop:
                self._cv.wait()
            if not self._heap:
                return None                      # stopped and drained
            if self.config.max_wait_ms > 0 and not self._stop:
                t_end = self._clock() + self.config.max_wait_ms / 1e3
                while len(self._heap) < self.max_batch and not self._stop:
                    # deadline-aware shrink: waiting past the earliest
                    # queued deadline converts a live request into an
                    # expiration, so the window is cut to that deadline —
                    # the batch forms smaller but every admitted request
                    # that can still make it, makes it
                    bound = t_end
                    for _, _, it in self._heap:
                        if it.deadline is not None and it.deadline < bound:
                            bound = it.deadline
                    left = bound - self._clock()
                    if left <= 0:
                        if bound < t_end:
                            self._counters["window_shrunk"] += 1
                        break
                    self._cv.wait(timeout=left)
            # partition as we pop so expired requests never consume live
            # batch slots: keep drawing from the queue until max_batch
            # UNEXPIRED items are staged (or it drains) — a backlog of
            # dead heads must not shrink the batch the live tail gets
            now = self._clock()
            staged, dead = [], []
            while self._heap and len(staged) < self.max_batch:
                it = heapq.heappop(self._heap)[2]
                if it.deadline is not None and now > it.deadline:
                    dead.append(it)
                else:
                    staged.append(it)
            self._cv.notify_all()                # wake blocked submitters
        # future mutations happen OUTSIDE the lock (done-callbacks may
        # re-enter submit); set_running first — set_exception on a
        # caller-cancelled future would raise and kill the worker
        live, n_expired = [], 0
        for it in dead:
            if it.future.set_running_or_notify_cancel():
                n_expired += 1
                self._publish(EV.REQUEST_EXPIRED, trace_id=it.trace_id,
                              name=it.request.name,
                              waited_ms=(now - it.t_submit) * 1e3)
                it.future.set_exception(DeadlineExpired(
                    f"request {it.request.name!r} expired after "
                    f"{(now - it.t_submit) * 1e3:.1f}ms in queue"))
        for it in staged:
            if it.future.set_running_or_notify_cancel():
                live.append(it)
        if n_expired:
            with self._cv:
                self._counters["expired"] += n_expired
        return live

    def _run_batch(self, items: list[_Item]) -> None:
        t_start = self._clock()
        n = len(items)
        # counters mutate UNDER the lock: stats() snapshots the same
        # dict concurrently, and Python's per-opcode interleaving made
        # the old unlocked increments observable as torn reads
        # (sum(batch_size_hist) != batches mid-update)
        with self._cv:
            self._counters["batches"] += 1
            self._batch_hist[n] = self._batch_hist.get(n, 0) + 1
            key = "bucket_hits" if n in self._bucket_set else "bucket_misses"
            self._counters[key] += 1
        self._publish(EV.BATCH_FORMED, n=n,
                      trace_ids=[it.trace_id for it in items])
        if self._dispatch is not None:
            # fleet handoff: the router places this formed batch on a
            # replica; that replica's worker resolves the futures (via
            # finalize_batch) and reports back through note_completed
            self._dispatch(items)
            return
        try:
            responses = self.engine.query_batch(
                [it.request for it in items],
                trace_ids=[it.trace_id for it in items])
        except BaseException as e:
            with self._cv:
                self._counters["failed"] += n
            for it in items:
                try:
                    it.future.set_exception(e)
                except InvalidStateError:
                    pass
            return
        finalize_batch(items, responses, t_start, metrics=self.metrics)
        with self._cv:
            self._counters["completed"] += n
        if self.metrics is not None:
            # fold this batch's events into the registry now, so the
            # metrics cursor tails the ring closely (zero-drop guarantee
            # at any load the worker keeps up with) and a scrape between
            # batches sees current counters
            self.metrics.drain()

    # -- fleet reporting ----------------------------------------------------

    def note_completed(self, n: int) -> None:
        """Fleet replica workers report delivered requests here so
        ``stats()['completed']`` stays the single source of truth no
        matter which thread finished the batch."""
        with self._cv:
            self._counters["completed"] += int(n)

    def note_failed(self, n: int) -> None:
        with self._cv:
            self._counters["failed"] += int(n)

    # -- lifecycle / observability ------------------------------------------

    def close(self, drain: bool = True) -> None:
        """Stop accepting submissions and shut the worker down.  With
        ``drain=True`` (default) queued requests are still served; with
        ``drain=False`` they fail fast with a ``RuntimeError``."""
        with self._cv:
            if self._closed and self._stop:
                return
            self._closed = True
            self._stop = True
            if not drain:
                while self._heap:
                    _, _, it = heapq.heappop(self._heap)
                    if it.future.set_running_or_notify_cancel():
                        it.future.set_exception(RuntimeError(
                            "scheduler closed before the request was "
                            "served"))
            self._cv.notify_all()
        self._worker.join()

    def __enter__(self) -> "RequestScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def queue_depth(self) -> int:
        with self._cv:
            return len(self._heap)

    def stats(self) -> dict:
        """Scheduler counters: queue depth (current/max), formed-batch
        size histogram, bucket hit/miss counts, expirations, sheds,
        deadline-shrunk coalescing windows."""
        with self._cv:
            depth = len(self._heap)
            c = dict(self._counters)
            hist = dict(sorted(self._batch_hist.items()))
            closed = self._closed
        return {
            "queue_depth": depth,
            "max_queue": self.config.max_queue,
            "max_batch": self.max_batch,
            "buckets": list(self.buckets),
            "batch_size_hist": hist,
            "closed": closed,
            **c,
        }
