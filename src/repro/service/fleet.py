"""Engine-replica fleet: N discovery engines behind a load-aware router.

One :class:`~repro.service.scheduler.RequestScheduler` worker thread was
the whole serving plane — goodput stalled at a single engine no matter
how many devices existed.  This module multiplies the plane:

* :class:`EngineReplica` — one :class:`~repro.service.engine.DiscoveryEngine`
  (typically a :class:`~repro.service.catalog.CatalogReader` follower
  pinned to its own device slice via
  :func:`repro.launch.mesh.make_replica_meshes`) driven by its own worker
  thread, moving through the lifecycle

  ::

      WARMING ──► SERVING ──► DRAINING ──► EVICTED
         │            │            │
         └────────────┴────────────┴──► EVICTED   (failure / kill / hang)

  A replica warms via ``engine.warmup()`` (PR 8's AOT ladder) before it
  takes traffic; draining finishes its queue then retires; eviction is
  terminal and closes the engine so every pinned snapshot refcount
  returns to zero once in-flight work unpins.

* :class:`FleetRouter` — a **pure, deterministic** placement policy over
  :class:`ReplicaSnapshot` tuples: only SERVING replicas are eligible,
  replicas more than ``max_depth_spread`` requests above the least-loaded
  one are excluded (bounded spread ⇒ no ready replica starves), and among
  the rest the one with the lowest estimated completion time
  ``(queue_depth + n_items) × cost_per_item`` wins, ties broken by depth
  then replica id.  ``cost_per_item`` comes from the engine's last
  executed plan through the calibrated cost model
  (:func:`repro.launch.costmodel.plan_cost_per_query`).  Purity is the
  point: the property suite (`tests/test_fleet.py`) drives ``choose``
  with arbitrary synthetic states.

* :class:`EngineFleet` — owns the replicas, the router, a health-check
  loop (dead-worker and hung-heartbeat eviction), and **batch
  re-dispatch**: a batch stranded on a failed replica is atomically
  transferred and re-placed on a survivor, up to ``max_redispatch``
  attempts, after which its futures fail with a clean
  :class:`~repro.service.scheduler.SchedulerOverloadError` — an accepted
  future always resolves, a batch is never silently dropped.  The fleet
  presents the scheduler-facing engine surface (``dispatch_batch``,
  ``install_buckets``, ``warm_event``, ``profile_request``, ``stats``),
  so ``RequestScheduler(fleet)`` is a drop-in upgrade, and publishes
  ``replica_state`` / ``batch_routed`` / ``batch_redispatched`` events
  on the shared PR 6 bus (folded into ``redispatches_total`` /
  ``router_queue_depth`` by :class:`~repro.service.metrics.ServiceMetrics`).

:class:`FaultInjector` is the test hook the hardening layer is built on:
it kills (raises) or hangs (blocks) a replica worker at named points —
``mid_batch``, ``mid_warmup``, ``mid_drain`` — without touching
production code paths.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
import typing
from concurrent.futures import Future

from repro.launch.costmodel import plan_cost_per_query
from repro.service import events as EV
from repro.service.scheduler import (SchedulerOverloadError, _Item,
                                     fail_batch, finalize_batch)

# -- replica lifecycle states ------------------------------------------------

WARMING = "warming"
SERVING = "serving"
DRAINING = "draining"
EVICTED = "evicted"
REPLICA_STATES = (WARMING, SERVING, DRAINING, EVICTED)


class ReplicaKilled(RuntimeError):
    """Raised inside a replica worker by an armed kill fault."""


# -- fault injection (test hook) ---------------------------------------------

class FaultInjector:
    """Kill or hang a replica worker at a named execution point.

    Production code never constructs one — the fleet threads an optional
    injector through to each replica, whose worker calls
    ``injector.check(point, replica_id)`` at the named points:

    ``mid_warmup``   before the WARMING replica runs ``engine.warmup()``
    ``mid_batch``    after a batch is claimed, before the engine scores it
    ``mid_drain``    before a DRAINING replica processes a queued batch

    ``kill`` raises :class:`ReplicaKilled` (the worker's failure path
    evicts and re-dispatches); ``hang`` blocks the worker until
    :meth:`release_hangs` — the heartbeat goes stale and the health
    check evicts it.  The points deliberately live in the *fleet* layer,
    outside ``engine.query_batch``: a hung worker holds no snapshot pin,
    so eviction can prove refcounts return to zero.
    """

    POINTS = ("mid_batch", "mid_warmup", "mid_drain")
    MODES = ("kill", "hang")

    def __init__(self):
        self._lock = threading.Lock()
        self._arms: list[dict] = []
        self._release = threading.Event()
        self.fired: list[tuple[str, int, str]] = []

    def arm(self, point: str, *, replica: int | None = None,
            mode: str = "kill", times: int = 1) -> None:
        """Arm ``point`` to fire ``times`` times (on ``replica``, or on
        whichever replica reaches it first when ``None``)."""
        if point not in self.POINTS:
            raise ValueError(f"unknown point {point!r}; want {self.POINTS}")
        if mode not in self.MODES:
            raise ValueError(f"unknown mode {mode!r}; want {self.MODES}")
        with self._lock:
            self._arms.append({"point": point, "replica": replica,
                               "mode": mode, "times": int(times)})

    def check(self, point: str, replica_id: int) -> None:
        with self._lock:
            arm = next((a for a in self._arms
                        if a["point"] == point and a["times"] > 0
                        and a["replica"] in (None, replica_id)), None)
            if arm is None:
                return
            arm["times"] -= 1
            self.fired.append((point, replica_id, arm["mode"]))
            mode = arm["mode"]
        if mode == "kill":
            raise ReplicaKilled(
                f"fault injected at {point} on replica {replica_id}")
        self._release.wait()            # hang until the test releases us

    def release_hangs(self) -> None:
        """Unblock every hung worker (they find their replica evicted and
        exit; any late batch completion loses the delivery claim)."""
        self._release.set()


# -- router ------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaSnapshot:
    """One replica's routing-relevant state at a point in time."""

    replica_id: int
    state: str
    queue_depth: int                    # requests queued + in flight
    cost_per_item: float = 1.0          # modeled seconds per request


class FleetRouter:
    """Pure deterministic batch placement over replica snapshots.

    ``choose`` is a function of its arguments alone — no clock, no
    randomness, no internal state — which is what makes the routing
    invariants property-testable:

    * never places on a non-SERVING replica (returns ``None`` if no
      replica serves);
    * deterministic: identical snapshots ⇒ identical placement;
    * bounded spread: a replica more than ``max_depth_spread`` requests
      above the least-loaded SERVING replica is excluded, so by
      induction ``max_depth - min_depth ≤ max_depth_spread + n_items``
      over any placement sequence — no eligible replica starves while
      another backs up unboundedly.
    """

    def __init__(self, max_depth_spread: int = 64):
        if max_depth_spread < 0:
            raise ValueError(
                f"max_depth_spread must be >= 0; got {max_depth_spread}")
        self.max_depth_spread = int(max_depth_spread)

    def choose(self, snapshots: typing.Sequence[ReplicaSnapshot],
               n_items: int = 1) -> int | None:
        """Replica id for the next ``n_items``-request batch, or ``None``
        when no replica is SERVING.  Picks the minimum estimated
        completion time ``(queue_depth + n_items) * cost_per_item`` among
        spread-eligible SERVING replicas (ties: depth, then id)."""
        eligible = [s for s in snapshots if s.state == SERVING]
        if not eligible:
            return None
        d_min = min(s.queue_depth for s in eligible)
        cap = d_min + self.max_depth_spread
        best = min((s for s in eligible if s.queue_depth <= cap),
                   key=lambda s: ((s.queue_depth + n_items)
                                  * max(s.cost_per_item, 1e-12),
                                  s.queue_depth, s.replica_id))
        return best.replica_id


# -- batches -----------------------------------------------------------------

class _FleetBatch:
    """A formed batch moving through the fleet.

    Ownership and completion are both atomic claims so the unavoidable
    races — an evicting health check re-dispatching while the original
    worker finishes, a hung worker un-hanging after its batch was served
    elsewhere — each resolve to exactly one winner:

    * ``assign``/``release`` track which replica currently holds the
      batch; eviction only re-dispatches batches it can ``release`` from
      the dead replica (a batch already transferred is never re-placed
      twice);
    * ``finish`` claims the right to resolve the futures; the loser of a
      double-execution race drops its responses on the floor.
    """

    __slots__ = ("items", "attempts", "owner", "_done", "_lock")

    def __init__(self, items: list):
        self.items = items
        self.attempts = 0               # re-dispatches so far
        self.owner: int | None = None
        self._done = False
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.items)

    def assign(self, replica_id: int) -> bool:
        with self._lock:
            if self._done:
                return False
            self.owner = replica_id
            return True

    def release(self, replica_id: int) -> bool:
        """Take the batch away from ``replica_id`` (eviction). False if
        it already completed or was already transferred elsewhere."""
        with self._lock:
            if self._done or self.owner != replica_id:
                return False
            self.owner = None
            return True

    def finish(self) -> bool:
        with self._lock:
            if self._done:
                return False
            self._done = True
            return True

    @property
    def done(self) -> bool:
        return self._done


# -- replica -----------------------------------------------------------------

class EngineReplica:
    """One engine + one worker thread + a bounded lifecycle.

    The worker: warm (optionally via ``engine.warmup()``), flip SERVING,
    then pop queued batches and score them through ``engine.query_batch``
    — one pinned MVCC snapshot per batch, exactly like direct serving.
    Every state flip is reported to the fleet, which publishes the
    ``replica_state`` event and recomputes the fleet-level warm gate.
    """

    def __init__(self, replica_id: int, engine, *, fleet: "EngineFleet",
                 clock: typing.Callable[[], float], injector=None):
        self.replica_id = int(replica_id)
        self.engine = engine
        self._fleet = fleet
        self._clock = clock
        self._injector = injector
        self._cv = threading.Condition()
        self._queue: collections.deque[_FleetBatch] = collections.deque()
        self._inflight: _FleetBatch | None = None
        self._depth = 0                 # requests queued + in flight
        self.state = WARMING
        self.heartbeat = clock()
        self.batches_served = 0
        self.requests_served = 0
        self._worker = threading.Thread(
            target=self._run, daemon=True,
            name=f"freyja-replica-{self.replica_id}")

    def start(self) -> None:
        self._worker.start()

    # -- router-facing views -------------------------------------------------

    def cost_per_item(self) -> float:
        plan = getattr(self.engine, "last_plan", None)
        cost = getattr(plan, "cost", None) if plan is not None else None
        v = plan_cost_per_query(cost)
        return v if v is not None else 1.0

    def snapshot_state(self) -> ReplicaSnapshot:
        with self._cv:
            return ReplicaSnapshot(replica_id=self.replica_id,
                                   state=self.state,
                                   queue_depth=self._depth,
                                   cost_per_item=self.cost_per_item())

    # -- fleet-facing control ------------------------------------------------

    def enqueue(self, batch: _FleetBatch) -> bool:
        """Accept ``batch`` if SERVING.  True also for an already-done
        batch (nothing left to place); False tells the caller to pick
        another replica."""
        with self._cv:
            if self.state != SERVING:
                return False
            if not batch.assign(self.replica_id):
                return True             # completed while in transit
            self._queue.append(batch)
            self._depth += len(batch)
            self._cv.notify_all()
            return True

    def begin_drain(self) -> None:
        """Stop taking new placements; finish the queue, then retire."""
        self._set_state(DRAINING, reason="drain")

    def evict(self, reason: str = "") -> list[_FleetBatch]:
        """Terminal transition: mark EVICTED, close the engine (releasing
        its pinned head snapshot), and return the unfinished batches this
        replica still owned — the fleet re-dispatches them."""
        with self._cv:
            if self.state == EVICTED:
                return []
            old, self.state = self.state, EVICTED
            stranded = list(self._queue)
            self._queue.clear()
            if self._inflight is not None:
                stranded.insert(0, self._inflight)
            self._depth = 0
            self._cv.notify_all()
        self._fleet._on_state(self, old, EVICTED, reason)
        try:
            self.engine.close()
        except Exception:
            pass
        # only batches we can atomically take away from this replica get
        # re-dispatched; ones that completed (or were already transferred
        # by a racing eviction path) are left alone
        return [b for b in stranded if b.release(self.replica_id)]

    # -- worker --------------------------------------------------------------

    def _check_fault(self, point: str) -> None:
        if self._injector is not None:
            self._injector.check(point, self.replica_id)

    def _set_state(self, new: str, reason: str = "") -> None:
        with self._cv:
            old = self.state
            if old == new or old == EVICTED:
                return
            if new == SERVING and old != WARMING:
                return                  # a drain during warmup sticks
            self.state = new
            self._cv.notify_all()
        self._fleet._on_state(self, old, new, reason)

    def _run(self) -> None:
        try:
            self._check_fault("mid_warmup")
            if self.engine.config.warmup and self.engine.warmup_report is None:
                self.engine.warmup()
        except BaseException as e:
            self._fleet._on_replica_failure(self, e)
            return
        self._set_state(SERVING)
        while True:
            with self._cv:
                while not self._queue and self.state == SERVING:
                    self.heartbeat = self._clock()
                    self._cv.wait(timeout=0.05)
                if self.state == EVICTED:
                    return
                if not self._queue:     # DRAINING and queue empty
                    break
                batch = self._queue.popleft()
                self._inflight = batch
                draining = self.state == DRAINING
                self.heartbeat = self._clock()
            t_exec = self._clock()
            try:
                if draining:
                    self._check_fault("mid_drain")
                self._check_fault("mid_batch")
                responses = self.engine.query_batch(
                    [it.request for it in batch.items],
                    trace_ids=[it.trace_id for it in batch.items])
            except BaseException as e:
                self._fleet._on_replica_failure(self, e)
                return
            self._fleet._deliver(self, batch, responses, t_exec)
            with self._cv:
                self._inflight = None
                self._depth -= len(batch)
                self.heartbeat = self._clock()
        # drained: the queue is empty and no new placement can land
        self._fleet._on_drained(self)


# -- fleet -------------------------------------------------------------------

@dataclasses.dataclass
class FleetConfig:
    # router fairness bound: a replica this many requests above the
    # least-loaded one is skipped until the gap closes
    max_depth_spread: int = 64
    # health-check cadence; 0 disables the background thread (tests call
    # check_health() by hand with a fake clock)
    health_interval_s: float = 0.25
    # a busy replica whose heartbeat is older than this is declared hung
    # and evicted (must exceed the worst first-contact compile)
    hang_timeout_s: float = 30.0
    # WARMING gets its own (much larger) stall budget: an AOT warmup
    # legitimately holds the worker for the whole ladder compile
    warmup_timeout_s: float = 300.0
    # re-dispatch budget per batch; None = one attempt per replica
    max_redispatch: int | None = None
    # rolling-refresh cadence: the fleet polls the catalog and refreshes
    # replicas onto new manifest versions ONE AT A TIME (each keeps
    # serving its pinned MVCC head while its new state builds, so a
    # manifest advance never pauses the fleet).  0 disables the
    # background thread (tests call roll_refresh() by hand)
    refresh_interval_s: float = 0.0
    # scheduler-compat: ladder the scheduler reads/installs (None adopts
    # the first engine's configured ladder)
    batch_buckets: tuple | None = None
    # injectable time source shared by heartbeats and queue_ms stamping —
    # MUST tick the same epoch as the scheduler's clock
    clock: typing.Callable[[], float] = time.perf_counter


class EngineFleet:
    """N engine replicas + router + health plane, behind the engine
    surface :class:`~repro.service.scheduler.RequestScheduler` expects.

    ``RequestScheduler(fleet)`` hands every formed batch to
    :meth:`dispatch_batch`; replica workers resolve the futures.  The
    fleet is also directly callable (:meth:`query_batch`) for
    scheduler-less use.
    """

    def __init__(self, engines: list, config: FleetConfig | None = None,
                 *, events=None, metrics=None, injector=None):
        if not engines:
            raise ValueError("a fleet needs at least one engine")
        self.config = config or FleetConfig()
        if self.config.batch_buckets is None:
            self.config.batch_buckets = engines[0].config.batch_buckets
        if self.config.max_redispatch is None:
            self.config.max_redispatch = len(engines)
        self._clock = self.config.clock
        # one observability plane for the whole fleet: adopt the given
        # bus, else whatever the first engine carries (from_catalog wires
        # all replicas onto one shared bus)
        self.events = events if events is not None else \
            getattr(engines[0], "events", None)
        self.metrics = metrics if metrics is not None else \
            getattr(engines[0], "metrics", None)
        self.router = FleetRouter(self.config.max_depth_spread)
        self.warm_event = threading.Event()
        self._lock = threading.Lock()
        self._pending: collections.deque[_FleetBatch] = collections.deque()
        self._counters = {"dispatched": 0, "completed": 0, "failed": 0,
                          "redispatches": 0, "evictions": 0,
                          "state_changes": 0, "rolling_refreshes": 0}
        self._scheduler = None
        self._closed = False
        self.replicas = [
            EngineReplica(i, eng, fleet=self, clock=self._clock,
                          injector=injector)
            for i, eng in enumerate(engines)]
        for r in self.replicas:
            r.start()
        self._stop = threading.Event()
        self._health = None
        if self.config.health_interval_s > 0:
            self._health = threading.Thread(target=self._health_loop,
                                            daemon=True,
                                            name="freyja-fleet-health")
            self._health.start()
        self._refresher = None
        if self.config.refresh_interval_s > 0:
            self._refresher = threading.Thread(target=self._refresh_loop,
                                               daemon=True,
                                               name="freyja-fleet-refresh")
            self._refresher.start()

    @classmethod
    def from_catalog(cls, catalog, model, engine_config=None, *,
                     n_replicas: int = 2, config: FleetConfig | None = None,
                     devices=None, lazy: bool = False, injector=None
                     ) -> "EngineFleet":
        """Build ``n_replicas`` follower engines over one catalog root.

        ``catalog`` is a :class:`~repro.service.catalog.CatalogStore` (or
        anything with ``.root``) or a root path.  Each replica gets its
        own :class:`~repro.service.catalog.CatalogReader` follower and
        its own device slice from
        :func:`repro.launch.mesh.make_replica_meshes`; all replicas share
        one event bus + metrics registry when the config enables them.
        Engine warmup is deferred into each replica's WARMING state so
        the fleet comes up concurrently, not serially.
        """
        from repro.launch.mesh import make_replica_meshes
        from repro.service.catalog import CatalogReader
        from repro.service.engine import DiscoveryEngine, EngineConfig

        root = getattr(catalog, "root", catalog)
        engine_config = engine_config or EngineConfig()
        meshes = make_replica_meshes(n_replicas, devices=devices)
        bus = metrics = None
        if engine_config.metrics:
            from repro.service.metrics import ServiceMetrics
            bus = EV.EventBus(capacity=engine_config.event_capacity)
            metrics = ServiceMetrics(bus)
        engines = []
        for i in range(n_replicas):
            reader = CatalogReader(root, lazy=lazy, events=bus)
            cfg = dataclasses.replace(engine_config, warmup=False)
            eng = DiscoveryEngine(reader.snapshot(lazy=lazy), model,
                                  cfg, mesh=meshes[i], events=bus)
            # restore the warmup policy AFTER construction: the replica
            # worker runs it inside the WARMING state instead of the
            # constructor running it serially here
            cfg.warmup = engine_config.warmup
            # auto=False: replicas do NOT poll per query batch — the
            # fleet's rolling refresher advances them one at a time, so
            # a manifest advance can never trigger N simultaneous
            # rebuilds across the fleet (the refresh storm)
            eng.follow(reader, auto=False)
            engines.append(eng)
        return cls(engines, config=config, events=bus, metrics=metrics,
                   injector=injector)

    # -- scheduler-compat engine surface ------------------------------------

    def install_buckets(self, buckets: tuple) -> None:
        """Propagate the scheduler's bucket ladder to every replica (the
        single-engine path assigns ``engine.config.batch_buckets``; the
        fleet must fan it out so all planners pad identically)."""
        self.config.batch_buckets = tuple(buckets)
        for r in self.replicas:
            r.engine.config.batch_buckets = tuple(buckets)
            r.engine.planner.config.batch_buckets = tuple(buckets)

    def attach_scheduler(self, scheduler) -> None:
        self._scheduler = scheduler

    def profile_request(self, request) -> None:
        """Profile an uploaded column against the catalog geometry (all
        replicas follow the same catalog, so any live engine's head
        works)."""
        for r in self.replicas:
            if r.state != EVICTED:
                r.engine.profile_request(request)
                return
        raise RuntimeError("no live replica to profile against")

    # -- dispatch ------------------------------------------------------------

    def dispatch_batch(self, items: list) -> None:
        """Scheduler handoff: route one formed batch onto a replica.
        Non-blocking — the replica worker resolves the futures."""
        batch = _FleetBatch(items)
        with self._lock:
            self._counters["dispatched"] += 1
        self._place(batch)

    def query_batch(self, requests: list, *, trace_ids=None,
                    timeout: float | None = None) -> list:
        """Blocking convenience: dispatch and wait.  Lets the fleet stand
        in for an engine with no scheduler in front."""
        now = self._clock()
        if trace_ids is None:
            trace_ids = [getattr(r, "trace_id", "") or EV.mint_trace_id()
                         for r in requests]
        items = [_Item(request=r, future=Future(), t_submit=now,
                       deadline=None, trace_id=t)
                 for r, t in zip(requests, trace_ids)]
        self.dispatch_batch(items)
        return [it.future.result(timeout=timeout) for it in items]

    def _place(self, batch: _FleetBatch) -> None:
        while True:
            snaps = [r.snapshot_state() for r in self.replicas]
            rid = self.router.choose(snaps, n_items=len(batch))
            if rid is None:
                if any(s.state == WARMING for s in snaps):
                    # hold until a replica finishes warming; _on_state
                    # flushes this queue on the WARMING→SERVING flip
                    with self._lock:
                        self._pending.append(batch)
                    if not any(r.state == SERVING for r in self.replicas):
                        return
                    # a replica flipped SERVING between snapshot and
                    # append — reclaim the batch and place it ourselves
                    with self._lock:
                        try:
                            self._pending.remove(batch)
                        except ValueError:
                            return      # a flush beat us to it
                    continue
                self._fail_batch(batch, SchedulerOverloadError(
                    f"no serving replica available for a "
                    f"{len(batch)}-request batch "
                    f"(states: {[s.state for s in snaps]})"))
                return
            if self.replicas[rid].enqueue(batch):
                self._publish(EV.BATCH_ROUTED, replica=rid, n=len(batch),
                              queue_depth=snaps[rid].queue_depth
                              + len(batch))
                return
            # the chosen replica left SERVING between snapshot and
            # enqueue — re-snapshot and pick again

    def _flush_pending(self) -> None:
        while True:
            if not any(r.state == SERVING for r in self.replicas):
                if any(r.state == WARMING for r in self.replicas):
                    return              # a later flip will flush
                with self._lock:
                    stranded = list(self._pending)
                    self._pending.clear()
                for b in stranded:
                    self._fail_batch(b, SchedulerOverloadError(
                        "every fleet replica was evicted before this "
                        "batch could be placed"))
                return
            with self._lock:
                if not self._pending:
                    return
                batch = self._pending.popleft()
            self._place(batch)

    def _redispatch(self, batches: list[_FleetBatch],
                    from_replica: int) -> None:
        for b in batches:
            b.attempts += 1
            if b.attempts > self.config.max_redispatch:
                self._fail_batch(b, SchedulerOverloadError(
                    f"batch of {len(b)} exhausted its re-dispatch budget "
                    f"({self.config.max_redispatch}) after repeated "
                    f"replica failures"))
                continue
            with self._lock:
                self._counters["redispatches"] += 1
            self._publish(EV.BATCH_REDISPATCHED, replica=from_replica,
                          n=len(b), attempts=b.attempts)
            self._place(b)

    def _fail_batch(self, batch: _FleetBatch, exc: Exception) -> None:
        if not batch.finish():
            return
        fail_batch(batch.items, exc)
        with self._lock:
            self._counters["failed"] += len(batch)
        if self._scheduler is not None:
            self._scheduler.note_failed(len(batch))

    # -- replica callbacks ---------------------------------------------------

    def _deliver(self, replica: EngineReplica, batch: _FleetBatch,
                 responses: list, t_exec: float) -> None:
        if not batch.finish():
            return                      # served elsewhere during a race
        finalize_batch(batch.items, responses, t_exec,
                       metrics=self.metrics)
        replica.batches_served += 1
        replica.requests_served += len(batch)
        with self._lock:
            self._counters["completed"] += len(batch)
        if self._scheduler is not None:
            self._scheduler.note_completed(len(batch))
        if self.metrics is not None:
            self.metrics.drain()

    def _on_state(self, replica: EngineReplica, old: str, new: str,
                  reason: str) -> None:
        with self._lock:
            self._counters["state_changes"] += 1
            if new == EVICTED:
                self._counters["evictions"] += 1
        self._publish(EV.REPLICA_STATE, replica=replica.replica_id,
                      state=new, prev=old, reason=reason)
        self._update_warm()
        if new == SERVING or new == EVICTED:
            self._flush_pending()

    def _on_replica_failure(self, replica: EngineReplica,
                            exc: BaseException) -> None:
        stranded = replica.evict(reason=f"{type(exc).__name__}: {exc}")
        if stranded:
            self._redispatch(stranded, replica.replica_id)

    def _on_drained(self, replica: EngineReplica) -> None:
        stranded = replica.evict(reason="drained")
        if stranded:                    # a placement raced the drain
            self._redispatch(stranded, replica.replica_id)

    def _update_warm(self) -> None:
        states = [r.state for r in self.replicas]
        # set while anyone serves — and ALSO once everyone is evicted,
        # so a scheduler holding on warm_event dispatches into _place
        # and gets clean failures instead of hanging forever
        if SERVING in states or all(s == EVICTED for s in states):
            self.warm_event.set()
        else:
            self.warm_event.clear()

    def _publish(self, type: str, **payload) -> None:
        if self.events is not None:
            self.events.publish(type, **payload)

    # -- rolling refresh -----------------------------------------------------

    def _refresh_loop(self) -> None:
        while not self._stop.wait(self.config.refresh_interval_s):
            try:
                self.roll_refresh()
            except Exception:
                pass                    # a torn replica refresh must not
                                        # kill the cadence thread

    def roll_refresh(self) -> int:
        """One rolling-refresh sweep: poll each live replica's follower
        and refresh it onto the newest catalog version, strictly one
        replica at a time.  MVCC keeps the refreshing replica serving
        its pinned head until the new state swaps in, and the other
        replicas are untouched until their turn — so serving never
        pauses and queries are never dropped by an ingest.  Returns how
        many replicas actually moved to a new version."""
        n = 0
        for r in self.replicas:
            if self._closed or r.state == EVICTED:
                continue
            eng = r.engine
            head = getattr(eng, "_head", None)
            v0 = head.version if head is not None else None
            try:
                eng._maybe_follow(force=True)
            except Exception:
                continue                # this replica retries next sweep
            head = getattr(eng, "_head", None)
            if head is not None and head.version != v0:
                n += 1
        if n:
            with self._lock:
                self._counters["rolling_refreshes"] += n
        return n

    # -- health --------------------------------------------------------------

    def _health_loop(self) -> None:
        while not self._stop.wait(self.config.health_interval_s):
            self.check_health()

    def check_health(self, now: float | None = None) -> list[int]:
        """One health sweep: evict replicas whose worker died without
        transitioning, or whose heartbeat is older than the hang
        timeout.  Returns the replica ids evicted this sweep (tests call
        this directly with a pinned ``now``)."""
        now = self._clock() if now is None else now
        evicted = []
        for r in self.replicas:
            if r.state == EVICTED:
                continue
            dead = r._worker.ident is not None and not r._worker.is_alive()
            limit = (self.config.warmup_timeout_s if r.state == WARMING
                     else self.config.hang_timeout_s)
            hung = (now - r.heartbeat) > limit
            if dead or hung:
                why = "worker died" if dead else (
                    f"heartbeat stale for {now - r.heartbeat:.1f}s")
                self._on_replica_failure(r, RuntimeError(why))
                evicted.append(r.replica_id)
        return evicted

    # -- lifecycle / observability ------------------------------------------

    def drain_replica(self, replica_id: int) -> None:
        """Gracefully retire one replica (finish queue, then evict)."""
        self.replicas[replica_id].begin_drain()

    def close(self, drain: bool = True) -> None:
        """Shut the fleet down.  ``drain=True`` lets every replica finish
        its queue first; ``drain=False`` evicts immediately and fails
        whatever was queued with :class:`SchedulerOverloadError`."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._stop.set()
        if self._health is not None:
            self._health.join()
        if self._refresher is not None:
            self._refresher.join()
        if drain:
            for r in self.replicas:
                r.begin_drain()
            for r in self.replicas:
                r._worker.join(timeout=60.0)
        for r in self.replicas:
            for b in r.evict(reason="close"):
                self._fail_batch(b, SchedulerOverloadError(
                    "fleet closed before this batch ran"))
        with self._lock:
            stranded = list(self._pending)
            self._pending.clear()
        for b in stranded:
            self._fail_batch(b, SchedulerOverloadError(
                "fleet closed before this batch was placed"))
        self._update_warm()

    def __enter__(self) -> "EngineFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def queue_depth(self) -> int:
        return sum(r.snapshot_state().queue_depth for r in self.replicas)

    def stats(self) -> dict:
        with self._lock:
            c = dict(self._counters)
            pending = len(self._pending)
        reps = {}
        for r in self.replicas:
            s = r.snapshot_state()
            reps[r.replica_id] = {
                "state": s.state, "queue_depth": s.queue_depth,
                "cost_per_item": s.cost_per_item,
                "batches_served": r.batches_served,
                "requests_served": r.requests_served,
                "engine_version": getattr(r.engine, "_head", None).version
                if getattr(r.engine, "_head", None) is not None else None,
            }
        out = {
            "n_replicas": len(self.replicas),
            "max_depth_spread": self.router.max_depth_spread,
            "max_redispatch": self.config.max_redispatch,
            "pending": pending,
            "warm": self.warm_event.is_set(),
            "replicas": reps,
            **c,
        }
        if self._scheduler is not None:
            out["scheduler"] = self._scheduler.stats()
        return out
