"""DiscoveryEngine: batched query serving over pinned catalog snapshots.

The engine is a thin serving shell around the unified query-execution
layer (``repro.exec``): per micro-batch of concurrent queries it asks the
:class:`~repro.exec.Planner` for a plan (candidate stage × placement ×
budget, chosen from lake size, mesh availability and the cost model) and
hands the padded batch to the :class:`~repro.exec.Executor`.  All scoring
math — full-scan, LSH/hybrid pruning, mesh-sharded variants of both —
lives in ``repro.exec``; this module owns only serving concerns:

* **MVCC snapshot pinning**: every query batch pins one immutable
  per-version state (snapshot, LSH index, executor with its sharded
  corpus placement) for its whole candidate→score→merge pipeline, so a
  concurrent ``refresh`` — a follower picking up a new catalog version,
  or a background compaction swap — can never tear a batch.  Retired
  versions are released by refcount: the last in-flight batch to unpin
  one closes its executor and frees the device placements;
* request resolution (resident column ids vs uploaded raw columns —
  uploads are profiled once per signature geometry and stashed on the
  request, so a scheduler can pay that device work at submit time,
  off the formed-batch path),
* micro-batch padding so repeated batch shapes reuse compiles: the next
  ``batch_pad`` multiple, or — when a bucket ladder is configured
  (``EngineConfig.batch_buckets``, installed by the continuous-batching
  :class:`~repro.service.scheduler.RequestScheduler`) — the smallest
  ladder bucket that fits, so only a handful of shapes are ever
  compiled/planned,
* a **cost-aware LRU cache** namespaced by snapshot version: keys embed
  the pinned version, so a result computed against version v can never
  answer a query served at v+1 (stale hits are structurally impossible,
  even for inserts racing a refresh).  Entries are weighted by the
  executed plan's modeled cost, so a full-scan result outranks a pruned
  one and cheap entries are evicted (or refused admission) first;
* **follower mode** (:meth:`follow`): attach a
  :class:`~repro.service.catalog.CatalogReader` and each query batch
  first tails the manifest chain, refreshing onto the newest version;
* per-plan serving statistics via :meth:`DiscoveryEngine.stats`.

Modes (``EngineConfig.mode``): ``lsh`` (pruned; sharded over the mesh
whenever one is supplied — lakes bigger than one device), ``full``
(single-device brute scan), ``sharded`` (brute scan over the mesh),
``auto`` (planner picks by cost — the analytic model, or a measured one
injected via ``EngineConfig.cost_fn``, e.g. from
``launch.costmodel.calibrate_stage_costs``).

Sharded plans place work on a 2-D (query × data) device grid: the
planner factorizes the mesh into ``grid=(q_shards, d_shards)`` per
micro-batch (large concurrent batches shard the query axis alongside the
lake), or the operator pins a geometry with ``EngineConfig.grid`` /
``--grid``. The executed grid is surfaced in ``stats()["last_plan"]``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict
from typing import Callable

import numpy as np

from repro.core import features as FT
from repro.core.ingest import ingest_string_columns
from repro.core.predictor import JoinQualityModel
from repro.exec import (DEFAULT_BATCH_BUCKETS, MODES, Executor,
                        ExecutableCache, Planner, PlannerConfig, pad_rows)
from repro.kernels.profile_distance import quantize_profiles_streamed
from repro.service import events as EV
from repro.service.api import ColumnMatch, DiscoveryRequest, DiscoveryResponse
from repro.service.catalog import (CatalogSnapshot, CatalogStore,
                                   fold_moments, manifest_delta,
                                   moments_from_stats, profile_and_sign)
from repro.service.lsh import LSHConfig, LSHIndex


@dataclasses.dataclass
class EngineConfig:
    k: int = 10
    mode: str = "lsh"          # "lsh" | "full" | "sharded" | "auto" | "tiered"
    lsh: LSHConfig = dataclasses.field(default_factory=LSHConfig)
    candidate_frac: float = 0.2        # LSH budget as a fraction of the lake
    max_candidates: int = 4096         # absolute cap on that budget
    # resident profile-matrix dtype: "fp32" | "fp16" | "int8" — quantized
    # sidecars shrink the corpus stream (dequant happens after the gather /
    # in-kernel); parity vs fp32 top-k is test-gated
    profile_dtype: str = "fp32"
    batch_pad: int = 8                 # pad micro-batches to this multiple
    # padded-batch bucket ladder: when set, micro-batches snap UP to the
    # smallest bucket that fits instead of the next batch_pad multiple, so
    # only the ladder's shapes are ever compiled/planned.  None = legacy
    # batch_pad padding; the continuous-batching scheduler installs its
    # ladder here at construction (see service.scheduler)
    batch_buckets: tuple | None = None
    cache_entries: int = 1024
    exclude_same_table: bool = True
    shard_axes: tuple = ("data",)
    cost_fn: Callable | None = None    # measured cost model (planner hook)
    # (q_shards, d_shards) device grid for sharded plans; None lets the
    # planner pick the factorization per micro-batch from the batch size,
    # lake size, and cost model (large batches shard the query axis too)
    grid: tuple | None = None
    # observability: True stands up an EventBus (engine.events) + the
    # standard ServiceMetrics registry (engine.metrics) — every serving
    # component publishes into it and `discover --metrics-port` / a
    # MetricsServer can export it.  False (default) keeps the hot path
    # event-free; per-request phase traces are recorded either way
    metrics: bool = False
    event_capacity: int = 8192
    # AOT warmup: False = first-contact compiles on the serving path
    # (legacy); True / "serve" = pre-compile the bucket-ladder executables
    # the configured mode would serve before traffic; "full" = every
    # admissible (bucket × grid × plan kind) executable.  The scheduler
    # holds batch dispatch until ``engine.warm_event`` sets (see
    # SchedulerConfig.wait_for_warm)
    warmup: bool | str = False
    # persistent executable cache directory (shared across engine
    # processes); None keeps warmup in-process only — a restart re-compiles
    executable_cache_dir: str | None = None
    # delta-proportional refresh: True lets a follower refresh extend the
    # resident state in place when the manifest advance is append-only
    # (same MinHash geometry, same tombstones, old segments a prefix) —
    # O(delta) hashing + upload instead of an O(lake) rebuild.  Requires
    # fp32 resident profiles; any other advance falls back to a rebuild
    incremental: bool = False
    # corpus-axis bucket ladder: pad the placed corpus UP to the smallest
    # bucket that fits (sentinel rows score -inf), so in-bucket ingest
    # deltas re-dispatch the same compiled executables — zero steady-state
    # recompiles.  None = exact-size placement (legacy)
    column_buckets: tuple | None = None
    # when live columns exceed this fraction of the current bucket, a
    # daemon thread AOT-compiles the next bucket's plan set ahead of the
    # crossing, so the cutover swaps onto pre-built executables
    prewarm_fraction: float = 0.75


@dataclasses.dataclass(eq=False)
class _VersionState:
    """Everything a query batch needs from one catalog version, immutable
    after construction and released by refcount."""

    snapshot: CatalogSnapshot
    # zscored numeric profiles (C, F_NUM): a fp32 ndarray, or a lazy
    # ZscoreView (lazy snapshot + quantized sidecar) — both row-indexable
    z: np.ndarray
    w: np.ndarray                      # word features (C, F_WORDS)
    lsh: LSHIndex
    executor: Executor
    refs: int = 1                      # the head reference
    # the version's FROZEN normalization stats: a delta-built state keeps
    # its predecessor's (mean, std) so resident device rows stay valid
    # without a rescale; every query — resident or uploaded — z-scores
    # against these, never the snapshot's recomputed stats
    mean: np.ndarray | None = None
    std: np.ndarray | None = None
    # accumulated float64 profile moments {count, sum, sumsq}: folded
    # O(delta) per incremental refresh, reconstructed exactly from
    # (mean, std, count) on full builds — feeds stats_drift reporting
    moments: dict | None = None

    @property
    def version(self) -> int:
        return int(self.snapshot.version)


class DiscoveryEngine:
    """Serves discovery queries from pinned catalog snapshots."""

    def __init__(self, snapshot: CatalogSnapshot, model: JoinQualityModel,
                 config: EngineConfig | None = None, mesh=None, events=None):
        config = config if config is not None else EngineConfig()
        if config.mode not in MODES:
            raise ValueError(f"unknown mode {config.mode!r}; "
                             f"want one of {MODES}")
        if config.mode == "sharded" and mesh is None:
            raise ValueError("sharded mode needs a mesh")
        self.config = config
        self.model = model
        self.mesh = mesh
        self.planner = Planner(PlannerConfig(
            k=config.k, candidate_frac=config.candidate_frac,
            max_candidates=config.max_candidates,
            n_bands=config.lsh.n_bands,
            n_coarse_bands=config.lsh.n_coarse_bands,
            shard_axes=tuple(config.shard_axes),
            batch_buckets=tuple(config.batch_buckets or ()),
            column_buckets=tuple(config.column_buckets or ())),
            cost_fn=config.cost_fn)
        self._cache: OrderedDict[bytes, tuple[list[ColumnMatch], float]] = \
            OrderedDict()
        self._cache_lock = threading.Lock()
        self._counters = {"queries": 0, "batches": 0, "cache_hits": 0,
                          "cache_misses": 0, "cache_admitted": 0,
                          "cache_rejected": 0, "cache_evicted": 0,
                          "scored_columns": 0, "scan_columns": 0,
                          "refreshes": 0, "refreshes_coalesced": 0}
        self._plan_counts: dict[str, int] = {}
        self.last_plan = None
        self._slock = threading.Lock()
        self._head: _VersionState | None = None
        self._live: set[_VersionState] = set()
        self._reader = None
        self._follow_auto = True
        self._scheduler = None
        self._prewarmed: set[int] = set()
        self._refresh_stats = {"count": 0, "incremental": 0, "full": 0,
                               "last_ms": 0.0, "last_delta_columns": 0,
                               "bytes_uploaded_total": 0,
                               "recompiles_total": 0}
        # observability plane: events/metrics exist only when configured
        # (publish sites guard on None so the disabled hot path pays one
        # attribute read, nothing else).  An externally supplied bus
        # (``events=``) is adopted as-is WITHOUT a private aggregator —
        # the fleet shares one bus + one ServiceMetrics across replicas
        self._closed = False
        self.events = events
        self.metrics = None
        if config.metrics and events is None:
            from repro.service.metrics import ServiceMetrics
            self.events = EV.EventBus(capacity=config.event_capacity)
            self.metrics = ServiceMetrics(self.events)
        # AOT warmup plane: the cache is shared by every version's
        # executor; warm_event starts SET so a never-warmed engine (or a
        # scheduler racing construction) is not held hostage — warmup()
        # clears it only for its own duration
        self._exec_cache = (ExecutableCache(config.executable_cache_dir)
                            if config.executable_cache_dir else None)
        self.warm_event = threading.Event()
        self.warm_event.set()
        self.warmup_report: dict | None = None
        self.refresh(snapshot)
        if config.warmup:
            self.warmup()

    @classmethod
    def from_catalog(cls, catalog: CatalogStore, model: JoinQualityModel,
                     config: EngineConfig | None = None, mesh=None):
        return cls(catalog.snapshot(), model, config=config, mesh=mesh)

    # -- snapshot management (MVCC) -----------------------------------------

    def refresh(self, snapshot: CatalogSnapshot, *,
                _coalesced: int = 0) -> None:
        """Swap in a new catalog snapshot (after add/drop/compact).

        In-flight query batches keep the version they pinned — the old
        state is retired only once its last batch unpins it.  The result
        cache is cleared; entries racing this swap land under the retired
        version's namespace and can never hit again.

        With ``EngineConfig.incremental`` and an attached reader, an
        append-only manifest advance takes the **delta path**: the new
        state extends the predecessor in place (O(delta) hashing, only
        the new rows uploaded, executables inherited — zero recompiles)
        instead of rebuilding from scratch.  ``_coalesced`` counts the
        intermediate manifest versions this refresh collapsed (the
        follower passes it through for observability)."""
        with self._slock:
            if self._closed:     # a follower poll racing eviction: the
                return           # closed engine must not grow new states
            version_from = (self._head.version if self._head is not None
                            else None)
            c_from = (self._head.snapshot.n_columns
                      if self._head is not None else 0)
        t0 = time.perf_counter()
        if self.events is not None:
            self.events.publish(EV.REFRESH_BEGIN, version_from=version_from,
                                version_to=int(snapshot.version))
        st = self._try_delta(snapshot)
        incremental = st is not None
        if st is None:
            st = self._build_state(snapshot)
        with self._slock:
            old, self._head = self._head, st
            self._live.add(st)
            with self._cache_lock:
                self._cache.clear()
            self._counters["refreshes"] += 1
        if old is not None:
            self._release(old)
        recompiles = 0
        if incremental:
            # the delta executor inherited the predecessor's compiled
            # dispatch table — no re-warm, zero steady-state recompiles;
            # near bucket capacity, compile the NEXT bucket in background
            self._maybe_prewarm(st)
        elif self.config.warmup and self.warmup_report is not None:
            # a rebuilt version means a fresh executor with an empty
            # dispatch table — re-warm it so the swap doesn't reintroduce
            # first-contact compiles (guarded on a prior warmup: __init__'s
            # refresh runs before the configured warmup, which then warms
            # the head itself)
            report = self.warmup()
            recompiles = int(report.get("cache_misses", 0))
        ms = (time.perf_counter() - t0) * 1e3
        delta_columns = (st.snapshot.n_columns - c_from if incremental
                         else st.snapshot.n_columns)
        bytes_up = int(st.executor.bytes_uploaded)
        with self._slock:
            rs = self._refresh_stats
            rs["count"] += 1
            rs["incremental" if incremental else "full"] += 1
            rs["last_ms"] = ms
            rs["last_delta_columns"] = delta_columns
            rs["bytes_uploaded_total"] += bytes_up
            rs["recompiles_total"] += recompiles
        if self.events is not None:
            self.events.publish(
                EV.REFRESH_END, version_from=version_from,
                version_to=st.version, incremental=incremental,
                delta_columns=delta_columns, bytes_uploaded=bytes_up,
                recompiles=recompiles, coalesced=_coalesced, ms=ms)

    def _try_delta(self, snapshot: CatalogSnapshot) -> _VersionState | None:
        """Build the new head as a delta over the current one, or None
        when the delta path is inadmissible — no reader, incremental off,
        quantized resident profiles, or a manifest advance that is not
        append-only (drop / compaction / re-sign).  The caller then falls
        back to a full rebuild.

        The predecessor is pinned for the duration so a racing release
        can never close its executor mid-extension."""
        cfg = self.config
        if (not cfg.incremental or self._reader is None
                or cfg.profile_dtype != "fp32"):
            return None
        with self._slock:
            if self._closed or self._head is None:
                return None
            old = self._head
            old.refs += 1
        try:
            try:
                old_m = self._reader.manifest(old.version)
                new_m = self._reader.manifest(snapshot.version)
            except KeyError:       # fell off the reader's bounded tail
                return None
            if manifest_delta(old_m, new_m) is None:
                return None
            c_old = old.snapshot.n_columns
            d = snapshot.n_columns - c_old
            if d < 0 or old.mean is None:
                return None
            prof = snapshot.profiles
            # frozen stats: the delta rows z-score with the PREDECESSOR's
            # (mean, std), so the resident device rows need no rescale
            num_new = np.asarray(prof.numeric[c_old:], np.float64)
            z_rows = ((num_new - old.mean) / old.std).astype(np.float32)
            w_rows = np.asarray(prof.words[c_old:])
            lsh = old.lsh.extend(snapshot.signatures[c_old:])
            n_pad = (self.planner.snap_columns(snapshot.n_columns)
                     if self.planner.config.column_buckets else None)
            executor = old.executor.extended(
                z_rows, w_rows,
                table_ids=np.asarray(snapshot.table_ids[c_old:], np.int32),
                band_keys=lsh.keys[c_old:],
                coarse_keys=(None if lsh.coarse is None
                             else lsh.coarse[c_old:]),
                n_padded=n_pad)
            # host z concat is an accepted O(lake) memcpy (MB-scale);
            # the delta-proportionality claim is about device placement,
            # hashing and recompiles
            z = (np.concatenate([np.asarray(old.z, np.float32), z_rows])
                 if d else old.z)
            moments = fold_moments(old.moments, {
                "count": d, "sum": num_new.sum(axis=0),
                "sumsq": (num_new * num_new).sum(axis=0)})
            return _VersionState(snapshot=snapshot, z=z, w=prof.words,
                                 lsh=lsh, executor=executor,
                                 mean=old.mean, std=old.std,
                                 moments=moments)
        except NotImplementedError:
            return None            # executor can't extend this placement
        finally:
            self._release(old)

    # -- next-bucket prewarm -------------------------------------------------

    def _maybe_prewarm(self, st: _VersionState) -> None:
        """Kick a background AOT compile of the NEXT column bucket once
        occupancy crosses ``prewarm_fraction``, so a future bucket-boundary
        crossing swaps onto pre-built executables."""
        if not (self.planner.config.column_buckets
                and self.planner.config.batch_buckets):
            return
        cur = st.executor.n_columns
        if st.snapshot.n_columns < self.config.prewarm_fraction * cur:
            return
        nxt = self.planner.next_column_bucket(cur)
        if nxt is None or nxt in self._prewarmed:
            return
        self._prewarmed.add(nxt)
        threading.Thread(target=self._prewarm_safe, args=(int(nxt),),
                         daemon=True, name="freyja-prewarm").start()

    def _prewarm_safe(self, bucket: int) -> None:
        try:
            self.prewarm_bucket(bucket)
        except Exception:
            pass    # best effort: a failed prewarm only means a
                    # first-contact compile at the actual crossing

    def prewarm_bucket(self, bucket: int) -> dict:
        """Synchronously AOT-compile the serving plan set at ``bucket``
        corpus columns on the current head's executor.  The executables
        land in the head's dispatch table under corpus-width-qualified
        keys, which ``Executor.extended`` carries forward — the crossing
        inherits them and pays no compile.  ``refresh`` calls this on a
        daemon thread near bucket capacity; tests call it directly."""
        st = self._pin()
        try:
            bb = (self.planner.config.batch_buckets
                  or tuple(DEFAULT_BATCH_BUCKETS))
            entries = [(plan, b) for b in sorted({int(x) for x in bb})
                       for plan in self.planner.plan_set(
                           n_columns=int(bucket), n_queries=b,
                           mode=self.config.mode, mesh=self.mesh,
                           grid=self.config.grid, scope="serve")]
            return st.executor.aot_compile(entries, cache=self._exec_cache,
                                           n_columns=int(bucket))
        finally:
            self._release(st)

    def follow(self, reader, *, auto: bool = True) -> None:
        """Attach a :class:`~repro.service.catalog.CatalogReader`; every
        query batch first tails the manifest chain and refreshes onto the
        newest published version.  ``auto=False`` attaches without the
        per-batch polling — an external driver (the fleet's rolling
        refresher) calls ``_maybe_follow(force=True)`` on its own cadence
        so replicas never all rebuild at once."""
        self._reader = reader
        self._follow_auto = bool(auto)
        # adopt the follower into this engine's observability plane so
        # its manifest_advanced events land on the same bus
        if self.events is not None and getattr(reader, "events", None) is None:
            reader.events = self.events
        self._maybe_follow(force=True)

    def attach_scheduler(self, scheduler) -> None:
        """Register the continuous-batching runtime driving this engine so
        its counters surface under ``stats()["scheduler"]`` (called by
        ``RequestScheduler.__init__``; the latest attached wins)."""
        self._scheduler = scheduler

    # -- AOT warmup ----------------------------------------------------------

    def warmup(self, scope: str | None = None) -> dict:
        """AOT-compile the admissible executable set before admitting
        traffic: every bucket of the padded-batch ladder × the plans
        :meth:`Planner.plan_set` enumerates for it (``scope="serve"`` —
        the served plan plus its recall baseline; ``scope="full"`` — every
        admissible candidate kind × grid factorization).  Executables come
        from the persistent :class:`~repro.exec.ExecutableCache` when
        ``executable_cache_dir`` is set and the signature matches, else
        from a fresh ``lower().compile()`` that is then stored — so a
        restarted engine warms from disk in milliseconds.

        ``warm_event`` is cleared for the duration; a scheduler with
        ``wait_for_warm`` holds batch dispatch until it sets again.
        Returns (and stashes as ``warmup_report``) the compile/hit counts
        and walls."""
        if scope is None:
            w = self.config.warmup
            scope = w if isinstance(w, str) and w else "serve"
        if scope not in ("serve", "full"):
            raise ValueError(f"unknown warmup scope {scope!r}; "
                             f"want 'serve' or 'full'")
        if not self.planner.config.batch_buckets:
            # no ladder configured (scheduler not constructed yet, or a
            # direct-call engine): warm the default ladder, and install it
            # so serving actually pads onto the warmed shapes
            ladder = tuple(DEFAULT_BATCH_BUCKETS)
            self.config.batch_buckets = ladder
            self.planner.config.batch_buckets = ladder
        buckets = tuple(sorted({int(b)
                                for b in self.planner.config.batch_buckets}))
        t0 = time.perf_counter()
        self.warm_event.clear()
        st = self._pin()
        try:
            entries = [(plan, b) for b in buckets
                       for plan in self.planner.plan_set(
                           n_columns=st.executor.n_columns, n_queries=b,
                           mode=self.config.mode, mesh=self.mesh,
                           grid=self.config.grid, scope=scope)]
            if self.events is not None:
                self.events.publish(EV.WARMUP_BEGIN, scope=scope,
                                    buckets=list(buckets),
                                    n_plans=len(entries))
            report = st.executor.aot_compile(entries,
                                             cache=self._exec_cache)
        finally:
            self._release(st)
            self.warm_event.set()
        report["scope"] = scope
        report["buckets"] = list(buckets)
        report["wall_ms"] = (time.perf_counter() - t0) * 1e3
        if self.events is not None:
            self.events.publish(
                EV.WARMUP_END, scope=scope,
                executables=report["n_executables"],
                cache_hits=report["cache_hits"],
                cache_misses=report["cache_misses"],
                wall_ms=report["wall_ms"])
        self.warmup_report = report
        return report

    def _maybe_follow(self, force: bool = False) -> None:
        reader = self._reader
        if reader is None or (not force and not self._follow_auto):
            return
        new = reader.poll()
        if new:
            # a burst of manifest advances collapses into ONE refresh onto
            # the newest version (latest-snapshot path: race-proof against
            # a compaction deleting an intermediate version's segments) —
            # a follower behind by N versions pays one build, not N
            coalesced = len(new) - 1
            if coalesced:
                with self._slock:
                    self._counters["refreshes_coalesced"] += coalesced
            self.refresh(reader.snapshot(), _coalesced=coalesced)

    def _build_state(self, snapshot: CatalogSnapshot) -> _VersionState:
        prof = snapshot.profiles
        w = prof.words
        lsh = LSHIndex.build(snapshot.signatures, self.config.lsh)
        dt = self.config.profile_dtype
        # corpus-axis bucket padding applies to full builds too, so the
        # traced shapes match what later delta refreshes re-dispatch
        n_pad = (self.planner.snap_columns(snapshot.n_columns)
                 if self.planner.config.column_buckets else None)
        # moments reconstruct EXACTLY from the snapshot stats — no O(lake)
        # float64 pass; delta refreshes fold onto these
        mean, std = prof.mean, prof.std
        moments = moments_from_stats(mean, std, snapshot.n_columns)
        if snapshot.lazy and dt != "fp32":
            # lazy snapshot + quantized sidecar: stream the quantizer over
            # the memmapped raw profiles in blocks (byte-identical sidecar
            # to the eager path) and never materialize the lake-sized fp32
            # z-score matrix — per-row resolve and the exact rescore
            # re-z-score just the rows they gather, through the lazy view
            sidecar, scale = quantize_profiles_streamed(
                prof.numeric, prof.mean, prof.std, dt)
            zv = prof.zscored_view()
            executor = Executor(
                sidecar, w, self.model.gbdt.astuple(),
                table_ids=snapshot.table_ids, band_keys=lsh.keys,
                coarse_keys=lsh.coarse, profile_dtype=dt,
                z_scale=scale, fp32_rows=zv.__getitem__,
                mesh=self.mesh, events=self.events,
                exec_cache=self._exec_cache, n_padded=n_pad)
            return _VersionState(snapshot=snapshot, z=zv, w=w, lsh=lsh,
                                 executor=executor, mean=mean, std=std,
                                 moments=moments)
        z = prof.zscored.astype(np.float32)
        executor = Executor(
            z, w, self.model.gbdt.astuple(),
            table_ids=snapshot.table_ids, band_keys=lsh.keys,
            coarse_keys=lsh.coarse,
            profile_dtype=dt,
            mesh=self.mesh, events=self.events,
            exec_cache=self._exec_cache, n_padded=n_pad)
        return _VersionState(snapshot=snapshot, z=z, w=w, lsh=lsh,
                             executor=executor, mean=mean, std=std,
                             moments=moments)

    def _pin(self) -> _VersionState:
        with self._slock:
            if self._closed:
                raise RuntimeError("engine is closed")
            st = self._head
            st.refs += 1
        if self.events is not None:      # publish outside the lock
            self.events.publish(EV.SNAPSHOT_PINNED, version=st.version,
                                refs=st.refs)
        return st

    def _release(self, st: _VersionState) -> None:
        with self._slock:
            st.refs -= 1
            dead = st.refs == 0
            if dead:
                self._live.discard(st)
        if dead:
            st.executor.close()
            if self.events is not None:
                self.events.publish(EV.SNAPSHOT_RETIRED, version=st.version)

    # -- lifecycle (fleet drain/evict hook) ---------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Retire this engine: refuse new pins and release the head's
        construction reference.  The drain hook fleet eviction relies on —
        in-flight batches keep their pinned version until their own
        ``finally`` unpins it, so once the last one finishes every live
        state's refcount reaches zero and its executor is closed.
        Idempotent; a closed engine still answers ``stats()``."""
        with self._slock:
            if self._closed:
                return
            self._closed = True
            head = self._head
        if head is not None:
            self._release(head)

    # -- compat surface (head-state views) ----------------------------------

    @property
    def snapshot(self) -> CatalogSnapshot:
        return self._head.snapshot

    @property
    def version(self) -> int:
        return self._head.version

    @property
    def lsh(self) -> LSHIndex:
        return self._head.lsh

    @property
    def _executor(self) -> Executor:
        return self._head.executor

    @property
    def _z_np(self) -> np.ndarray:
        return self._head.z

    @property
    def _w_np(self) -> np.ndarray:
        return self._head.w

    @property
    def n_columns(self) -> int:
        return self._head.snapshot.n_columns

    @property
    def candidate_budget(self) -> int:
        return self.planner.candidate_budget(self.n_columns)

    # -- query path ---------------------------------------------------------

    def query(self, request: DiscoveryRequest) -> DiscoveryResponse:
        return self.query_batch([request])[0]

    def query_batch(self, requests: list[DiscoveryRequest], *,
                    trace_ids: list[str] | None = None
                    ) -> list[DiscoveryResponse]:
        """Serve one micro-batch against one pinned snapshot version.

        Reentrant: the scheduler's worker, direct callers, and racing
        ``refresh``/follower swaps may all run concurrently — each call
        pins its own version end-to-end and the result cache/counters
        are lock-guarded.  ``compute_ms`` on each response is this
        call's per-query share; ``queue_ms`` stays 0 unless a scheduler
        delivered the batch.  ``trace_ids`` threads the scheduler's
        per-submission ids through; direct callers get fresh ids (or the
        request's own ``trace_id``) and a trace whose spans sum to
        ``compute_ms``."""
        t0 = time.perf_counter()
        if trace_ids is None:
            trace_ids = [r.trace_id or EV.mint_trace_id() for r in requests]
        self._maybe_follow()
        st = self._pin()
        try:
            return self._query_pinned(st, requests, t0, trace_ids)
        finally:
            self._release(st)

    def _query_pinned(self, st: _VersionState,
                      requests: list[DiscoveryRequest], t0: float,
                      trace_ids: list[str]) -> list[DiscoveryResponse]:
        if st.snapshot.n_columns == 0:
            return [DiscoveryResponse(name=r.name, matches=[],
                                      n_candidates=0, trace_id=tid)
                    for r, tid in zip(requests, trace_ids)]
        # contiguous phase marks: (phase, t) pairs partition [t0, t_end]
        # so the per-query span shares sum EXACTLY to compute_ms
        marks: list[tuple[str, float]] = [("pin", time.perf_counter())]
        zq, wq, sigq, tq, qid = self._resolve(requests, st)
        keys = [self._cache_key(st, zq[i], wq[i], sigq[i], requests[i])
                for i in range(len(requests))]

        responses: list[DiscoveryResponse | None] = [None] * len(requests)
        todo = []
        scored = 0
        for i, key in enumerate(keys):
            hit = self._cache_get(key)
            if hit is not None:
                responses[i] = DiscoveryResponse(
                    name=requests[i].name,
                    matches=self._trim(hit, requests[i]),
                    n_candidates=0, cached=True, trace_id=trace_ids[i])
            else:
                todo.append(i)
        marks.append(("resolve", time.perf_counter()))

        compile_ms = None
        if todo:
            scores, ids, ncand, plan = self._rank_rows(
                zq[todo], wq[todo], sigq[todo], tq[todo], qid[todo], st,
                marks=marks)
            compile_ms = st.executor.last_compile_ms()
            # the plan's cost was modeled for the PADDED batch — normalize
            # by that count, not len(todo), or a lone miss looks batch_pad×
            # costlier than the same query served in a full batch
            cost_per_query = (plan.cost.get("total_flops", 0.0)
                              / max(plan.cost.get("n_queries", 1), 1))
            for row, i in enumerate(todo):
                matches = self._matches(scores[row], ids[row], st)
                self._cache_put(keys[i], matches, cost_per_query)
                responses[i] = DiscoveryResponse(
                    name=requests[i].name,
                    matches=self._trim(matches, requests[i]),
                    n_candidates=int(ncand[row]), trace_id=trace_ids[i])
                scored += int(ncand[row])

        with self._slock:                  # one locked fold per batch
            self._counters["queries"] += len(requests)
            self._counters["batches"] += 1
            self._counters["cache_hits"] += len(requests) - len(todo)
            self._counters["cache_misses"] += len(todo)
            self._counters["scored_columns"] += scored
            self._counters["scan_columns"] += \
                len(todo) * st.snapshot.n_columns
        if self.events is not None:
            hits = [trace_ids[i] for i in range(len(requests))
                    if i not in set(todo)]
            if hits:
                self.events.publish(EV.CACHE_HIT, n=len(hits),
                                    trace_ids=hits, version=st.version)
            if todo:
                self.events.publish(EV.CACHE_MISS, n=len(todo),
                                    trace_ids=[trace_ids[i] for i in todo],
                                    version=st.version)
        t_end = time.perf_counter()
        n = max(len(requests), 1)
        dt_ms = (t_end - t0) * 1e3 / n
        spans = []
        prev = t0
        for phase, t in marks + [("finalize", t_end)]:
            spans.append({"phase": phase, "ms": (t - prev) * 1e3 / n})
            prev = t
        if compile_ms is not None:
            for s in spans:                # annotate, never add a span —
                if s["phase"] == "execute":  # the sum must stay exact
                    s["compile_ms"] = compile_ms
        for r in responses:
            r.compute_ms = dt_ms
            r.latency_ms = r.queue_ms + dt_ms
            r.trace = r.trace + [dict(s) for s in spans]
        return responses

    # -- observability ------------------------------------------------------

    def stats(self) -> dict:
        """Serving counters for capacity planning (the ``/stats`` payload):
        query/batch totals, cache hit/miss/admission counts, the per-plan
        query histogram, snapshot-version lifecycle (current version,
        refresh count, live pinned states), the last executed plan with
        its modeled cost, and — when a :class:`RequestScheduler` is
        attached — the scheduler's counters (queue depth, formed-batch
        size histogram, bucket hits, expirations, sheds)."""
        # one consistent snapshot: counters, cache occupancy, plan
        # histogram and version lifecycle are all read under the same
        # locks that guard their writers (lock order _slock -> _cache_lock
        # matches refresh()), so a stats() racing a batch fold or a cache
        # admission can never see a torn view (e.g. hits+misses != queries)
        with self._slock:
            plans = dict(self._plan_counts)
            head = self._head
            version = head.version
            n_columns = head.snapshot.n_columns
            exec_columns = head.executor.n_columns
            live = len(self._live)
            rs = dict(self._refresh_stats)
            prewarmed = sorted(self._prewarmed)
            with self._cache_lock:     # admission counters live under it
                c = dict(self._counters)
                cache_size = len(self._cache)
        out = {
            "queries": c["queries"], "batches": c["batches"],
            "scored_columns": c["scored_columns"],
            "scan_columns": c["scan_columns"],
            "cache": {
                "hits": c["cache_hits"], "misses": c["cache_misses"],
                "admitted": c["cache_admitted"],
                "rejected": c["cache_rejected"],
                "evicted": c["cache_evicted"],
                "size": cache_size,
                "capacity": self.config.cache_entries,
            },
            "plans": plans,
            "n_columns": n_columns,
            "snapshot": {"version": version, "refreshes": c["refreshes"],
                         "live_states": live},
            "refresh": {**rs,
                        "coalesced": c["refreshes_coalesced"],
                        "stats_drift": _stats_drift(head),
                        "column_bucket": exec_columns,
                        "prewarmed": prewarmed},
        }
        if self._scheduler is not None:
            out["scheduler"] = self._scheduler.stats()
        if self.last_plan is not None:
            p = self.last_plan
            out["last_plan"] = {"kind": p.kind, "budget": p.budget,
                                "n_shards": p.n_shards,
                                "grid": list(p.grid), "k": p.k,
                                "cost": p.cost}
        return out

    # -- internals ----------------------------------------------------------

    def _pad_target(self, n_queries: int) -> int:
        """Padded size of an ``n_queries`` micro-batch: the bucket ladder
        when one is configured (scheduler-installed or explicit), else
        the next ``batch_pad`` multiple — the legacy padding."""
        if self.planner.config.batch_buckets:
            return self.planner.snap_batch(n_queries)
        bp = max(self.config.batch_pad, 1)
        return -(-max(int(n_queries), 1) // bp) * bp

    def _rank_rows(self, zq, wq, sigq, tq, qid,
                   st: _VersionState | None = None, marks=None):
        """Plan + execute one padded micro-batch through ``repro.exec``.

        ``marks`` (optional) collects contiguous ``(phase, t)`` trace
        marks — plan / candidates / execute — for the caller's span
        accounting."""
        st = st if st is not None else self._head
        (zq, wq, sigq, tq, qid), q = pad_rows(
            (zq, wq, sigq, tq, qid),
            self._pad_target(np.asarray(zq).shape[0]))
        pad = zq.shape[0]

        # plan against the executor's (bucket-padded) corpus width, not
        # the live count: plan statics then stay fixed inside a bucket,
        # which is what lets an in-bucket ingest delta re-dispatch the
        # same compiled executables with zero recompiles
        plan = self.planner.plan(n_columns=st.executor.n_columns,
                                 n_queries=pad, mode=self.config.mode,
                                 mesh=self.mesh, grid=self.config.grid)
        if marks is not None:
            marks.append(("plan", time.perf_counter()))
        qkeys = (st.lsh.query_keys(sigq) if plan.candidates != "all"
                 else None)
        qcoarse = (st.lsh.coarse_query_keys(sigq)
                   if plan.candidates == "tiered" else None)
        if marks is not None:
            marks.append(("candidates", time.perf_counter()))
        sc, ids, ncand = st.executor.execute(plan, zq, wq, tq, qid,
                                             qkeys=qkeys, qcoarse=qcoarse)
        if marks is not None:
            marks.append(("execute", time.perf_counter()))
        self.last_plan = plan
        with self._slock:
            self._plan_counts[plan.kind] = \
                self._plan_counts.get(plan.kind, 0) + q
        return sc[:q], ids[:q], ncand[:q], plan

    def _resolve(self, requests, st: _VersionState | None = None):
        """Requests -> stacked (zq, wq, sigq, tq, qid) numpy rows."""
        st = st if st is not None else self._head
        snap = st.snapshot
        n = len(requests)
        zq = np.zeros((n, FT.F_NUM), np.float32)
        wq = np.zeros((n, FT.F_WORDS), np.uint32)
        sigq = np.zeros((n, snap.signatures.shape[1]), np.uint32)
        tq = np.full((n,), -1, np.int32)
        qid = np.full((n,), -1, np.int32)

        external = [i for i, r in enumerate(requests) if r.values is not None]
        for i, req in enumerate(requests):
            if req.column_id is not None:
                cid = int(req.column_id)
                if not 0 <= cid < snap.n_columns:
                    raise IndexError(f"column_id {cid} outside catalog "
                                     f"(0..{snap.n_columns - 1})")
                zq[i] = st.z[cid]
                wq[i] = st.w[cid]
                sigq[i] = snap.signatures[cid]
                qid[i] = cid
                if self.config.exclude_same_table:
                    tq[i] = int(snap.table_ids[cid])
        if external:
            profs = self._ensure_profiled([requests[i] for i in external],
                                          st)
            prof = snap.profiles
            # the version's FROZEN stats, not the snapshot's recomputed
            # ones: a delta-built state z-scored its resident rows with
            # the predecessor's (mean, std), and uploaded queries must
            # live in the same space or scores skew post-ingest
            mean = st.mean if st.mean is not None else prof.mean
            std = st.std if st.std is not None else prof.std
            for (_, num, words, sigs), i in zip(profs, external):
                zq[i] = (num - mean) / std
                wq[i] = words
                sigq[i] = sigs
        return zq, wq, sigq, tq, qid

    def profile_request(self, request: DiscoveryRequest) -> None:
        """Profile + MinHash an uploaded (``values=``) request against the
        current head's signature geometry and stash the raw profile on the
        request.  The scheduler calls this at **submit time**, in the
        submitter's thread, so the worker's formed-batch path is pure
        scoring dispatch; a no-op for resident (``column_id=``) requests
        and for requests already stashed with a matching geometry."""
        if request.values is None:
            return
        st = self._pin()
        try:
            self._ensure_profiled([request], st)
        finally:
            self._release(st)

    def _ensure_profiled(self, requests, st: _VersionState) -> list[tuple]:
        """Return one (geometry, numeric, words, sigs) profile per request
        for ``st``'s signature geometry, stashing fresh ones on the
        requests.  The stash is geometry-keyed, not version-keyed: a
        refresh that keeps the MinHash geometry reuses the device
        profiling and only re-z-scores (cheap numpy) at resolve.  The
        returned tuples — not re-reads of the mutable stash, which a
        concurrent profile against a different geometry may replace — are
        what the caller must consume."""
        snap = st.snapshot
        geom = (sigq_width(snap), int(snap.minhash_seed))
        out: dict[int, tuple] = {}
        todo, queued = [], set()
        for r in requests:
            p = r._profile                 # snapshot the mutable field once
            if p is not None and p[0] == geom:
                out[id(r)] = p
            elif id(r) not in queued:      # one profile per request object
                queued.add(id(r))
                todo.append(r)
        if todo:
            batch, _ = ingest_string_columns(
                [(r.name, r.values) for r in todo])
            num, words, sigs = profile_and_sign(batch, *geom)
            for row, r in enumerate(todo):
                p = (geom, num[row], words[row], sigs[row])
                r._profile = p
                out[id(r)] = p
        return [out[id(r)] for r in requests]

    def _matches(self, scores, ids,
                 st: _VersionState | None = None) -> list[ColumnMatch]:
        st = st if st is not None else self._head
        snap = st.snapshot
        out = []
        for s, i in zip(scores, ids):
            if not np.isfinite(s) or i < 0:
                continue
            tid = int(snap.table_ids[i])
            out.append(ColumnMatch(
                column_id=int(i), column=snap.names[i],
                table=snap.table_names.get(tid, str(tid)),
                score=float(s)))
        return out

    def _trim(self, matches, request):
        k = request.k if request.k is not None else self.config.k
        return list(matches[:k])

    def _cache_key(self, st: _VersionState, z_row, w_row, sig_row,
                   request) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(z_row.tobytes())
        h.update(w_row.tobytes())
        h.update(sig_row.tobytes())     # LSH results depend on the signature
        h.update(f"{self.config.mode}|{self.config.k}|"
                 f"{self.config.exclude_same_table}|"
                 f"{request.column_id}".encode())
        # version prefix = cache namespace: an insert racing a refresh lands
        # under its (retired) version and is unreachable from the new head
        return st.version.to_bytes(8, "big", signed=True) + h.digest()

    def _cache_get(self, key):
        with self._cache_lock:
            hit = self._cache.get(key)
            if hit is None:
                return None
            self._cache.move_to_end(key)
            return hit[0]

    def _cache_put(self, key, matches, cost: float) -> None:
        """Cost-aware admission: when full, the cheapest (oldest on ties)
        resident entry is the victim — and a new entry cheaper than every
        resident one is not admitted at all (cheap plans are cheap to
        recompute; a full-scan result outranks any pruned one)."""
        cap = self.config.cache_entries
        if cap <= 0:
            return
        with self._cache_lock:
            if key in self._cache:
                self._cache[key] = (matches, cost)
                self._cache.move_to_end(key)
                return
            if len(self._cache) >= cap:
                victim, vcost = None, np.inf
                for k_, (_, c_) in self._cache.items():  # oldest-first:
                    if c_ < vcost:                       # ties go oldest
                        victim, vcost = k_, c_
                if cost < vcost:
                    self._counters["cache_rejected"] += 1
                    return
                del self._cache[victim]
                self._counters["cache_evicted"] += 1
            self._cache[key] = (matches, cost)
            self._counters["cache_admitted"] += 1


def _stats_drift(st: _VersionState) -> float:
    """How far the lake's TRUE normalization has drifted from the state's
    frozen (mean, std), in current-std units: ``max |mean_now - frozen| /
    std_now``.  Delta refreshes fold true moments O(delta), so this stays
    exact without rescoring anything; operators watch it to decide when a
    full rebuild (which re-freezes the stats) is worth scheduling."""
    m, frozen = st.moments, st.mean
    if m is None or frozen is None or not int(m["count"]):
        return 0.0
    n = float(m["count"])
    mean_now = np.asarray(m["sum"], np.float64) / n
    var = np.maximum(np.asarray(m["sumsq"], np.float64) / n
                     - mean_now * mean_now, 0.0)
    std_now = np.maximum(np.sqrt(var), 1e-6)
    return float(np.max(np.abs(mean_now - np.asarray(frozen, np.float64))
                        / std_now))


def sigq_width(snapshot: CatalogSnapshot) -> int:
    return int(snapshot.signatures.shape[1])


def measure_recall(engine: DiscoveryEngine, query_ids: np.ndarray,
                   k: int | None = None) -> dict:
    """Recall@k of the engine's (pruned) top-k against the full scan on the
    same snapshot, plus the fraction of the lake scored.

    Shard-aware on both sides: the pruned run reports the *global* number
    of columns scored (per-device counts are psum-ed over the DATA axes
    only — a query-sharded grid must not double-count its query replicas),
    and the exact baseline is the sharded full scan **on the same
    (q_shards, d_shards) grid** whenever the engine's plan is sharded — so
    ``scored_fraction`` and recall stay honest on any mesh geometry.
    """
    k = k or engine.config.k
    if k > engine.config.k:
        raise ValueError(f"k={k} exceeds the engine's configured "
                         f"k={engine.config.k}; the pruned side can only "
                         f"return config.k results")
    reqs = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q), k=k)
            for q in query_ids]
    st = engine._pin()                  # both sides see one version
    try:
        zq, wq, sigq, tq, qid = engine._resolve(reqs, st)
        got_s, got_ids, ncand, plan = engine._rank_rows(zq, wq, sigq, tq,
                                                        qid, st)
        # the served plan's grid was chosen against the PADDED batch; plan
        # the baseline at the same size so its q_shards stay admissible
        pad = engine._pad_target(len(reqs))
        base_plan = engine.planner.plan(
            n_columns=st.executor.n_columns, n_queries=pad,
            mode="sharded" if plan.sharded else "full",
            mesh=engine.mesh if plan.sharded else None,
            grid=plan.grid if plan.sharded else None)
        full_s, full_ids, _ = st.executor.execute(base_plan, zq, wq, tq, qid)
        n_columns = st.snapshot.n_columns
    finally:
        engine._release(st)
    hits, total = 0, 0
    for row in range(len(reqs)):
        want = set(full_ids[row][:k][np.isfinite(full_s[row][:k])].tolist())
        got = set(got_ids[row][:k][np.isfinite(got_s[row][:k])].tolist())
        hits += len(want & got)
        total += len(want)
    return {"recall": hits / max(total, 1),
            "scored_fraction": float(ncand.mean()) / max(n_columns, 1),
            "candidate_budget": engine.candidate_budget,
            "plan": plan.kind, "baseline_plan": base_plan.kind,
            "k": k, "n_queries": len(reqs)}
