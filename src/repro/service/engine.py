"""DiscoveryEngine: batched two-stage query serving over a catalog snapshot.

Pipeline per micro-batch of concurrent queries:

1. **Candidate generation** — the LSH bucket probe marks the columns that
   share a MinHash band with each query (``kernels/lsh_probe``), and a
   stable top-k over the hit mask gathers them into a fixed candidate
   budget (a static fraction of the lake, so the stage is jit-cached).
2. **Re-rank** — only the gathered candidates go through the expensive
   distance-features + GBDT scorer; the final top-k comes out of that
   small (Q, budget) score block.

Modes: ``lsh`` (two-stage, the default), ``full`` (single-device brute
scan — the exact baseline), ``sharded`` (full scan via ``rank_sharded``
over a mesh, for lakes larger than one device).

An LRU cache keyed by the query-profile hash short-circuits repeated
queries (identical uploaded columns are common in production traffic);
entries are invalidated wholesale when the catalog version moves.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as FT
from repro.core.discovery import build_rank_sharded
from repro.core.ingest import ingest_string_columns
from repro.core.predictor import (JoinQualityModel, distance_features_ref,
                                  gbdt_predict_ref)
from repro.kernels.lsh_probe import lsh_probe_pallas
from repro.service.api import ColumnMatch, DiscoveryRequest, DiscoveryResponse
from repro.service.catalog import (CatalogSnapshot, ColumnCatalog,
                                   profile_and_sign)
from repro.service.lsh import LSHConfig, LSHIndex


@dataclasses.dataclass
class EngineConfig:
    k: int = 10
    mode: str = "lsh"                  # "lsh" | "full" | "sharded"
    lsh: LSHConfig = dataclasses.field(default_factory=LSHConfig)
    candidate_frac: float = 0.2        # LSH budget as a fraction of the lake
    max_candidates: int = 4096         # absolute cap on that budget
    batch_pad: int = 8                 # pad micro-batches to this multiple
    cache_entries: int = 1024
    exclude_same_table: bool = True
    shard_axes: tuple = ("data",)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("k", "max_cand", "interpret"))
def _lsh_rank(zq, wq, qkeys, tq, qid, z, w, ckeys, tids, gbdt_tuple,
              k: int, max_cand: int, interpret: bool):
    """Two-stage ranking. Query tensors are (Q, ...); tq=-1 disables the
    same-table mask for a row, qid=-1 marks an external (non-resident)
    query. Returns (scores (Q,k), ids (Q,k), n_scored (Q,)).

    Candidate generation is hybrid (the blocking construction of Flores et
    al.): every LSH bucket hit is a candidate, and the remaining budget is
    filled with the nearest columns in profile space (squared-L2 proxy via
    one matmul — no trees, no word features). LSH covers the high-overlap
    joins; the profile proxy covers what the GBDT ranks by profile shape.
    """
    mask = lsh_probe_pallas(qkeys, ckeys, interpret=interpret)   # (Q, C)
    # -||zq - z||² up to a per-query constant: 2·zq@zᵀ - ||z||²
    proxy = 2.0 * zq @ z.T - jnp.sum(z * z, axis=1)[None]        # (Q, C)
    proxy = proxy / (1.0 + jnp.abs(proxy))                       # squash to (-1, 1)
    big = jnp.float32(4.0)
    prio = mask.astype(jnp.float32) * big + proxy
    # keep excluded columns out of the budget entirely
    prio = jnp.where(tids[None] == tq[:, None], -jnp.inf, prio)
    n = z.shape[0]
    prio = jnp.where(jnp.arange(n)[None] == qid[:, None], -jnp.inf, prio)
    pval, cand = jax.lax.top_k(prio, max_cand)                   # (Q, M)
    valid = jnp.isfinite(pval)
    d = distance_features_ref(zq[:, None], wq[:, None], z[cand], w[cand])
    s = gbdt_predict_ref(gbdt_tuple, d)                          # (Q, M)
    s = jnp.where(valid, s, -jnp.inf)
    sc, pos = jax.lax.top_k(s, min(k, max_cand))
    ids = jnp.take_along_axis(cand, pos, axis=1)
    ids = jnp.where(jnp.isfinite(sc), ids, -1)
    return sc, ids, valid.sum(axis=1)


@partial(jax.jit, static_argnames=("k",))
def _full_rank(zq, wq, tq, qid, z, w, tids, gbdt_tuple, k: int):
    """Single-device brute scan (the exact baseline the LSH path prunes)."""
    n = z.shape[0]
    d = distance_features_ref(zq[:, None], wq[:, None], z[None], w[None])
    s = gbdt_predict_ref(gbdt_tuple, d)                          # (Q, N)
    s = jnp.where(tids[None] == tq[:, None], -jnp.inf, s)
    s = jnp.where(jnp.arange(n)[None] == qid[:, None], -jnp.inf, s)
    sc, ids = jax.lax.top_k(s, min(k, n))
    ids = jnp.where(jnp.isfinite(sc), ids, -1)
    return sc, ids, jnp.full((zq.shape[0],), n, jnp.int32)


class DiscoveryEngine:
    """Serves discovery queries from a catalog snapshot."""

    def __init__(self, snapshot: CatalogSnapshot, model: JoinQualityModel,
                 config: EngineConfig | None = None, mesh=None):
        config = config if config is not None else EngineConfig()
        self.config = config
        self.model = model
        self.mesh = mesh
        self._gbdt = tuple(map(jnp.asarray, model.gbdt.astuple()))
        self._cache: OrderedDict[bytes, list[ColumnMatch]] = OrderedDict()
        self.stats = {"queries": 0, "cache_hits": 0, "scored_columns": 0,
                      "scan_columns": 0, "batches": 0}
        self._sharded_fn = None
        self.refresh(snapshot)
        if config.mode == "sharded":
            if mesh is None:
                raise ValueError("sharded mode needs a mesh")
            self._sharded_fn = build_rank_sharded(
                mesh, config.k, self._gbdt, shard_axes=config.shard_axes,
                with_tables=True)

    @classmethod
    def from_catalog(cls, catalog: ColumnCatalog, model: JoinQualityModel,
                     config: EngineConfig | None = None, mesh=None):
        return cls(catalog.snapshot(), model, config=config, mesh=mesh)

    # -- snapshot management ------------------------------------------------

    def refresh(self, snapshot: CatalogSnapshot) -> None:
        """Swap in a new catalog snapshot (after add/drop/compact)."""
        self.snapshot = snapshot
        prof = snapshot.profiles
        self._z_np = prof.zscored.astype(np.float32)
        self._w_np = prof.words
        self._z = jnp.asarray(self._z_np)
        self._w = jnp.asarray(self._w_np)
        self._tids = jnp.asarray(snapshot.table_ids)
        self.lsh = LSHIndex.build(snapshot.signatures, self.config.lsh)
        self._ckeys = jnp.asarray(self.lsh.keys)
        self._cache.clear()

    @property
    def n_columns(self) -> int:
        return self.snapshot.n_columns

    @property
    def candidate_budget(self) -> int:
        c = self.n_columns
        want = max(self.config.k, int(c * self.config.candidate_frac))
        return max(1, min(want, self.config.max_candidates, c))

    # -- query path ---------------------------------------------------------

    def query(self, request: DiscoveryRequest) -> DiscoveryResponse:
        return self.query_batch([request])[0]

    def query_batch(self, requests: list[DiscoveryRequest]
                    ) -> list[DiscoveryResponse]:
        t0 = time.perf_counter()
        if self.n_columns == 0:
            return [DiscoveryResponse(name=r.name, matches=[], n_candidates=0)
                    for r in requests]
        zq, wq, sigq, tq, qid = self._resolve(requests)
        keys = [self._cache_key(zq[i], wq[i], sigq[i], requests[i]) for i in
                range(len(requests))]

        responses: list[DiscoveryResponse | None] = [None] * len(requests)
        todo = []
        for i, key in enumerate(keys):
            hit = self._cache_get(key)
            if hit is not None:
                responses[i] = DiscoveryResponse(
                    name=requests[i].name, matches=self._trim(hit, requests[i]),
                    n_candidates=0, cached=True)
                self.stats["cache_hits"] += 1
            else:
                todo.append(i)

        if todo:
            scores, ids, ncand = self._rank_rows(
                zq[todo], wq[todo], sigq[todo], tq[todo], qid[todo])
            for row, i in enumerate(todo):
                matches = self._matches(scores[row], ids[row])
                self._cache_put(keys[i], matches)
                responses[i] = DiscoveryResponse(
                    name=requests[i].name,
                    matches=self._trim(matches, requests[i]),
                    n_candidates=int(ncand[row]))
                self.stats["scored_columns"] += int(ncand[row])
                self.stats["scan_columns"] += self.n_columns

        self.stats["queries"] += len(requests)
        self.stats["batches"] += 1
        dt_ms = (time.perf_counter() - t0) * 1e3 / max(len(requests), 1)
        for r in responses:
            r.latency_ms = dt_ms
        return responses

    # -- internals ----------------------------------------------------------

    def _rank_rows(self, zq, wq, sigq, tq, qid):
        """Dispatch one padded micro-batch to the mode's jitted stage."""
        q = zq.shape[0]
        pad = -(-q // self.config.batch_pad) * self.config.batch_pad
        if pad != q:
            rep = lambda a: np.concatenate(
                [a, np.repeat(a[-1:], pad - q, axis=0)])
            zq, wq, sigq, tq, qid = map(rep, (zq, wq, sigq, tq, qid))

        mode = self.config.mode
        if mode == "lsh":
            qkeys = self.lsh.query_keys(sigq)
            sc, ids, ncand = _lsh_rank(
                jnp.asarray(zq), jnp.asarray(wq), jnp.asarray(qkeys),
                jnp.asarray(tq), jnp.asarray(qid), self._z, self._w,
                self._ckeys, self._tids, self._gbdt,
                self.config.k, self.candidate_budget, _interpret())
        elif mode == "full":
            sc, ids, ncand = _full_rank(
                jnp.asarray(zq), jnp.asarray(wq), jnp.asarray(tq),
                jnp.asarray(qid), self._z, self._w, self._tids, self._gbdt,
                self.config.k)
        elif mode == "sharded":
            sc, ids = self._sharded_rank(zq, wq, tq, qid)
            ncand = np.full((zq.shape[0],), self.n_columns, np.int32)
        else:
            raise ValueError(f"unknown mode {self.config.mode!r}")
        return np.asarray(sc)[:q], np.asarray(ids)[:q], np.asarray(ncand)[:q]

    def _sharded_rank(self, zq, wq, tq, qid):
        from repro.core.discovery import place_sharded_corpus
        corpus = place_sharded_corpus(self.mesh, self.config.shard_axes,
                                      self._z_np, self._w_np,
                                      table_ids=self.snapshot.table_ids)
        rep = corpus["rep"]
        sc, ids = self._sharded_fn(
            corpus["z"], corpus["w"], corpus["cids"],
            jax.device_put(zq.astype(np.float32), rep),
            jax.device_put(wq, rep),
            jax.device_put(qid.astype(np.int32), rep),
            corpus["tids"],
            jax.device_put(tq.astype(np.int32), rep))
        return np.asarray(sc), np.asarray(ids)

    def _resolve(self, requests):
        """Requests -> stacked (zq, wq, sigq, tq, qid) numpy rows."""
        n = len(requests)
        zq = np.zeros((n, FT.F_NUM), np.float32)
        wq = np.zeros((n, FT.F_WORDS), np.uint32)
        sigq = np.zeros((n, self.snapshot.signatures.shape[1]), np.uint32)
        tq = np.full((n,), -1, np.int32)
        qid = np.full((n,), -1, np.int32)

        external = [i for i, r in enumerate(requests) if r.values is not None]
        for i, req in enumerate(requests):
            if req.column_id is not None:
                cid = int(req.column_id)
                if not 0 <= cid < self.n_columns:
                    raise IndexError(f"column_id {cid} outside catalog "
                                     f"(0..{self.n_columns - 1})")
                zq[i] = self._z_np[cid]
                wq[i] = self._w_np[cid]
                sigq[i] = self.snapshot.signatures[cid]
                qid[i] = cid
                if self.config.exclude_same_table:
                    tq[i] = int(self.snapshot.table_ids[cid])
        if external:
            ze, we, se = self._profile_external(
                [requests[i] for i in external])
            for row, i in enumerate(external):
                zq[i], wq[i], sigq[i] = ze[row], we[row], se[row]
        return zq, wq, sigq, tq, qid

    def _profile_external(self, requests):
        """Profile + sign uploaded raw columns with the snapshot's stats."""
        batch, _ = ingest_string_columns(
            [(r.name, r.values) for r in requests])
        num, words, sigs = profile_and_sign(batch, sigq_width(self.snapshot),
                                            self.snapshot.minhash_seed)
        prof = self.snapshot.profiles
        return (num - prof.mean) / prof.std, words, sigs

    def _matches(self, scores, ids) -> list[ColumnMatch]:
        out = []
        for s, i in zip(scores, ids):
            if not np.isfinite(s) or i < 0:
                continue
            tid = int(self.snapshot.table_ids[i])
            out.append(ColumnMatch(
                column_id=int(i), column=self.snapshot.names[i],
                table=self.snapshot.table_names.get(tid, str(tid)),
                score=float(s)))
        return out

    def _trim(self, matches, request):
        k = request.k if request.k is not None else self.config.k
        return list(matches[:k])

    def _cache_key(self, z_row, w_row, sig_row, request) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(z_row.tobytes())
        h.update(w_row.tobytes())
        h.update(sig_row.tobytes())     # LSH results depend on the signature
        h.update(f"{self.config.mode}|{self.config.k}|"
                 f"{self.config.exclude_same_table}|"
                 f"{self.snapshot.version}|{request.column_id}".encode())
        return h.digest()

    def _cache_get(self, key):
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
        return hit

    def _cache_put(self, key, matches) -> None:
        self._cache[key] = matches
        self._cache.move_to_end(key)
        while len(self._cache) > self.config.cache_entries:
            self._cache.popitem(last=False)


def sigq_width(snapshot: CatalogSnapshot) -> int:
    return int(snapshot.signatures.shape[1])


def measure_recall(engine: DiscoveryEngine, query_ids: np.ndarray,
                   k: int | None = None) -> dict:
    """Recall@k of the engine's (LSH-pruned) top-k against the brute-force
    scan on the same snapshot, plus the fraction of the lake scored."""
    k = k or engine.config.k
    if k > engine.config.k:
        raise ValueError(f"k={k} exceeds the engine's configured "
                         f"k={engine.config.k}; the pruned side can only "
                         f"return config.k results")
    reqs = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q), k=k)
            for q in query_ids]
    zq, wq, sigq, tq, qid = engine._resolve(reqs)
    lsh_s, lsh_ids, ncand = engine._rank_rows(zq, wq, sigq, tq, qid)
    full_s, full_ids, _ = map(np.asarray, _full_rank(
        jnp.asarray(zq), jnp.asarray(wq), jnp.asarray(tq), jnp.asarray(qid),
        engine._z, engine._w, engine._tids, engine._gbdt, k))
    hits, total = 0, 0
    for row in range(len(reqs)):
        want = set(full_ids[row][:k][np.isfinite(full_s[row][:k])].tolist())
        got = set(lsh_ids[row][:k][np.isfinite(lsh_s[row][:k])].tolist())
        hits += len(want & got)
        total += len(want)
    return {"recall": hits / max(total, 1),
            "scored_fraction": float(ncand.mean()) / max(engine.n_columns, 1),
            "candidate_budget": engine.candidate_budget,
            "k": k, "n_queries": len(reqs)}
