"""Fault-tolerant checkpointing: atomic, keep-N, elastic mesh restore.

Format: one ``.npz`` per checkpoint step holding flattened param + optimizer
leaves (host numpy), plus a JSON manifest (step, keypaths, shapes, dtypes).
Writes go to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash mid-write
never corrupts the latest checkpoint. ``restore`` re-shards onto *any* mesh
via ``jax.device_put`` with the target sharding (elastic scaling: a job
restarted on a different pod count resumes from the same file).

On multi-host deployments the leaves would stream through a
``jax.experimental.multihost_utils`` gather; this container is single-host
so ``np.asarray`` suffices — the interface is the same.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree):
    # jax.tree.flatten_with_path only exists on newer jax; the tree_util
    # spelling works across the versions this repo supports
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def save(self, step: int, tree) -> str:
        keys, leaves, _ = _flatten(tree)
        arrays = {f"a{i}": np.asarray(v) for i, v in enumerate(leaves)}
        manifest = {"step": step, "keys": keys}
        tmp = os.path.join(self.dir, f"tmp.{step}.npz")
        final = self._path(step)
        np.savez(tmp, __manifest__=json.dumps(manifest), **arrays)
        os.replace(tmp, final)                      # atomic on POSIX
        self._gc()
        return final

    def latest_step(self) -> int | None:
        steps = [int(m.group(1)) for f in os.listdir(self.dir)
                 if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
        return max(steps) if steps else None

    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of ``like_tree``; device_put with
        ``shardings`` (any mesh) if given — elastic resharding."""
        z = np.load(self._path(step), allow_pickle=False)
        manifest = json.loads(str(z["__manifest__"]))
        keys, leaves, treedef = _flatten(like_tree)
        assert keys == manifest["keys"], "checkpoint/model structure mismatch"
        loaded = [z[f"a{i}"] for i in range(len(keys))]
        tree = jax.tree.unflatten(treedef, loaded)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if s is not None else a,
                tree, shardings)
        return tree, manifest["step"]

    def _gc(self):
        steps = sorted([int(m.group(1)) for f in os.listdir(self.dir)
                        if (m := re.match(r"ckpt_(\d+)\.npz$", f))])
        for s in steps[:-self.keep]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass
