"""Pallas TPU kernel: batched continuous join-quality Q(A,B,s).

Element-wise product of two truncated-Gaussian CDFs (erf on the VPU's
transcendental unit). Used by the exact-metric path of the benchmarks and by
label generation; tiled 2-D blocks over a flattened pair axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quality import QualityParams

_SQRT2 = 1.4142135623730951


def _phi(x):
    return 0.5 * (1.0 + jax.lax.erf(x / _SQRT2))


def _trunc_cdf(x, mu, sigma, lo, hi):
    num = _phi((x - mu) / sigma) - _phi((lo - mu) / sigma)
    den = _phi((hi - mu) / sigma) - _phi((lo - mu) / sigma)
    return jnp.clip(num / den, 0.0, 1.0)


def _kernel(j_ref, k_ref, out_ref, *, mu_j, mu_k, sigma_j, sigma_k, lo, hi):
    j = j_ref[...]
    k = k_ref[...]
    cj = _trunc_cdf(j, mu_j, sigma_j, lo, hi)
    ck = _trunc_cdf(k, mu_k, sigma_k, lo, hi)
    out_ref[...] = cj * ck


@functools.partial(jax.jit, static_argnames=("strictness", "block", "interpret"))
def quality_cdf_pallas(j, k, *, strictness: float = 0.25, block: int = 4096,
                       interpret: bool = True):
    """j, k: same-shape f32 arrays -> Q(A,B,s) element-wise."""
    p = QualityParams()
    shape = j.shape
    flat_j = j.reshape(-1)
    flat_k = k.reshape(-1)
    n = flat_j.shape[0]
    npad = max(-(-n // block) * block, block)
    fj = jnp.pad(flat_j, (0, npad - n)).reshape(npad // block, block)
    fk = jnp.pad(flat_k, (0, npad - n)).reshape(npad // block, block)
    out = pl.pallas_call(
        functools.partial(_kernel, mu_j=p.mu_j + strictness, mu_k=p.mu_k,
                          sigma_j=p.sigma_j, sigma_k=p.sigma_k, lo=p.lo, hi=p.hi),
        grid=(npad // block,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0)),
                  pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad // block, block), jnp.float32),
        interpret=interpret,
    )(fj, fk)
    return out.reshape(-1)[:n].reshape(shape)
