"""Pallas TPU kernel: oblivious-GBDT ensemble inference.

The join-quality model is evaluated for every (query, corpus-column) pair —
the per-query hot loop of FREYJA's ranking path. Oblivious trees make this
branch-free (see ``core/gbdt.py``): per tree,

  * feature select   — one-hot matmul ``(Nb, F) @ (F, D)``  (MXU),
  * level compares   — ``(Nb, D)`` >= thresholds            (VPU),
  * leaf index       — bit-pack of compares                 (VPU),
  * leaf lookup      — one-hot matmul ``(Nb, 2^D) @ (2^D,)``(MXU).

Rows are tiled into VMEM blocks of ``block_n``; the whole ensemble
(T×D feature ids/thresholds + T×2^D leaves — a few KB for the paper's 50
trees) is replicated into VMEM once per block. The tree loop is a
``fori_loop`` so the program stays O(1) in T.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, feats_ref, thrs_ref, leaves_ref, out_ref, *, base: float):
    x = x_ref[...]                      # (Nb, F) f32
    feats = feats_ref[...]              # (T, D) i32
    thrs = thrs_ref[...]                # (T, D) f32
    leaves = leaves_ref[...]            # (T, 2^D) f32
    t, d = feats.shape
    nb, f = x.shape
    n_leaves = leaves.shape[1]
    pw2 = (2 ** jnp.arange(d, dtype=jnp.int32))[None, :]
    f_iota = jnp.arange(f, dtype=jnp.int32)[:, None]
    l_iota = jnp.arange(n_leaves, dtype=jnp.int32)[None, :]

    def tree(ti, acc):
        f_l = jax.lax.dynamic_slice(feats, (ti, 0), (1, d))[0]
        t_l = jax.lax.dynamic_slice(thrs, (ti, 0), (1, d))[0]
        lv = jax.lax.dynamic_slice(leaves, (ti, 0), (1, n_leaves))[0]
        onehot_f = (f_iota == f_l[None, :]).astype(jnp.float32)   # (F, D)
        sel = jax.lax.dot(x, onehot_f,
                          precision=jax.lax.Precision.HIGHEST)    # (Nb, D)
        bits = (sel >= t_l[None, :]).astype(jnp.int32)
        idx = jnp.sum(bits * pw2, axis=-1)                        # (Nb,)
        onehot_l = (idx[:, None] == l_iota).astype(jnp.float32)   # (Nb, 2^D)
        return acc + jax.lax.dot(onehot_l, lv[:, None],
                                 precision=jax.lax.Precision.HIGHEST)[:, 0]

    acc0 = jnp.full((nb,), base, jnp.float32)
    out_ref[...] = jax.lax.fori_loop(0, t, tree, acc0)[:, None]


@functools.partial(jax.jit, static_argnames=("base", "block_n", "interpret"))
def gbdt_infer_pallas(x, feats, thrs, leaves, *, base: float,
                      block_n: int = 1024, interpret: bool = True):
    """x (N, F) f32 -> (N,) f32 predictions."""
    n, f = x.shape
    n_pad = -(-n // block_n) * block_n
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    t, d = feats.shape
    out = pl.pallas_call(
        functools.partial(_kernel, base=base),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((t, leaves.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        interpret=interpret,
    )(xp, feats, thrs, leaves)
    return out[:n, 0]
