"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(``tests/test_kernels.py`` sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import features as FT
from repro.core import quality

SENTINEL = jnp.uint32(FT.HASH_SENTINEL)


def gbdt_infer_ref(x, feats, thrs, leaves, base):
    """Oblivious-GBDT inference. x (N, F) -> (N,)."""
    t, d = feats.shape

    def tree(acc, tp):
        f_l, t_l, lv = tp
        sel = x[:, f_l]                                   # (N, D)
        bits = (sel >= t_l).astype(jnp.int32)
        idx = jnp.sum(bits * (2 ** jnp.arange(d, dtype=jnp.int32)), axis=-1)
        return acc + lv[idx], None

    acc0 = jnp.full((x.shape[0],), base, jnp.float32)
    out, _ = jax.lax.scan(tree, acc0, (feats, thrs, leaves))
    return out


def profile_distance_ref(z_q, w_q, z_c, w_c):
    """Distance features for all (query, corpus) pairs.

    z_q (Q, F_NUM) f32, w_q (Q, F_WORDS) u32, z_c (N, F_NUM), w_c (N, F_WORDS)
    -> (Q, N, F_DIST) f32
    """
    d_num = jnp.abs(z_q[:, None, :] - z_c[None, :, :])
    ta = w_q[:, :FT.N_FREQ_WORDS]
    tb = w_c[:, :FT.N_FREQ_WORDS]
    eq = (ta[:, None, :, None] == tb[None, :, None, :]) & (ta[:, None, :, None] != SENTINEL)
    overlap = eq.any(-1).sum(-1).astype(jnp.float32) / FT.N_FREQ_WORDS
    fa, fb = w_q[:, FT.FIRST_WORD], w_c[:, FT.FIRST_WORD]
    first = ((fa[:, None] == fb[None, :]) & (fa[:, None] != SENTINEL)).astype(jnp.float32)
    return jnp.concatenate([d_num, overlap[..., None], first[..., None]], axis=-1)


def fused_score_ref(z_q, w_q, z_c, w_c, feats, thrs, leaves, base):
    """profile_distance ∘ gbdt_infer without materializing (Q, N, F)."""
    d = profile_distance_ref(z_q, w_q, z_c, w_c)
    q, n, f = d.shape
    return gbdt_infer_ref(d.reshape(q * n, f), feats, thrs, leaves, base).reshape(q, n)


def minhash_ref(values, a, b):
    """MinHash signatures. values (C, R) u32 (sentinel-padded), a/b (P,) u32
    -> (C, P) u32 via universal hash h_p(v) = a_p * v + b_p (mod 2^32)."""
    v = values[:, :, None].astype(jnp.uint32)
    h = v * a[None, None, :] + b[None, None, :]
    h = jnp.where(values[:, :, None] == SENTINEL, jnp.uint32(0xFFFFFFFF), h)
    return jnp.min(h, axis=1)


def lsh_probe_ref(qkeys, ckeys):
    """Banded-LSH bucket probe. qkeys (Q, B) u32, ckeys (C, B) u32 ->
    (Q, C) int32: 1 iff the pair shares a bucket key in any band."""
    eq = qkeys[:, None, :] == ckeys[None, :, :]
    return jnp.any(eq, axis=-1).astype(jnp.int32)


def lsh_probe_gathered_ref(qkeys, ckeys):
    """Gathered-survivor probe. qkeys (Q, B) u32 against per-query key rows
    ckeys (Q, C', B) u32 -> (Q, C') int32 hit mask."""
    eq = qkeys[:, None, :] == ckeys
    return jnp.any(eq, axis=-1).astype(jnp.int32)


def minhash_jaccard_ref(sig_a, sig_b):
    """Estimated *set* Jaccard from signatures (the MinHash baseline)."""
    return jnp.mean((sig_a == sig_b).astype(jnp.float32), axis=-1)


def quality_cdf_ref(j, k, strictness, params: quality.QualityParams = quality.QualityParams()):
    """Continuous quality Q(A,B,s) — see core.quality."""
    return quality.continuous_quality(j, k, strictness, params)
