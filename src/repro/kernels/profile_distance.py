"""Pallas TPU kernels: profile distance features (+ fused scoring).

``profile_distance``: the (Q, N, F_DIST) distance tensor between query
profiles and a corpus tile — |Δz| per numeric slot, top-10 frequent-word
overlap, first-word equality. Memory-bound streaming over the corpus:
corpus tiles of ``block_n`` columns are staged through VMEM; queries are
small and replicated per block.

``fused_score``: the production path — distance features are consumed by the
oblivious-GBDT ensemble *inside the kernel*, so the (Q, N, F) tensor never
touches HBM: per (Q-tile, N-tile) the kernel writes only the (Qb, Nb) score
block. This is the kernel the roofline/§Perf iteration targets (the paper's
query path, arithmetic intensity lifted from ~1 flop/byte to ~T·D).

``fused_score_q``: the same fused scorer over a *quantized* corpus sidecar
— the resident z-scored profile matrix stored int8 (per-feature symmetric
scale, abs-max/127) or fp16, dequantized to f32 *inside the kernel* right
before the distance math. The corpus stream shrinks 4× (int8) / 2× (fp16)
in HBM and VMEM while queries stay f32; parity against the f32 top-k is
gated in tests (overlap ≥ 0.99).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import features as FT

_SENT = np.uint32(FT.HASH_SENTINEL)


def _distances(zq, wq, zc, wc):
    """(Qb, Nb, F_DIST) from profile blocks (shared by both kernels)."""
    d_num = jnp.abs(zq[:, None, :] - zc[None, :, :])          # (Qb, Nb, F_NUM)
    ta = wq[:, :FT.N_FREQ_WORDS]                               # (Qb, 10)
    tb = wc[:, :FT.N_FREQ_WORDS]                               # (Nb, 10)

    def word(ai, acc):
        wa = jax.lax.dynamic_slice(ta, (0, ai), (ta.shape[0], 1))  # (Qb, 1)
        hit = (wa[:, :, None] == tb[None, :, :]).any(-1)           # (Qb, Nb)
        return acc + jnp.where(wa != _SENT, hit, False).astype(jnp.float32)

    overlap = jax.lax.fori_loop(0, FT.N_FREQ_WORDS, word,
                                jnp.zeros((zq.shape[0], zc.shape[0]), jnp.float32))
    overlap = overlap / FT.N_FREQ_WORDS
    fa = wq[:, FT.FIRST_WORD]
    fb = wc[:, FT.FIRST_WORD]
    first = ((fa[:, None] == fb[None, :]) & (fa[:, None] != _SENT)).astype(jnp.float32)
    return jnp.concatenate([d_num, overlap[..., None], first[..., None]], axis=-1)


def _dist_kernel(zq_ref, wq_ref, zc_ref, wc_ref, out_ref):
    out_ref[...] = _distances(zq_ref[...], wq_ref[...], zc_ref[...], wc_ref[...])


@functools.partial(jax.jit, static_argnames=("block_q", "block_n", "interpret"))
def profile_distance_pallas(zq, wq, zc, wc, *, block_q: int = 8,
                            block_n: int = 256, interpret: bool = True):
    """zq (Q,F_NUM) f32, wq (Q,F_WORDS) u32, corpus likewise -> (Q,N,F_DIST)."""
    q, fn = zq.shape
    n = zc.shape[0]
    qp = -(-q // block_q) * block_q
    np_ = -(-n // block_n) * block_n
    zq = jnp.pad(zq, ((0, qp - q), (0, 0)))
    wq = jnp.pad(wq, ((0, qp - q), (0, 0)), constant_values=np.uint32(FT.HASH_SENTINEL))
    zc = jnp.pad(zc, ((0, np_ - n), (0, 0)))
    wc = jnp.pad(wc, ((0, np_ - n), (0, 0)), constant_values=np.uint32(FT.HASH_SENTINEL))
    fw = wq.shape[1]
    out = pl.pallas_call(
        _dist_kernel,
        grid=(qp // block_q, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_q, fn), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, fw), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, fn), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, fw), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n, FT.F_DIST), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((qp, np_, FT.F_DIST), jnp.float32),
        interpret=interpret,
    )(zq, wq, zc, wc)
    return out[:q, :n]


def _fused_body(d, feats_ref, thrs_ref, leaves_ref, *, base: float):
    qb, nb, f = d.shape
    x = d.reshape(qb * nb, f)
    feats = feats_ref[...]
    thrs = thrs_ref[...]
    leaves = leaves_ref[...]
    t, depth = feats.shape
    n_leaves = leaves.shape[1]
    pw2 = (2 ** jnp.arange(depth, dtype=jnp.int32))[None, :]
    f_iota = jnp.arange(f, dtype=jnp.int32)[:, None]
    l_iota = jnp.arange(n_leaves, dtype=jnp.int32)[None, :]

    def tree(ti, acc):
        f_l = jax.lax.dynamic_slice(feats, (ti, 0), (1, depth))[0]
        t_l = jax.lax.dynamic_slice(thrs, (ti, 0), (1, depth))[0]
        lv = jax.lax.dynamic_slice(leaves, (ti, 0), (1, n_leaves))[0]
        onehot_f = (f_iota == f_l[None, :]).astype(jnp.float32)
        sel = jax.lax.dot(x, onehot_f, precision=jax.lax.Precision.HIGHEST)
        idx = jnp.sum((sel >= t_l[None, :]).astype(jnp.int32) * pw2, axis=-1)
        onehot_l = (idx[:, None] == l_iota).astype(jnp.float32)
        return acc + jax.lax.dot(onehot_l, lv[:, None],
                                 precision=jax.lax.Precision.HIGHEST)[:, 0]

    acc0 = jnp.full((qb * nb,), base, jnp.float32)
    return jax.lax.fori_loop(0, t, tree, acc0).reshape(qb, nb)


def _fused_kernel(zq_ref, wq_ref, zc_ref, wc_ref, feats_ref, thrs_ref,
                  leaves_ref, out_ref, *, base: float):
    d = _distances(zq_ref[...], wq_ref[...], zc_ref[...], wc_ref[...])
    out_ref[...] = _fused_body(d, feats_ref, thrs_ref, leaves_ref, base=base)


@functools.partial(jax.jit, static_argnames=("base", "block_q", "block_n", "interpret"))
def fused_score_pallas(zq, wq, zc, wc, feats, thrs, leaves, *, base: float,
                       block_q: int = 8, block_n: int = 256,
                       interpret: bool = True):
    """Fused distance + GBDT scoring: -> (Q, N) f32 without HBM round-trip."""
    q, fn = zq.shape
    n = zc.shape[0]
    qp = -(-q // block_q) * block_q
    np_ = -(-n // block_n) * block_n
    zq = jnp.pad(zq, ((0, qp - q), (0, 0)))
    wq = jnp.pad(wq, ((0, qp - q), (0, 0)), constant_values=np.uint32(FT.HASH_SENTINEL))
    zc = jnp.pad(zc, ((0, np_ - n), (0, 0)))
    wc = jnp.pad(wc, ((0, np_ - n), (0, 0)), constant_values=np.uint32(FT.HASH_SENTINEL))
    fw = wq.shape[1]
    t, depth = feats.shape
    out = pl.pallas_call(
        functools.partial(_fused_kernel, base=base),
        grid=(qp // block_q, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_q, fn), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, fw), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, fn), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, fw), lambda i, j: (j, 0)),
            pl.BlockSpec((t, depth), lambda i, j: (0, 0)),
            pl.BlockSpec((t, depth), lambda i, j: (0, 0)),
            pl.BlockSpec((t, leaves.shape[1]), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.float32),
        interpret=interpret,
    )(zq, wq, zc, wc, feats, thrs, leaves)
    return out[:q, :n]


# ---------------------------------------------------------------------------
# Quantized corpus sidecars (int8 / fp16) with dequant-in-kernel scoring
# ---------------------------------------------------------------------------

PROFILE_DTYPES = ("fp32", "fp16", "int8")


def quantize_profiles(z: np.ndarray, dtype: str) -> tuple[np.ndarray, np.ndarray]:
    """Quantize a z-scored (C, F) profile matrix to a compact sidecar.

    Returns ``(sidecar, scale)`` where ``scale`` is the per-feature f32
    multiplier that dequantizes the sidecar back to f32
    (``sidecar.astype(f32) * scale``):

    * ``int8`` — symmetric per-feature quantization, scale = abs-max/127
      (the TPU-friendly layout from the quantization playbook; z-scored
      features are centred so symmetric loses nothing);
    * ``fp16`` — a plain half-precision copy, scale ≡ 1;
    * ``fp32`` — identity (scale ≡ 1), so callers can treat every dtype
      uniformly.
    """
    z = np.asarray(z, np.float32)
    f = z.shape[1] if z.ndim == 2 else 0
    ones = np.ones((f,), np.float32)
    if dtype == "fp32":
        return z, ones
    if dtype == "fp16":
        return z.astype(np.float16), ones
    if dtype == "int8":
        amax = np.abs(z).max(axis=0) if z.shape[0] else ones
        scale = np.maximum(amax, 1e-12).astype(np.float32) / 127.0
        q = np.clip(np.rint(z / scale[None, :]), -127, 127).astype(np.int8)
        return q, scale
    raise ValueError(f"unknown profile dtype {dtype!r}; want one of {PROFILE_DTYPES}")


def quantize_profiles_streamed(numeric, mean, std, dtype: str, *,
                               block: int = 8192
                               ) -> tuple[np.ndarray, np.ndarray]:
    """:func:`quantize_profiles` of ``(numeric - mean) / std`` without ever
    materializing the z-scored fp32 matrix — the lazy-snapshot path, where
    ``numeric`` is a read-only segment memmap and the eager z-score pass
    would page the whole lake through host memory just to throw the fp32
    away after quantization.  Blocks of ``block`` rows are z-scored and
    quantized in flight; only the compact sidecar accumulates.

    Byte-identical to the eager quantizer: int8's per-feature abs-max is
    order-independent, so the two-pass stream (pass 1 reduces the abs-max,
    pass 2 quantizes against it) lands on exactly the same scale.
    """
    mean = np.asarray(mean, np.float32)
    std = np.asarray(std, np.float32)
    c = int(numeric.shape[0])
    f = int(numeric.shape[1]) if getattr(numeric, "ndim", 2) == 2 else 0
    block = max(int(block), 1)
    ones = np.ones((f,), np.float32)
    zblock = lambda lo, hi: \
        (np.asarray(numeric[lo:hi], np.float32) - mean) / std
    if dtype == "fp32":
        out = np.empty((c, f), np.float32)
        for lo in range(0, c, block):
            out[lo:lo + block] = zblock(lo, lo + block)
        return out, ones
    if dtype == "fp16":
        out = np.empty((c, f), np.float16)
        for lo in range(0, c, block):
            out[lo:lo + block] = zblock(lo, lo + block).astype(np.float16)
        return out, ones
    if dtype == "int8":
        amax = np.zeros((f,), np.float32)
        for lo in range(0, c, block):        # pass 1: abs-max reduction
            z = zblock(lo, lo + block)
            if z.shape[0]:
                np.maximum(amax, np.abs(z).max(axis=0), out=amax)
        if c == 0:
            amax = ones
        scale = np.maximum(amax, 1e-12).astype(np.float32) / 127.0
        out = np.empty((c, f), np.int8)
        for lo in range(0, c, block):        # pass 2: quantize
            z = zblock(lo, lo + block)
            out[lo:lo + block] = np.clip(
                np.rint(z / scale[None, :]), -127, 127).astype(np.int8)
        return out, scale
    raise ValueError(f"unknown profile dtype {dtype!r}; "
                     f"want one of {PROFILE_DTYPES}")


def dequantize(zc, scale):
    """Sidecar block (..., F) of any dtype + (F,) scale -> f32 (jnp-safe)."""
    if zc.dtype == jnp.float32:
        return zc
    return zc.astype(jnp.float32) * scale


def _fused_q_kernel(zq_ref, wq_ref, zc_ref, scale_ref, wc_ref, feats_ref,
                    thrs_ref, leaves_ref, out_ref, *, base: float):
    zc = dequantize(zc_ref[...], scale_ref[...][0])
    d = _distances(zq_ref[...], wq_ref[...], zc, wc_ref[...])
    out_ref[...] = _fused_body(d, feats_ref, thrs_ref, leaves_ref, base=base)


@functools.partial(jax.jit, static_argnames=("base", "block_q", "block_n", "interpret"))
def fused_score_q_pallas(zq, wq, zc, scale, wc, feats, thrs, leaves, *,
                         base: float, block_q: int = 8, block_n: int = 256,
                         interpret: bool = True):
    """Fused scoring over a quantized (int8/fp16) corpus sidecar.

    ``zc`` is the (N, F_NUM) sidecar from :func:`quantize_profiles` and
    ``scale`` its (F_NUM,) dequant multiplier; queries stay f32. The
    sidecar is dequantized per VMEM tile inside the kernel, so HBM traffic
    for the corpus stream shrinks by the storage ratio.
    """
    q, fn = zq.shape
    n = zc.shape[0]
    qp = -(-q // block_q) * block_q
    np_ = -(-n // block_n) * block_n
    zq = jnp.pad(zq, ((0, qp - q), (0, 0)))
    wq = jnp.pad(wq, ((0, qp - q), (0, 0)), constant_values=np.uint32(FT.HASH_SENTINEL))
    zc = jnp.pad(zc, ((0, np_ - n), (0, 0)))
    wc = jnp.pad(wc, ((0, np_ - n), (0, 0)), constant_values=np.uint32(FT.HASH_SENTINEL))
    fw = wq.shape[1]
    t, depth = feats.shape
    scale2 = jnp.asarray(scale, jnp.float32)[None, :]            # (1, F_NUM)
    out = pl.pallas_call(
        functools.partial(_fused_q_kernel, base=base),
        grid=(qp // block_q, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_q, fn), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, fw), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, fn), lambda i, j: (j, 0)),
            pl.BlockSpec((1, fn), lambda i, j: (0, 0)),
            pl.BlockSpec((block_n, fw), lambda i, j: (j, 0)),
            pl.BlockSpec((t, depth), lambda i, j: (0, 0)),
            pl.BlockSpec((t, depth), lambda i, j: (0, 0)),
            pl.BlockSpec((t, leaves.shape[1]), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.float32),
        interpret=interpret,
    )(zq, wq, zc, scale2, wc, feats, thrs, leaves)
    return out[:q, :n]
