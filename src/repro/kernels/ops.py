"""Public jit'd entry points for the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container) they run in
``interpret=True`` mode — the kernel body executes as jnp ops, validating
semantics against ``ref.py``. Callers never pass ``interpret`` themselves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gbdt import GBDTParams
from repro.kernels.gbdt_infer import gbdt_infer_pallas
from repro.kernels.lsh_probe import (lsh_probe_gathered_pallas,
                                     lsh_probe_pallas)
from repro.kernels.minhash import make_permutations, minhash_pallas
from repro.kernels.profile_distance import (fused_score_pallas,
                                            fused_score_q_pallas,
                                            profile_distance_pallas,
                                            quantize_profiles)
from repro.kernels.quality_cdf import quality_cdf_pallas

__all__ = ["gbdt_infer", "profile_distance", "fused_score", "fused_score_q",
           "minhash", "lsh_probe", "lsh_probe_gathered", "quality_cdf",
           "quantize_profiles"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gbdt_infer(x, params: GBDTParams, *, block_n: int = 1024):
    feats, thrs, leaves, base = params.astuple()
    return gbdt_infer_pallas(jnp.asarray(x), jnp.asarray(feats),
                             jnp.asarray(thrs), jnp.asarray(leaves),
                             base=float(base), block_n=block_n,
                             interpret=_interpret())


def profile_distance(zq, wq, zc, wc, *, block_q: int = 8, block_n: int = 256):
    return profile_distance_pallas(jnp.asarray(zq), jnp.asarray(wq),
                                   jnp.asarray(zc), jnp.asarray(wc),
                                   block_q=block_q, block_n=block_n,
                                   interpret=_interpret())


def fused_score(zq, wq, zc, wc, params: GBDTParams, *, block_q: int = 8,
                block_n: int = 256):
    feats, thrs, leaves, base = params.astuple()
    return fused_score_pallas(jnp.asarray(zq), jnp.asarray(wq),
                              jnp.asarray(zc), jnp.asarray(wc),
                              jnp.asarray(feats), jnp.asarray(thrs),
                              jnp.asarray(leaves), base=float(base),
                              block_q=block_q, block_n=block_n,
                              interpret=_interpret())


def fused_score_q(zq, wq, zc, scale, wc, params: GBDTParams, *,
                  block_q: int = 8, block_n: int = 256):
    """Fused scoring over a quantized (int8/fp16) corpus sidecar."""
    feats, thrs, leaves, base = params.astuple()
    return fused_score_q_pallas(jnp.asarray(zq), jnp.asarray(wq),
                                jnp.asarray(zc), jnp.asarray(scale),
                                jnp.asarray(wc), jnp.asarray(feats),
                                jnp.asarray(thrs), jnp.asarray(leaves),
                                base=float(base), block_q=block_q,
                                block_n=block_n, interpret=_interpret())


def minhash(values, *, n_perm: int = 128, seed: int = 0,
            block_c: int = 8, block_r: int = 256):
    a, b = make_permutations(n_perm, seed)
    return minhash_pallas(jnp.asarray(values), a, b, block_c=block_c,
                          block_r=block_r, interpret=_interpret())


def lsh_probe(qkeys, ckeys, *, block_q: int = 8, block_c: int = 512):
    return lsh_probe_pallas(jnp.asarray(qkeys), jnp.asarray(ckeys),
                            block_q=block_q, block_c=block_c,
                            interpret=_interpret())


def lsh_probe_gathered(qkeys, ckeys, *, block_q: int = 8, block_c: int = 256):
    """Fine probe over per-query gathered survivor keys (Q, C', B)."""
    return lsh_probe_gathered_pallas(jnp.asarray(qkeys), jnp.asarray(ckeys),
                                     block_q=block_q, block_c=block_c,
                                     interpret=_interpret())


def quality_cdf(j, k, *, strictness: float = 0.25, block: int = 4096):
    return quality_cdf_pallas(jnp.asarray(j), jnp.asarray(k),
                              strictness=strictness, block=block,
                              interpret=_interpret())
