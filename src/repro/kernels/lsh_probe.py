"""Pallas TPU kernel: batched LSH bucket probe.

Given banded-MinHash bucket keys for a batch of queries (Q, B) and for the
whole catalog (C, B), the probe marks column c a candidate for query q iff
the two share a bucket in at least one band:

    hit[q, c] = any_b (qkeys[q, b] == ckeys[c, b])

This is the candidate-generation stage of the discovery pipeline
(``repro.exec``): an O(Q·C·B) stream of uint32 equality compares (VPU
work, no MXU, no floats) instead of the O(Q·C·F_DIST·T) fused GBDT scan —
the kernel's output mask picks the <<C columns the expensive scorer
actually sees. Under a sharded plan the kernel runs *inside* ``shard_map``
on each device's (C/devices, B) key shard (``exec/sharded.py``), so
candidate generation scales with the lake exactly like scoring; corpus
padding rows use ``PAD_CORPUS`` and never collide with query keys.

Tiling mirrors ``minhash.py``: the grid walks (Q, C) tiles, each program
loads a (Qb, B) and a (Cb, B) key block into VMEM and emits the (Qb, Cb)
int32 hit block. VMEM working set with the defaults (8 × 512 × 64 × 4 B
intermediate) is ~1 MB.

Dispatch: ``interpret=True`` means "no TPU here" (the CPU fallback every
serving path takes in this container), and interpret-mode ``pallas_call``
re-enters the Pallas interpreter once per grid step — at full-lake grids
(hundreds of tiles for 10^5 columns) that overhead outweighs the actual
uint32 compare stream by 30-100×.  The tile entry points therefore lower
to the jnp reference oracle when ``interpret`` is requested: identical
math, one fused XLA op.  ``lsh_probe_pallas`` / ``lsh_probe_gathered_pallas``
keep running the real interpreter so the parity suites still exercise the
kernel bodies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref

# Padding keys: queries and corpus pad with *different* sentinels so padded
# rows never match anything (including each other).
PAD_QUERY = np.uint32(0xFFFFFFFF)
PAD_CORPUS = np.uint32(0xFFFFFFFE)


def _kernel(qk_ref, ck_ref, out_ref):
    q = qk_ref[...]                                     # (Qb, B) u32
    c = ck_ref[...]                                     # (Cb, B) u32
    eq = q[:, None, :] == c[None, :, :]                 # (Qb, Cb, B)
    out_ref[...] = jnp.any(eq, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_q", "block_c", "interpret"))
def lsh_probe_pallas(qkeys, ckeys, *, block_q: int = 8, block_c: int = 512,
                     interpret: bool = True):
    """qkeys (Q, B) u32, ckeys (C, B) u32 -> (Q, C) int32 hit mask."""
    q, b = qkeys.shape
    c = ckeys.shape[0]
    qp = -(-q // block_q) * block_q
    cp = -(-c // block_c) * block_c
    qk = jnp.pad(qkeys, ((0, qp - q), (0, 0)), constant_values=PAD_QUERY)
    ck = jnp.pad(ckeys, ((0, cp - c), (0, 0)), constant_values=PAD_CORPUS)
    out = pl.pallas_call(
        _kernel,
        grid=(qp // block_q, cp // block_c),
        in_specs=[
            pl.BlockSpec((block_q, b), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, b), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, cp), jnp.int32),
        interpret=interpret,
    )(qk, ck)
    return out[:q, :c]


def lsh_probe_tile(qkeys, ckeys, *, block_q: int = 8, block_c: int = 512,
                   interpret: bool = True):
    """Per-(Q-shard, C-shard) tile entry point for grid pipelines.

    Under a 2-D (query × data) ``shard_map`` each device probes only its
    local query shard — often just 1-4 rows when the batch is spread over
    the ``query`` mesh axis. This wrapper clamps the query tile to the
    local shard size (and the corpus tile to the local column count) so a
    q-sharded probe doesn't pad every tiny shard up to the global default
    tile; shapes are static inside ``jit``/``shard_map``, so the clamp
    costs nothing at trace time.

    With ``interpret=True`` (no TPU) the probe lowers to the jnp reference
    instead of the per-tile Pallas interpreter — see the module docstring.
    """
    if interpret:
        return _ref.lsh_probe_ref(qkeys, ckeys)
    bq = max(1, min(int(block_q), int(qkeys.shape[0]) or 1))
    bc = max(1, min(int(block_c), int(ckeys.shape[0]) or 1))
    return lsh_probe_pallas(qkeys, ckeys, block_q=bq, block_c=bc,
                            interpret=interpret)


# ---------------------------------------------------------------------------
# Skinny-survivor geometry: per-query gathered corpora (tiered fine pass)
# ---------------------------------------------------------------------------

def _gathered_kernel(qk_ref, ck_ref, out_ref):
    q = qk_ref[...]                                     # (Qb, B) u32
    c = ck_ref[...]                                     # (Qb, Cb, B) u32
    eq = q[:, None, :] == c                             # (Qb, Cb, B)
    out_ref[...] = jnp.any(eq, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_q", "block_c", "interpret"))
def lsh_probe_gathered_pallas(qkeys, ckeys, *, block_q: int = 8,
                              block_c: int = 256, interpret: bool = True):
    """Fine probe over per-query gathered survivors.

    ``qkeys`` (Q, B) u32 against ``ckeys`` (Q, C', B) u32 — each query
    brings its *own* gathered key rows (the coarse pass's survivors,
    padded with ``PAD_CORPUS`` up to the static survivor budget C').
    Returns the (Q, C') int32 hit mask.

    The tiered geometry is skinny: C' is a few hundred to a few thousand
    where the full-lake probe sees 10^5+, so the corpus tile defaults much
    smaller (256) and is clamped to C' — one program often covers a whole
    query row's survivors.
    """
    q, b = qkeys.shape
    cprime = ckeys.shape[1]
    bq = max(1, min(int(block_q), q or 1))
    bc = max(1, min(int(block_c), cprime or 1))
    qp = -(-q // bq) * bq
    cp = -(-cprime // bc) * bc
    qk = jnp.pad(qkeys, ((0, qp - q), (0, 0)), constant_values=PAD_QUERY)
    ck = jnp.pad(ckeys, ((0, qp - q), (0, cp - cprime), (0, 0)),
                 constant_values=PAD_CORPUS)
    out = pl.pallas_call(
        _gathered_kernel,
        grid=(qp // bq, cp // bc),
        in_specs=[
            pl.BlockSpec((bq, b), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, bc, b), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, cp), jnp.int32),
        interpret=interpret,
    )(qk, ck)
    return out[:q, :cprime]


def lsh_probe_gathered_tile(qkeys, ckeys, *, block_q: int = 8,
                            block_c: int = 256, interpret: bool = True):
    """Dispatching entry point for the gathered fine probe: jnp reference
    when ``interpret`` is requested (CPU fallback), the Pallas kernel when
    compiling natively — same contract as ``lsh_probe_tile``."""
    if interpret:
        return _ref.lsh_probe_gathered_ref(qkeys, ckeys)
    return lsh_probe_gathered_pallas(qkeys, ckeys, block_q=block_q,
                                     block_c=block_c, interpret=interpret)
