"""Pallas TPU kernel: MinHash signatures (the syntactic baseline family).

For every column (tile of ``block_c``) and every permutation p, the kernel
streams value tiles of ``block_r`` through VMEM and keeps a running
element-wise minimum of the universal hash ``h_p(v) = a_p · v + b_p`` (uint32
wrap-around arithmetic — multiply-shift hashing). The output block revisits
the same (Cb, P) tile across the R grid dimension, initialized on the first
visit — the standard Pallas accumulation pattern.

VMEM working set: (block_c, block_r) values + (block_c, block_r, P) hash
intermediate when unchunked; with the defaults (8 × 256 × 128 × 4 B = 1 MB)
it fits comfortably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import features as FT

_SENT = np.uint32(FT.HASH_SENTINEL)
_UMAX = np.uint32(0xFFFFFFFF)


def _kernel(vals_ref, a_ref, b_ref, out_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, _UMAX, jnp.uint32)

    v = vals_ref[...]                                  # (Cb, Rb) u32
    a = a_ref[...][0]                                  # (P,) u32
    b = b_ref[...][0]
    h = v[:, :, None] * a[None, None, :] + b[None, None, :]
    h = jnp.where(v[:, :, None] == _SENT, _UMAX, h)
    m = jnp.min(h, axis=1)                             # (Cb, P)
    out_ref[...] = jnp.minimum(out_ref[...], m)


@functools.partial(jax.jit, static_argnames=("block_c", "block_r", "interpret"))
def minhash_pallas(values, a, b, *, block_c: int = 8, block_r: int = 256,
                   interpret: bool = True):
    """values (C, R) u32 sentinel-padded, a/b (P,) u32 -> (C, P) u32."""
    c, r = values.shape
    p = a.shape[0]
    cp = -(-c // block_c) * block_c
    rp = -(-r // block_r) * block_r
    vp = jnp.pad(values, ((0, cp - c), (0, rp - r)),
                 constant_values=np.uint32(FT.HASH_SENTINEL))
    out = pl.pallas_call(
        _kernel,
        grid=(cp // block_c, rp // block_r),
        in_specs=[
            pl.BlockSpec((block_c, block_r), lambda i, j: (i, j)),
            pl.BlockSpec((1, p), lambda i, j: (0, 0)),
            pl.BlockSpec((1, p), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, p), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, p), jnp.uint32),
        interpret=interpret,
    )(vp, a[None], b[None])
    return out[:c]


def make_permutations(n_perm: int = 128, seed: int = 0):
    """Odd multipliers + offsets for multiply-shift universal hashing."""
    rng = np.random.default_rng(seed)
    a = (rng.integers(1, 2 ** 32, size=n_perm, dtype=np.uint64) | 1).astype(np.uint32)
    b = rng.integers(0, 2 ** 32, size=n_perm, dtype=np.uint64).astype(np.uint32)
    return jnp.asarray(a), jnp.asarray(b)
