"""Pallas TPU kernels for FREYJA's perf-critical compute (interpret=True on
CPU; see ops.py for the public entry points and ref.py for the oracles):

  profile_distance / fused_score — pairwise profile distances (+ oblivious
                                    GBDT scoring fused in-VMEM)
  gbdt_infer                     — standalone oblivious-GBDT ensemble
  minhash                        — signature build (syntactic baseline)
  quality_cdf                    — truncated-Gaussian quality metric
"""
