"""Deterministic sharded token pipeline + FREYJA-augmented tabular path.

* ``TokenPipeline`` — synthetic corpus (mixture of Zipf-distributed n-gram
  "documents") with deterministic, restart-safe batching: batch ``i`` is a
  pure function of (seed, step), so a restarted job resumes mid-epoch
  byte-identically, and each data shard reads only its slice (host-sharded
  loading; here one host plays all parts).
* ``augmented_table_pipeline`` — the paper's downstream story: FREYJA
  discovers joinable columns for a base table and the pipeline emits
  feature-augmented rows for training (examples/discover_augment.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        b, s = self.global_batch, self.seq
        # zipfian unigrams + a short-range bigram structure so loss can fall
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        toks = (base + np.roll(base, 1, axis=1) * 7) % (self.vocab - 2) + 1
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1                      # mask the wrap position
        return {"tokens": toks.astype(np.int32), "labels": labels.astype(np.int32)}

    def shard_batch(self, step: int, shard: int, n_shards: int):
        full = self.batch(step)
        lo = shard * self.global_batch // n_shards
        hi = (shard + 1) * self.global_batch // n_shards
        return {k: v[lo:hi] for k, v in full.items()}


def augmented_table_pipeline(lake, index, query_col: int, k: int = 3):
    """Yield (base column values, discovered join partners) — the data-
    augmentation use the paper targets. Returns the top-k column ids and
    scores for the query column using the trained quality model."""
    from repro.core.discovery import rank
    scores, ids = rank(index, np.asarray([query_col]), k=k)
    return ids[0], scores[0]
