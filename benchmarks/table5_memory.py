"""Table V analogue: memory footprint of discovery structures vs raw lake.

The paper reports FREYJA profiles at <1% of lake size; we compare profiles
vs exact sketches vs MinHash signatures."""
from __future__ import annotations

from benchmarks.common import Timer, bench_lake, bench_profiles


def run():
    import numpy as np
    from repro.kernels import ops

    lake = bench_lake(0)
    prof = bench_profiles(0)
    raw = max(lake.raw_bytes, 1)
    sig = np.asarray(ops.minhash(lake.batch.values32, n_perm=128))
    sizes = {
        "freyja_profiles": prof.nbytes(),
        "exact_sketches": lake.packed.nbytes(),
        "minhash_sigs": sig.nbytes,
        "raw_lake": raw,
    }
    rows = []
    for name, b in sizes.items():
        rows.append((f"table5/{name}", 0.0,
                     f"{b/1e6:.3f} MB ({100*b/raw:.2f}% of raw)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
