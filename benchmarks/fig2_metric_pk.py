"""Fig. 2 analogue: P@k of containment vs set-Jaccard vs multiset-Jaccard
rankings over the ground-truth lake — the paper's motivating observation
that multiset Jaccard separates semantic from syntactic joins best."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Timer, hard_lake, precision_recall_at_k,
                               rank_by_scores)


def run(ks=(1, 3, 5, 10, 20), n_queries: int = 40):
    from repro.core import select_queries
    from repro.core.sketches import batch_exact_metrics
    import jax.numpy as jnp

    lake = hard_lake()
    qids = select_queries(lake, n_queries)
    p = lake.packed
    with Timer() as t:
        m = batch_exact_metrics(
            jnp.asarray(p.values[qids]), jnp.asarray(p.counts[qids]),
            jnp.asarray(p.card[qids]), jnp.asarray(p.n_rows[qids]),
            jnp.asarray(p.values), jnp.asarray(p.counts),
            jnp.asarray(p.card), jnp.asarray(p.n_rows))
        metrics = {k: np.asarray(v) for k, v in m.items()}

    # exclude self + same table + zero-overlap (not candidates)
    base_mask = np.ones((len(qids), lake.n_columns), bool)
    for i, q in enumerate(qids):
        base_mask[i, q] = False
        base_mask[i, lake.table == lake.table[q]] = False

    rows = []
    kmax = max(ks)
    for name, score in [("containment", metrics["containment"]),
                        ("jaccard", metrics["jaccard"]),
                        ("multiset_jaccard", metrics["j_multi"])]:
        s = np.where(base_mask & (metrics["j_multi"] > 0), score, -np.inf)
        sk, ids = rank_by_scores(s, kmax)
        valid = np.isfinite(sk)
        pr = precision_recall_at_k(lake, qids, ids, valid, ks)
        for k in ks:
            rows.append((f"fig2/{name}/P@{k}", t.s / len(qids) * 1e6,
                         f"{pr[k][0]:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
