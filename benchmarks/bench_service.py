"""Service benchmark: QPS and latency of the online engine at growing lake
sizes, LSH-pruned vs full scan, via the real catalog (disk round-trip).

Emits ``BENCH_service.json``:
  {"lakes": [{"n_columns": ..., "modes": {"lsh": {...}, "full": {...}},
              "speedup_lsh_over_full": ...}, ...]}
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import numpy as np

from benchmarks.common import Timer, bench_lake, bench_model

OUT_JSON = "BENCH_service.json"
TABLE_SIZES = (20, 45, 90)
N_QUERIES = 24
BATCH = 8


def _bench_engine(engine, qids, requests):
    from repro.service import serve_discovery
    # warm-up: compile every padded shape the runs below will hit
    list(serve_discovery(engine, requests, max_batch=BATCH))
    engine.query(requests[0])

    with Timer() as t_batch:
        list(serve_discovery(engine, requests, max_batch=BATCH))
    qps = len(requests) / max(t_batch.s, 1e-9)

    # per-query latency percentiles (cache is disabled by the caller)
    lats = []
    for req in requests:
        with Timer() as t:
            engine.query(req)
        lats.append(t.s * 1e3)
    return {
        "qps": qps,
        "batch_ms_per_query": t_batch.s / len(requests) * 1e3,
        "p50_ms": float(np.percentile(lats, 50)),
        "p99_ms": float(np.percentile(lats, 99)),
    }


def run():
    from repro.core import select_queries
    from repro.service import (ColumnCatalog, DiscoveryEngine,
                               DiscoveryRequest, EngineConfig, LSHConfig,
                               add_lake, measure_recall)

    model = bench_model()
    rows = []
    record = {"lakes": []}

    for n_tables in TABLE_SIZES:
        lake = bench_lake(seed=1, n_tables=n_tables)
        root = tempfile.mkdtemp(prefix=f"freyja_bench_{n_tables}_")
        try:
            catalog = ColumnCatalog(root, n_perm=128)
            with Timer() as t_ingest:
                add_lake(catalog, lake)
            snapshot = ColumnCatalog(root).snapshot()  # disk round-trip
        finally:
            shutil.rmtree(root, ignore_errors=True)
        c = snapshot.n_columns

        qids = select_queries(lake, N_QUERIES)
        requests = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q))
                    for q in qids]

        entry = {"n_tables": n_tables, "n_columns": c,
                 "ingest_s": t_ingest.s, "modes": {}}
        for mode in ("lsh", "full"):
            engine = DiscoveryEngine(
                snapshot, model,
                EngineConfig(k=10, mode=mode, lsh=LSHConfig(n_bands=64),
                             candidate_frac=0.2, cache_entries=0))
            stats = _bench_engine(engine, qids, requests)
            if mode == "lsh":
                rec = measure_recall(engine, qids, k=10)
                stats["recall_at_10"] = rec["recall"]
                stats["scored_fraction"] = rec["scored_fraction"]
            entry["modes"][mode] = stats
            rows.append((f"service/{mode}/C{c}",
                         stats["batch_ms_per_query"] * 1e3,
                         f"{stats['qps']:.1f} QPS p50={stats['p50_ms']:.1f}ms "
                         f"p99={stats['p99_ms']:.1f}ms"))

        # recall-vs-pruning curve of the raw LSH layer (no profile proxy)
        if n_tables == TABLE_SIZES[-1]:
            from repro.core import DiscoveryIndex, rank
            from repro.service.lsh import measure_tradeoff
            idx = DiscoveryIndex(profiles=snapshot.profiles, model=model,
                                 table_ids=snapshot.table_ids)
            _, top_ids = rank(idx, qids, k=10)
            entry["lsh_tradeoff"] = measure_tradeoff(
                snapshot.signatures, top_ids, qids)

        lsh, full = entry["modes"]["lsh"], entry["modes"]["full"]
        entry["speedup_lsh_over_full"] = (full["batch_ms_per_query"] /
                                          max(lsh["batch_ms_per_query"], 1e-9))
        rows.append((f"service/speedup/C{c}", 0.0,
                     f"{entry['speedup_lsh_over_full']:.2f}x "
                     f"recall={lsh['recall_at_10']:.3f} "
                     f"scored={100*lsh['scored_fraction']:.0f}%"))
        record["lakes"].append(entry)

    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)
    rows.append(("service/json", 0.0, os.path.abspath(OUT_JSON)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
