"""Service benchmark: QPS and latency of the online engine at growing lake
sizes, LSH-pruned vs full scan, via the real catalog (disk round-trip).

Emits ``BENCH_service.json``:
  {"lakes": [{"n_columns": ..., "modes": {"lsh": {...}, "full": {...}},
              "speedup_lsh_over_full": ...}, ...]}

Per-mode stats record the planner's chosen plan (``plan``) and the
shard-aware ``scored_fraction`` (global columns scored / lake size, psum-ed
over devices when the plan shards), so the JSON stays honest whether the
engine ran locally or over a mesh.

``--smoke`` runs one small lake in seconds and **fails (exit 1) on a
recall@10 regression below the gate** — the CI hook after the tier-1 suite.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import numpy as np

from benchmarks.common import Timer, bench_lake, bench_model

OUT_JSON = "BENCH_service.json"
TABLE_SIZES = (20, 45, 90)
SMOKE_TABLE_SIZES = (90,)
N_QUERIES = 24
SMOKE_N_QUERIES = 12
BATCH = 8
RECALL_GATE = 0.9


def _bench_engine(engine, qids, requests):
    from repro.service import serve_discovery
    # warm-up: compile every padded shape the runs below will hit
    list(serve_discovery(engine, requests, max_batch=BATCH))
    engine.query(requests[0])

    with Timer() as t_batch:
        list(serve_discovery(engine, requests, max_batch=BATCH))
    qps = len(requests) / max(t_batch.s, 1e-9)

    # per-query latency percentiles (cache is disabled by the caller)
    lats = []
    for req in requests:
        with Timer() as t:
            engine.query(req)
        lats.append(t.s * 1e3)
    plan = engine.stats().get("last_plan", {})
    return {
        "qps": qps,
        "batch_ms_per_query": t_batch.s / len(requests) * 1e3,
        "p50_ms": float(np.percentile(lats, 50)),
        "p99_ms": float(np.percentile(lats, 99)),
        "plan": plan.get("kind"),
        "plan_budget": plan.get("budget"),
    }


def run(smoke: bool = False):
    from repro.core import select_queries
    from repro.service import (ColumnCatalog, DiscoveryEngine,
                               DiscoveryRequest, EngineConfig, LSHConfig,
                               add_lake, measure_recall)

    table_sizes = SMOKE_TABLE_SIZES if smoke else TABLE_SIZES
    n_queries = SMOKE_N_QUERIES if smoke else N_QUERIES
    model = bench_model()
    rows = []
    record = {"lakes": [], "smoke": smoke}

    for n_tables in table_sizes:
        lake = bench_lake(seed=1, n_tables=n_tables)
        root = tempfile.mkdtemp(prefix=f"freyja_bench_{n_tables}_")
        try:
            catalog = ColumnCatalog(root, n_perm=128)
            with Timer() as t_ingest:
                add_lake(catalog, lake)
            snapshot = ColumnCatalog(root).snapshot()  # disk round-trip
        finally:
            shutil.rmtree(root, ignore_errors=True)
        c = snapshot.n_columns

        qids = select_queries(lake, n_queries)
        requests = [DiscoveryRequest(name=f"q{int(q)}", column_id=int(q))
                    for q in qids]

        entry = {"n_tables": n_tables, "n_columns": c,
                 "ingest_s": t_ingest.s, "modes": {}}
        for mode in ("lsh", "full"):
            engine = DiscoveryEngine(
                snapshot, model,
                EngineConfig(k=10, mode=mode, lsh=LSHConfig(n_bands=64),
                             candidate_frac=0.2, cache_entries=0))
            stats = _bench_engine(engine, qids, requests)
            if mode == "lsh":
                rec = measure_recall(engine, qids, k=10)
                stats["recall_at_10"] = rec["recall"]
                stats["scored_fraction"] = rec["scored_fraction"]
            entry["modes"][mode] = stats
            rows.append((f"service/{mode}/C{c}",
                         stats["batch_ms_per_query"] * 1e3,
                         f"{stats['qps']:.1f} QPS p50={stats['p50_ms']:.1f}ms "
                         f"p99={stats['p99_ms']:.1f}ms plan={stats['plan']}"))

        # recall-vs-pruning curve of the raw LSH layer (no profile proxy)
        if not smoke and n_tables == table_sizes[-1]:
            from repro.core import DiscoveryIndex, rank
            from repro.service.lsh import measure_tradeoff
            idx = DiscoveryIndex(profiles=snapshot.profiles, model=model,
                                 table_ids=snapshot.table_ids)
            _, top_ids = rank(idx, qids, k=10)
            entry["lsh_tradeoff"] = measure_tradeoff(
                snapshot.signatures, top_ids, qids)

        lsh, full = entry["modes"]["lsh"], entry["modes"]["full"]
        entry["speedup_lsh_over_full"] = (full["batch_ms_per_query"] /
                                          max(lsh["batch_ms_per_query"], 1e-9))
        rows.append((f"service/speedup/C{c}", 0.0,
                     f"{entry['speedup_lsh_over_full']:.2f}x "
                     f"recall={lsh['recall_at_10']:.3f} "
                     f"scored={100*lsh['scored_fraction']:.0f}%"))
        record["lakes"].append(entry)

    with open(OUT_JSON, "w") as f:
        json.dump(record, f, indent=1)
    rows.append(("service/json", 0.0, os.path.abspath(OUT_JSON)))

    worst = min(e["modes"]["lsh"]["recall_at_10"] for e in record["lakes"])
    rows.append(("service/recall_gate", 0.0,
                 f"worst recall@10 {worst:.3f} vs gate {RECALL_GATE}"))
    # the gate is enforced in smoke mode (CI); the full sweep also covers
    # deliberately hard small lakes where the pruned plan sits below it
    if smoke and worst < RECALL_GATE:
        raise SystemExit(
            f"RECALL REGRESSION: recall@10 {worst:.3f} < "
            f"gate {RECALL_GATE} (see {os.path.abspath(OUT_JSON)})")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small lake, fast; exit 1 below the recall gate")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(map(str, r)))
